"""Benchmark-regression gate (CI step, see .github/workflows/ci.yml).

Compares the fresh fast-mode results (``BENCH_*.fast.json``, written by
``python -m benchmarks.run --fast``) against the committed full-run
baselines (``BENCH_*.json``), and sanity-checks the committed baselines
themselves, so a perf regression fails the build instead of silently
shipping in an artifact:

* ``warm_batched_per_query_us`` (fast run) must not exceed 2x the committed
  full-run value — the fast config is ~4x smaller, so honoring this bound
  is easy unless the warm path actually regressed;
* ``payload_shrink_factor`` (fast run) must stay >= 8 — the bitpacked
  collective must keep its 8x advantage over uint8 shipping;
* committed ``BENCH_pr3.json`` must show incremental repair beating a full
  cache rebuild by >= 5x median at the Table-2 config, and the fast run
  must clear a small-graph floor (overheads dominate tiny matrices);
* mixed-kind session batches (``BENCH_pr4``): the fast-run warm
  per-query cost must not exceed 2x the committed full-run value (the fast
  config is ~3x smaller), and fusing a mixed reach+dist+RPQ batch must
  beat the per-kind serving loop (committed >= 3x, fast >= a small-graph
  floor — the RPQ group is what the per-kind loop cannot batch);
* sharded mixed batches (``BENCH_pr5``): both runs must report
  ``answers_match`` (shard_map == vmap answers on the mixed workload) and
  ``payload_bits_ok`` (summed per-group QueryStats == the wire size of
  each group's single collective), and the fast-run shard_map per-query
  cost must not exceed 3x the committed value (fake-device collectives on
  one CPU are noisier than the vmap path, hence the looser factor);
* k >> d scale-out (``BENCH_pr6``): every packing factor row (k fragments
  on 8 devices) in both runs must report ``answers_match`` and
  ``payload_bits_ok`` — packing must change neither answers nor the wire
  — and the fast run's densest-packing per-query cost must not exceed 3x
  the committed value;
* chaos serving (``BENCH_pr7``): both runs must report ``answers_ok``
  (every answered result exact against the delta-replay oracle) and a
  request ``success_rate`` >= 0.99 under the seeded 1% fault schedule,
  and the fast run's steady-state p95 per-query latency must not exceed
  3x the committed value;
* async continuous batching (``BENCH_pr8``): both runs must report
  ``answers_ok`` (every mode of the equal-work comparison plus the
  open-loop phase oracle-exact); the committed run's async engine must
  at least match the synchronous drain pattern's throughput at equal
  work (``throughput_ratio`` >= 1.0; the fast run gets a noise
  allowance), and the fast run's open-loop p99 latency must stay within
  3x the committed baseline (with a small-run absolute floor);
* MVCC snapshot serving (``BENCH_pr9``): both runs must report
  ``answers_ok`` (every read verified against the graph snapshot named
  by its stamped ``cache_version``) and carry the kernel roofline rows
  (report-only — no perf gate on achieved-vs-peak yet); the committed
  run's worst-mix barrier/mvcc read-p95 ratio must show MVCC retiring
  the write stall by >= 2x (the fast run gets a noise floor).

Exits non-zero with a FAIL line per violated bound.
"""
from __future__ import annotations

import json
import sys

WARM_REGRESSION_FACTOR = 2.0
MIN_PAYLOAD_SHRINK = 8.0
MIN_REPAIR_SPEEDUP_FULL = 5.0
MIN_REPAIR_SPEEDUP_FAST = 2.0
MIXED_REGRESSION_FACTOR = 2.0
MIN_FUSED_SPEEDUP_FULL = 3.0
MIN_FUSED_SPEEDUP_FAST = 1.3
SHARDED_REGRESSION_FACTOR = 3.0
MIN_CHAOS_SUCCESS_RATE = 0.99
CHAOS_P95_REGRESSION_FACTOR = 3.0
MIN_ASYNC_THROUGHPUT_RATIO_FULL = 1.0
MIN_ASYNC_THROUGHPUT_RATIO_FAST = 0.7
ASYNC_P99_REGRESSION_FACTOR = 3.0
ASYNC_P99_FLOOR_MS = 50.0
MIN_MVCC_P95_RATIO_FULL = 2.0
MIN_MVCC_P95_RATIO_FAST = 1.2


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = argv[0] if argv else "."
    failures = []

    def check(name, ok, detail):
        status = "PASS" if ok else "FAIL"
        print(f"{status} {name}: {detail}")
        if not ok:
            failures.append(name)

    base2 = _load(f"{root}/BENCH_pr2.json")
    fast2 = _load(f"{root}/BENCH_pr2.fast.json")
    warm_base = base2["warm_batched_per_query_us"]
    warm_fast = fast2["warm_batched_per_query_us"]
    check(
        "warm_batched_per_query_us",
        warm_fast <= WARM_REGRESSION_FACTOR * warm_base,
        f"fast {warm_fast:.1f}us vs committed {warm_base:.1f}us "
        f"(limit {WARM_REGRESSION_FACTOR}x)",
    )
    shrink = fast2["payload_shrink_factor"]
    check(
        "payload_shrink_factor",
        shrink >= MIN_PAYLOAD_SHRINK,
        f"fast {shrink:.2f} (floor {MIN_PAYLOAD_SHRINK})",
    )

    base3 = _load(f"{root}/BENCH_pr3.json")
    fast3 = _load(f"{root}/BENCH_pr3.fast.json")
    sp_full = base3["repair_speedup_median"]
    check(
        "repair_speedup_median (committed, Table-2 cfg)",
        sp_full >= MIN_REPAIR_SPEEDUP_FULL,
        f"committed {sp_full:.2f}x (floor {MIN_REPAIR_SPEEDUP_FULL}x)",
    )
    sp_fast = fast3["repair_speedup_median"]
    check(
        "repair_speedup_median (fast run)",
        sp_fast >= MIN_REPAIR_SPEEDUP_FAST,
        f"fast {sp_fast:.2f}x (floor {MIN_REPAIR_SPEEDUP_FAST}x)",
    )

    base4 = _load(f"{root}/BENCH_pr4.json")
    fast4 = _load(f"{root}/BENCH_pr4.fast.json")
    mixed_base = base4["mixed_per_query_us"]
    mixed_fast = fast4["mixed_per_query_us"]
    check(
        "mixed_per_query_us",
        mixed_fast <= MIXED_REGRESSION_FACTOR * mixed_base,
        f"fast {mixed_fast:.1f}us vs committed {mixed_base:.1f}us "
        f"(limit {MIXED_REGRESSION_FACTOR}x)",
    )
    fs_full = base4["fused_speedup"]
    check(
        "fused_speedup (committed)",
        fs_full >= MIN_FUSED_SPEEDUP_FULL,
        f"committed {fs_full:.2f}x (floor {MIN_FUSED_SPEEDUP_FULL}x)",
    )
    fs_fast = fast4["fused_speedup"]
    check(
        "fused_speedup (fast run)",
        fs_fast >= MIN_FUSED_SPEEDUP_FAST,
        f"fast {fs_fast:.2f}x (floor {MIN_FUSED_SPEEDUP_FAST}x)",
    )

    base5 = _load(f"{root}/BENCH_pr5.json")
    fast5 = _load(f"{root}/BENCH_pr5.fast.json")
    for tag, rep in (("committed", base5), ("fast", fast5)):
        check(
            f"sharded answers_match ({tag})",
            rep["answers_match"],
            "shard_map answers == vmap answers on the mixed batch",
        )
        check(
            f"sharded payload_bits_ok ({tag})",
            rep["payload_bits_ok"],
            "summed group QueryStats == one-collective wire size",
        )
    sh_base = base5["shard_map_per_query_us"]
    sh_fast = fast5["shard_map_per_query_us"]
    check(
        "shard_map_per_query_us",
        sh_fast <= SHARDED_REGRESSION_FACTOR * sh_base,
        f"fast {sh_fast:.1f}us vs committed {sh_base:.1f}us "
        f"(limit {SHARDED_REGRESSION_FACTOR}x)",
    )

    base6 = _load(f"{root}/BENCH_pr6.json")
    fast6 = _load(f"{root}/BENCH_pr6.fast.json")
    for tag, rep in (("committed", base6), ("fast", fast6)):
        for row in rep["rows"]:
            label = f"k={row['k']} fpd={row['fragments_per_device']}"
            check(
                f"scaleout answers_match ({tag}, {label})",
                row["answers_match"],
                "packed shard_map answers == vmap answers",
            )
            check(
                f"scaleout payload_bits_ok ({tag}, {label})",
                row["payload_bits_ok"],
                "summed group QueryStats == one-collective wire size",
            )
    dense_base = max(base6["rows"], key=lambda r: r["fragments_per_device"])
    dense_fast = max(fast6["rows"], key=lambda r: r["fragments_per_device"])
    check(
        "scaleout per_query_us (densest packing)",
        dense_fast["per_query_us"]
        <= SHARDED_REGRESSION_FACTOR * dense_base["per_query_us"],
        f"fast {dense_fast['per_query_us']:.1f}us vs committed "
        f"{dense_base['per_query_us']:.1f}us "
        f"(limit {SHARDED_REGRESSION_FACTOR}x)",
    )

    base7 = _load(f"{root}/BENCH_pr7.json")
    fast7 = _load(f"{root}/BENCH_pr7.fast.json")
    for tag, rep in (("committed", base7), ("fast", fast7)):
        check(
            f"chaos answers_ok ({tag})",
            rep["answers_ok"],
            "answered results exact against the delta-replay oracle",
        )
        rate = rep["success_rate"]
        check(
            f"chaos success_rate ({tag})",
            rate >= MIN_CHAOS_SUCCESS_RATE,
            f"{rate:.3f} (floor {MIN_CHAOS_SUCCESS_RATE})",
        )
    p95_base = base7["p95_per_query_us"]
    p95_fast = fast7["p95_per_query_us"]
    check(
        "chaos p95_per_query_us",
        p95_fast <= CHAOS_P95_REGRESSION_FACTOR * p95_base,
        f"fast {p95_fast:.1f}us vs committed {p95_base:.1f}us "
        f"(limit {CHAOS_P95_REGRESSION_FACTOR}x)",
    )

    base8 = _load(f"{root}/BENCH_pr8.json")
    fast8 = _load(f"{root}/BENCH_pr8.fast.json")
    for tag, rep in (("committed", base8), ("fast", fast8)):
        check(
            f"async answers_ok ({tag})",
            rep["answers_ok"],
            "sync-drain, continuous, and open-loop answers all "
            "oracle-exact",
        )
        check(
            f"async route coverage ({tag})",
            len(rep["open_loop"]["routes"]) >= 2,
            f"open-loop telemetry saw routes "
            f"{sorted(rep['open_loop']['routes'])}",
        )
    ratio_full = base8["throughput_ratio"]
    check(
        "async throughput_ratio (committed)",
        ratio_full >= MIN_ASYNC_THROUGHPUT_RATIO_FULL,
        f"committed async/sync {ratio_full:.2f}x "
        f"(floor {MIN_ASYNC_THROUGHPUT_RATIO_FULL}x)",
    )
    ratio_fast = fast8["throughput_ratio"]
    check(
        "async throughput_ratio (fast run)",
        ratio_fast >= MIN_ASYNC_THROUGHPUT_RATIO_FAST,
        f"fast async/sync {ratio_fast:.2f}x "
        f"(floor {MIN_ASYNC_THROUGHPUT_RATIO_FAST}x)",
    )
    p99_base = base8["open_loop"]["p99_ms"]
    p99_fast = fast8["open_loop"]["p99_ms"]
    p99_limit = max(ASYNC_P99_REGRESSION_FACTOR * p99_base,
                    ASYNC_P99_FLOOR_MS)
    check(
        "async open-loop p99_ms",
        p99_fast <= p99_limit,
        f"fast {p99_fast:.1f}ms vs committed {p99_base:.1f}ms "
        f"(limit {p99_limit:.1f}ms)",
    )

    base9 = _load(f"{root}/BENCH_pr9.json")
    fast9 = _load(f"{root}/BENCH_pr9.fast.json")
    for tag, rep in (("committed", base9), ("fast", fast9)):
        check(
            f"mvcc answers_ok ({tag})",
            rep["answers_ok"],
            "every read exact against the per-snapshot replay oracle "
            "(stamped cache_version -> replayed graph), both modes",
        )
        check(
            f"mvcc roofline coverage ({tag})",
            len(rep["roofline"]["kernels"]) >= 3,
            f"kernel roofline rows {sorted(rep['roofline']['kernels'])} "
            "(report-only, no perf gate)",
        )
    ratio9_full = base9["read_p95_ratio_min"]
    check(
        "mvcc read_p95_ratio_min (committed)",
        ratio9_full >= MIN_MVCC_P95_RATIO_FULL,
        f"committed barrier/mvcc read-p95 {ratio9_full:.2f}x over all "
        f"mixes (floor {MIN_MVCC_P95_RATIO_FULL}x)",
    )
    ratio9_fast = fast9["read_p95_ratio_min"]
    check(
        "mvcc read_p95_ratio_min (fast run)",
        ratio9_fast >= MIN_MVCC_P95_RATIO_FAST,
        f"fast barrier/mvcc read-p95 {ratio9_fast:.2f}x over all mixes "
        f"(floor {MIN_MVCC_P95_RATIO_FAST}x)",
    )

    if failures:
        print(f"regression gate FAILED: {failures}", file=sys.stderr)
        return 1
    print("regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
