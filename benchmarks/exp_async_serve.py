"""Async continuous-batching serving vs the synchronous drain pattern
(ISSUE-8 / DESIGN.md Sec. 8), plus an open-loop latency-by-route table.

Two phases over one fragmentation and one mixed reach/dist/RPQ workload:

* **equal-work throughput** — the same query list served (a) the PR-7
  way: one caller thread submitting a bucket then blocking on a
  synchronous barrier, round-tripping per bucket; and (b) the PR-8 way:
  concurrent submitter threads streaming the whole workload into a
  running scheduler and blocking only on their own futures.  Work is
  identical (same queries, same batch size, warm caches/compiles), so
  the ratio isolates what continuous batching buys: intake overlaps
  execution instead of serializing with it.  ``check_regression`` gates
  ``throughput_ratio`` (async must not lose to the barrier pattern).
* **open-loop latency** — arrivals paced on a fixed schedule at ~half
  the measured async capacity (open loop: the schedule never waits for
  completions, so queueing shows up in the numbers instead of being
  hidden by back-pressure).  Per-route p50/p95/p99 come straight from
  the server's live telemetry; ``check_regression`` bounds the fast
  run's p99 against the committed baseline.

All answers (both phases, every mode) are verified against the
networkx oracles; ``answers_ok`` gates in CI.
"""
from __future__ import annotations

import os
import statistics
import sys
import threading
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import build_query_automaton, fragment_graph
from repro.graph import erdos_renyi, random_partition
from repro.serve import QueryServer
from repro.serve.telemetry import percentile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))
from oracles import oracle_dist, oracle_reach, oracle_rpq  # noqa: E402

RESULT_TIMEOUT_S = 600.0
KINDS = ("reach", "dist", "rpq")


def _workload(g, n_q: int, rng) -> List[Tuple[int, int, str]]:
    return [(int(rng.integers(g.n)), int(rng.integers(g.n)),
             KINDS[i % len(KINDS)]) for i in range(n_q)]


def _check_answers(g, qa, served) -> bool:
    ok = True
    for s, t, kind, fut in served:
        if kind == "reach":
            want = oracle_reach(g, s, t)
        elif kind == "dist":
            want = oracle_dist(g, s, t)
        else:
            want = oracle_rpq(g, s, t, qa)
        ok = ok and fut.value == want
    return ok


def exp_async_serve(n: int = 900, m: int = 3600, k: int = 4,
                    n_q: int = 240, workers: int = 6, batch_size: int = 16,
                    open_loop_n: int = 120, repeats: int = 3,
                    seed: int = 7) -> Dict:
    g = erdos_renyi(n, m, n_labels=3, seed=seed)
    fr = fragment_graph(g, random_partition(g, k, 1), k)
    qa = build_query_automaton("(0|1)*", lambda x: int(x))
    rng = np.random.default_rng(2)
    work = _workload(g, n_q, rng)

    def submit_one(srv, s, t, kind):
        if kind == "rpq":
            return srv.submit(s, t, kind="rpq", automaton=qa)
        return srv.submit(s, t, kind=kind)

    # -- warmup: caches + every (kind, bucket-shape) compile out of the
    #    timings (chunks pad to powers of two, so size-1 and size-batch
    #    flushes cover both shapes each kind can ship as)
    warm = QueryServer(fr, batch_size=batch_size, with_dist=True,
                       start=False)
    for size in (1, batch_size):
        for kind in KINDS:
            for s, t, _ in work[:size]:
                submit_one(warm, s, t, kind)
            warm.flush()
    warm.close()

    # -- phase A: equal work, barrier round-trips vs continuous batching
    def sync_pass() -> Tuple[float, list]:
        srv = QueryServer(fr, batch_size=batch_size, warm=False,
                          start=False)
        served = []
        t0 = time.perf_counter()
        for i in range(0, len(work), batch_size):
            for s, t, kind in work[i:i + batch_size]:
                served.append((s, t, kind, submit_one(srv, s, t, kind)))
            srv.flush()                  # the PR-7 submit/drain round-trip
        elapsed = time.perf_counter() - t0
        srv.close()
        return elapsed, served

    def async_pass() -> Tuple[float, list]:
        srv = QueryServer(fr, batch_size=batch_size, warm=False,
                          batch_wait_ms=1.0)
        slices = [work[w::workers] for w in range(workers)]
        served = [[] for _ in range(workers)]

        def run_worker(w):
            for s, t, kind in slices[w]:
                served[w].append((s, t, kind, submit_one(srv, s, t, kind)))
            for *_, fut in served[w]:
                fut.result(timeout=RESULT_TIMEOUT_S)

        threads = [threading.Thread(target=run_worker, args=(w,))
                   for w in range(workers)]
        t0 = time.perf_counter()
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        elapsed = time.perf_counter() - t0
        srv.close()
        return elapsed, [x for sub in served for x in sub]

    answers_ok = True
    sync_s, async_s = [], []
    for _ in range(repeats):
        el, served = sync_pass()
        sync_s.append(el)
        answers_ok = answers_ok and _check_answers(g, qa, served)
        el, served = async_pass()
        async_s.append(el)
        answers_ok = answers_ok and _check_answers(g, qa, served)
    sync_qps = n_q / statistics.median(sync_s)
    async_qps = n_q / statistics.median(async_s)

    # -- phase B: open-loop arrivals at ~half capacity, latency by route
    offered_qps = max(50.0, 0.5 * async_qps)
    open_work = _workload(g, open_loop_n, rng)
    srv = QueryServer(fr, batch_size=batch_size, warm=False,
                      batch_wait_ms=2.0)
    served = []
    t0 = time.perf_counter()
    for i, (s, t, kind) in enumerate(open_work):
        lag = t0 + i / offered_qps - time.perf_counter()
        if lag > 0:
            time.sleep(lag)              # fixed schedule, never back-off
        served.append((s, t, kind, submit_one(srv, s, t, kind)))
    for *_, fut in served:
        fut.result(timeout=RESULT_TIMEOUT_S)
    elapsed = time.perf_counter() - t0
    snap = srv.telemetry()
    srv.close()
    answers_ok = answers_ok and _check_answers(g, qa, served)
    lat_ms = [fut.latency_s * 1e3 for *_, fut in served]

    return {
        "backend": "vmap",
        "n": n, "m": m, "k": k, "n_queries": n_q,
        "workers": workers, "batch_size": batch_size,
        "sync_qps": sync_qps,
        "async_qps": async_qps,
        "throughput_ratio": async_qps / sync_qps,
        "answers_ok": bool(answers_ok),
        "open_loop": {
            "n": open_loop_n,
            "offered_qps": offered_qps,
            "achieved_qps": open_loop_n / elapsed,
            "p50_ms": percentile(lat_ms, 0.50),
            "p95_ms": percentile(lat_ms, 0.95),
            "p99_ms": percentile(lat_ms, 0.99),
            "batch_occupancy": snap["batch_occupancy"],
            "routes": snap["routes"],
        },
    }
