"""MVCC snapshot store vs the barrier write path under a mixed
read/write open-loop workload (ISSUE-9 / DESIGN.md Sec. 9).

One fragmentation, one arrival schedule, two server modes at **equal
work**:

* **barrier** (PR-8 default): every delta fences the queue — queries
  behind it wait for the whole repair;
* **mvcc** (``QueryServer(..., mvcc=True)``): deltas commit as
  copy-on-write versions on the repair worker while query chunks keep
  serving the pinned head snapshot.

Two mixes (95/5 and 50/50 read/write) are paced open-loop (the schedule
never waits for completions, so write stalls show up as read latency
instead of being hidden by back-pressure), and the headline number is the
read p95 during sustained updates — ``check_regression`` gates the
barrier/mvcc ratio (MVCC must actually retire the write stall) and
``answers_ok``.

Answers are oracle-checked **per snapshot**: each applied delta bumps the
rvset-cache version exactly once, so a read's stamped ``cache_version``
names the graph snapshot it was served against; every answer is verified
with networkx on exactly that replayed graph (pre-delta reads against
pre-delta snapshots — the MVCC consistency model, checked end to end).
"""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import GraphDelta, fragment_graph
from repro.graph import Graph, erdos_renyi, random_partition
from repro.serve import QueryServer
from repro.serve.telemetry import percentile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))
from oracles import oracle_dist, oracle_reach  # noqa: E402

RESULT_TIMEOUT_S = 600.0
MIXES = (("95_5", 0.05), ("50_50", 0.50))


def _snapshot_graphs(g: Graph, deltas: List[list]) -> List[Graph]:
    """``snaps[i]`` = the graph after the first ``i`` deltas (host replay
    of the committed version sequence)."""
    snaps = [g]
    for edges in deltas:
        prev = snaps[-1]
        e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        snaps.append(Graph(prev.n,
                           np.concatenate([prev.src, e[:, 0]]),
                           np.concatenate([prev.dst, e[:, 1]]),
                           prev.labels, prev.label_names))
    return snaps


def _schedule(n_events: int, write_frac: float, g: Graph,
              rng) -> Tuple[List[tuple], int]:
    """One deterministic open-loop event list: reads interleaved with
    evenly spaced writes (same schedule for both server modes)."""
    n_upd = max(2, int(round(n_events * write_frac)))
    spacing = n_events / n_upd
    write_at = {int((j + 0.5) * spacing) for j in range(n_upd)}
    assert len(write_at) == n_upd
    events, wi = [], 0
    for i in range(n_events):
        if i in write_at:
            events.append(("write", wi))
            wi += 1
        else:
            kind = "dist" if i % 2 else "reach"
            events.append(("read", int(rng.integers(g.n)),
                           int(rng.integers(g.n)), kind))
    return events, n_upd


def _check_reads(snaps: List[Graph], c0: int, reads) -> bool:
    ok = True
    for s, t, kind, fut in reads:
        idx = fut.cache_version - c0
        if not 0 <= idx < len(snaps):
            return False
        g_i = snaps[idx]
        want = (oracle_dist(g_i, s, t) if kind == "dist"
                else oracle_reach(g_i, s, t))
        ok = ok and fut.value == want
    return ok


def _run_pass(mode: str, fr, events, deltas, snaps, batch_size: int,
              offered_qps: float) -> Dict:
    srv = QueryServer(fr, batch_size=batch_size, with_dist=True,
                      batch_wait_ms=2.0, mvcc=(mode == "mvcc"))
    # probe before the window: pins the initial head, yields the base
    # cache_version every stamped read is mapped through
    probe = srv.submit(0, 1)
    probe.result(timeout=RESULT_TIMEOUT_S)
    c0 = probe.cache_version

    reads, upds = [], []
    t0 = time.perf_counter()
    for i, ev in enumerate(events):
        lag = t0 + i / offered_qps - time.perf_counter()
        if lag > 0:
            time.sleep(lag)              # fixed schedule, never back-off
        if ev[0] == "write":
            upds.append(srv.submit_delta(GraphDelta.insert(deltas[ev[1]])))
        else:
            _, s, t, kind = ev
            reads.append((s, t, kind, srv.submit(s, t, kind=kind)))
    for *_, fut in reads:
        fut.result(timeout=RESULT_TIMEOUT_S)
    reads_done_s = time.perf_counter() - t0
    for u in upds:
        u.result(timeout=RESULT_TIMEOUT_S)
    total_s = time.perf_counter() - t0

    # every delta committed: a fresh read must see the final snapshot
    # (and exactly one version bump per applied delta — the stamp's
    # contract with the replay oracle above)
    fin = srv.submit(0, 1)
    fin.result(timeout=RESULT_TIMEOUT_S)
    stamp_ok = fin.cache_version == c0 + len(upds)
    gauges: Optional[Dict] = srv.telemetry().get("mvcc")
    srv.close()

    lat_ms = [fut.latency_s * 1e3 for *_, fut in reads]
    upd_ms = [u.latency_s * 1e3 for u in upds]
    return {
        "read_p50_ms": percentile(lat_ms, 0.50),
        "read_p95_ms": percentile(lat_ms, 0.95),
        "read_p99_ms": percentile(lat_ms, 0.99),
        "update_p50_ms": percentile(upd_ms, 0.50),
        "update_p95_ms": percentile(upd_ms, 0.95),
        "reads_done_s": reads_done_s,
        "total_s": total_s,
        "answers_ok": bool(_check_reads(snaps, c0, reads)),
        "stamp_ok": bool(stamp_ok),
        "mvcc_gauges": gauges,
    }


def exp_mvcc(n: int = 900, m: int = 3600, k: int = 4, batch_size: int = 16,
             n_events: int = 160, edges_per_delta: int = 2,
             seed: int = 7) -> Dict:
    g = erdos_renyi(n, m, n_labels=3, seed=seed)
    rng = np.random.default_rng(3)

    # one delta pool sized for the write-heaviest mix; headroom reserves
    # cover the worst case of every inserted edge landing in one fragment
    n_upd_max = max(2, int(round(n_events * max(f for _, f in MIXES))))
    pool = [[(int(rng.integers(n)), int(rng.integers(n)))
             for _ in range(edges_per_delta)] for _ in range(n_upd_max)]
    headroom = n_upd_max * edges_per_delta + 8
    part = random_partition(g, k, 1)

    def fresh_fr():
        return fragment_graph(g, part, k, reserve_boundary=headroom,
                              reserve_edges=headroom, reserve_stubs=headroom)

    # -- warmup on a throwaway fragmentation: every (kind, bucket-shape)
    #    query compile plus one repair compile, out of the timed windows
    fr_w = fresh_fr()
    warm = QueryServer(fr_w, batch_size=batch_size, with_dist=True,
                       start=False)
    for size in (1, batch_size):
        for kind in ("reach", "dist"):
            for _ in range(size):
                warm.submit(int(rng.integers(n)), int(rng.integers(n)),
                            kind=kind)
            warm.flush()
    warm.submit_delta(GraphDelta.insert(pool[0]))
    warm.flush()
    # closed-loop read capacity on the warm server sets the offered rate
    n_cal = 3 * batch_size
    t0 = time.perf_counter()
    for _ in range(n_cal):
        warm.submit(int(rng.integers(n)), int(rng.integers(n)))
    warm.flush()
    read_qps = n_cal / (time.perf_counter() - t0)
    warm.close()
    offered_qps = float(np.clip(0.5 * read_qps, 40.0, 500.0))

    answers_ok = True
    mixes: Dict[str, Dict] = {}
    ratios = []
    for name, frac in MIXES:
        ev_rng = np.random.default_rng(11)
        events, n_upd = _schedule(n_events, frac, g, ev_rng)
        deltas = pool[:n_upd]
        snaps = _snapshot_graphs(g, deltas)
        row: Dict = {"n_reads": n_events - n_upd, "n_updates": n_upd}
        for mode in ("barrier", "mvcc"):
            res = _run_pass(mode, fresh_fr(), events, deltas, snaps,
                            batch_size, offered_qps)
            answers_ok = answers_ok and res["answers_ok"] and res["stamp_ok"]
            row[mode] = res
        row["read_p95_ratio"] = (row["barrier"]["read_p95_ms"]
                                 / max(row["mvcc"]["read_p95_ms"], 1e-9))
        ratios.append(row["read_p95_ratio"])
        mixes[name] = row

    return {
        "backend": "vmap",
        "n": n, "m": m, "k": k, "batch_size": batch_size,
        "n_events": n_events, "edges_per_delta": edges_per_delta,
        "offered_qps": offered_qps,
        "answers_ok": bool(answers_ok),
        "read_p95_ratio_min": min(ratios),
        "mixes": mixes,
    }
