"""Paper-experiment benchmarks (Section 7): one function per table/figure.

The paper ran on EC2; we run single-host CPU, so absolute times differ —
what must reproduce are the *relations* its tables/figures show:
  Table 2:  disReach beats disReach_n and disReach_m on time; traffic(dis)
            << traffic(n); disReach visits each site once, _m many times.
  Fig 11a:  more fragments -> disReach faster, disReach_m slower.
  Fig 11b:  disReach scales mildly with size(F).
  Exp 2:    disDist mirrors disReach.
  Exp 3:    disRPQ beats centralized; time grows with |V_q|.
  Exp 4:    MRdRPQ works but pays the single-reducer/map-shipping penalty.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import (GraphDelta, apply_delta, build_query_automaton,
                        dis_dist, dis_reach, dis_rpq, fragment_graph,
                        prepare_rvset_cache)
# the PR-2/PR-3 experiments time the batched engine itself, not the
# deprecated free-function shims layered on top of it
from repro.core.cache import dis_dist_batch, dis_reach_batch, rpq_cached
from repro.core.baselines import dis_reach_m, dis_reach_n
from repro.core.mapreduce import mr_drpq
from repro.graph import bfs_partition, erdos_renyi, random_partition
from repro.graph.graph import bfs_reachable


def _timed(fn: Callable, repeat: int = 3) -> float:
    fn()                                   # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return (time.perf_counter() - t0) / repeat * 1e6     # us


def _queries(g, n_q: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [(int(rng.integers(g.n)), int(rng.integers(g.n)))
            for _ in range(n_q)]


def table2_reachability(n: int = 3000, m: int = 12000, k: int = 4,
                        n_q: int = 5) -> List[Dict]:
    """disReach vs disReach_n vs disReach_m: time + traffic + visits."""
    g = erdos_renyi(n, m, n_labels=8, seed=0)
    fr = fragment_graph(g, random_partition(g, k, 0), k)
    qs = [q for q in _queries(g, n_q) if q[0] != q[1]]
    rows = []
    for name, fn, traffic, visits in [
        ("disReach", lambda s, t: dis_reach(fr, s, t),
         lambda r: r.stats.payload_bits, lambda r: fr.k),
        ("disReach_n", lambda s, t: dis_reach_n(fr, s, t),
         lambda r: r.traffic_bits, lambda r: r.site_visits),
        ("disReach_m", lambda s, t: dis_reach_m(fr, s, t),
         lambda r: r.traffic_bits, lambda r: r.site_visits),
    ]:
        us = np.mean([_timed(lambda: fn(s, t), repeat=1) for s, t in qs])
        r = fn(*qs[0])
        rows.append(dict(algo=name, us_per_query=us,
                         traffic_bits=traffic(r), site_visits=visits(r)))
    return rows


def fig11a_vary_fragments(n: int = 4000, m: int = 16000,
                          ks=(2, 4, 8, 16)) -> List[Dict]:
    g = erdos_renyi(n, m, n_labels=8, seed=1)
    s, t = 1, n - 2
    rows = []
    for k in ks:
        fr = fragment_graph(g, random_partition(g, k, 1), k)
        rows.append(dict(
            card_f=k,
            disReach_us=_timed(lambda: dis_reach(fr, s, t), 2),
            disReach_m_us=_timed(lambda: dis_reach_m(fr, s, t), 2),
            disReach_m_rounds=dis_reach_m(fr, s, t).rounds,
        ))
    return rows


def fig11b_vary_size(sizes=(1000, 2000, 4000, 8000), k: int = 8) -> List[Dict]:
    rows = []
    for n in sizes:
        g = erdos_renyi(n, 4 * n, n_labels=8, seed=2)
        fr = fragment_graph(g, random_partition(g, k, 2), k)
        rows.append(dict(n=n, size_f=fr.largest_fragment(),
                         disReach_us=_timed(lambda: dis_reach(fr, 0, n - 1),
                                            2)))
    return rows


def exp2_bounded(n: int = 3000, m: int = 12000, ks=(2, 4, 8),
                 bound: int = 10) -> List[Dict]:
    g = erdos_renyi(n, m, n_labels=8, seed=3)
    rows = []
    for k in ks:
        fr = fragment_graph(g, random_partition(g, k, 3), k)
        rows.append(dict(card_f=k,
                         disDist_us=_timed(
                             lambda: dis_dist(fr, 0, n - 1, bound), 2)))
    return rows


def exp3_regular(n: int = 800, m: int = 3200, k: int = 4) -> List[Dict]:
    """disRPQ vs centralized (k=1 == ship-all) + query-complexity sweep."""
    g = erdos_renyi(n, m, n_labels=8, seed=4)
    fr = fragment_graph(g, random_partition(g, k, 4), k)
    fr1 = fragment_graph(g, np.zeros(g.n, np.int32), 1)   # centralized
    regexes = {            # growing |V_q|
        4: "0* 1*",
        6: "0* 1* 2*",
        8: "(0|1)* 2* 3*",
        10: "(0|1|2)* (3|4)* 5",
    }
    rows = []
    for vq, rx in regexes.items():
        qa = build_query_automaton(rx, lambda x: int(x))
        rows.append(dict(
            v_q=qa.n_states,
            disRPQ_us=_timed(lambda: dis_rpq(fr, 0, n - 1, qa), 1),
            disRPQ_n_us=_timed(lambda: dis_rpq(fr1, 0, n - 1, qa), 1),
            payload_bits=dis_rpq(fr, 0, n - 1, qa).stats.payload_bits,
        ))
    return rows


def _aligned_partition(g, k: int, max_seed: int = 256):
    """Partition whose boundary side |V_f|+2 is a multiple of 32, so the
    bitpacked payload carries zero word-alignment slack (exactly 8x fewer
    bits than the seed's uint8 shipping).  1/32 of random partitions
    qualify; scan seeds until one does (falls back to seed 0)."""
    part = random_partition(g, k, 0)
    for seed in range(max_seed):
        cand = random_partition(g, k, seed)
        cross = cand[g.src] != cand[g.dst]
        nb = np.unique(g.dst[cross]).size
        if (nb + 2) % 32 == 0:
            return cand
    return part


def exp_amortized(n: int = 3000, m: int = 12000, k: int = 4,
                  n_q: int = 64, n_cold: int = 5) -> Dict:
    """Beyond-paper experiment (ISSUE 2): cold single-query latency vs
    warm-cache batched throughput against the same fragmentation, plus the
    bitpacked collective payload accounting.

    cold  = seed engine, full localEval + evalDG per query;
    warm  = amortized rvset cache (built once) + dis_reach_batch: N vmapped
            single-source propagations + one or-and matmul per batch.
    """
    g = erdos_renyi(n, m, n_labels=8, seed=0)
    part = _aligned_partition(g, k)
    fr = fragment_graph(g, part, k)
    B, words = fr.B, (fr.B + 31) // 32
    pairs = [q for q in _queries(g, n_q) if q[0] != q[1]]

    # cold: seed single-query path (compiled once, then timed per query)
    dis_reach(fr, *pairs[0])                       # warmup / compile
    t0 = time.perf_counter()
    for p in pairs[:n_cold]:
        dis_reach(fr, *p)
    cold_us = (time.perf_counter() - t0) / n_cold * 1e6

    # cache build (once per fragmentation; amortized across all queries)
    t0 = time.perf_counter()
    prepare_rvset_cache(fr)
    build_ms = (time.perf_counter() - t0) * 1e3

    # warm: batched queries against the cache
    dis_reach_batch(fr, pairs)                     # warmup / compile
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        dis_reach_batch(fr, pairs)
    warm_us = (time.perf_counter() - t0) / reps / len(pairs) * 1e6

    unpacked_bits = 8 * B * B                      # seed ships uint8 B x B
    packed_bits = B * words * 32
    return dict(
        n=n, m=m, k=k, boundary=B, n_queries=len(pairs),
        cold_single_query_us=cold_us,
        cache_build_ms=build_ms,
        warm_batched_per_query_us=warm_us,
        speedup=cold_us / warm_us,
        warm_queries_per_sec=1e6 / warm_us,
        payload_unpacked_bits=unpacked_bits,
        payload_packed_bits=packed_bits,
        payload_shrink_factor=unpacked_bits / packed_bits,
    )


def exp_incremental(n: int = 3000, m: int = 12000, k: int = 4,
                    n_deltas: int = 12, edges_per_delta: int = 8,
                    n_q: int = 64) -> Dict:
    """Beyond-paper experiment (ISSUE 3): dynamic-graph workload at the
    Table-2 config — incremental cache repair vs full ``build_cache``
    rebuild on single-fragment intra-edge insertion deltas, plus the warm
    per-query cost before/after the delta stream (the 100x+ amortized-cache
    speedup must survive graph churn).
    """
    rng = np.random.default_rng(0)
    g = erdos_renyi(n, m, n_labels=8, seed=0)
    part = random_partition(g, k, 0)
    budget = (n_deltas + k + 2) * edges_per_delta
    fr = fragment_graph(g, part, k, reserve_boundary=64,
                        reserve_edges=budget, reserve_stubs=64)

    def intra_delta(f: int) -> GraphDelta:
        mine = np.nonzero(part == f)[0]
        return GraphDelta.insert(
            [(int(rng.choice(mine)), int(rng.choice(mine)))
             for _ in range(edges_per_delta)])

    # cold cache build, then the full-rebuild baseline (same compiled progs)
    t0 = time.perf_counter()
    prepare_rvset_cache(fr)
    build_ms = (time.perf_counter() - t0) * 1e3
    rebuild_ms = []
    for _ in range(3):
        fr.rvset_cache = None
        t0 = time.perf_counter()
        prepare_rvset_cache(fr)
        rebuild_ms.append((time.perf_counter() - t0) * 1e3)
    rebuild_med = float(np.median(rebuild_ms))

    pairs = [q for q in _queries(g, n_q, seed=1) if q[0] != q[1]]
    dis_reach_batch(fr, pairs)                     # warmup / compile
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        dis_reach_batch(fr, pairs)
    warm_before_us = (time.perf_counter() - t0) / reps / len(pairs) * 1e6

    # one warmup delta per fragment compiles every repair-shape bucket
    for f in range(k):
        stats = apply_delta(fr, intra_delta(f))
        assert stats.mode == "repair", stats
    repair_ms = []
    for d in range(n_deltas):
        delta = intra_delta(d % k)
        t0 = time.perf_counter()
        stats = apply_delta(fr, delta)
        repair_ms.append((time.perf_counter() - t0) * 1e3)
        assert stats.mode == "repair", stats
    repair_med = float(np.median(repair_ms))

    # deletion latency (per-fragment recompute path), reported not gated
    e = int(rng.integers(fr.g.m))
    del_delta = GraphDelta.delete([(int(fr.g.src[e]), int(fr.g.dst[e]))])
    t0 = time.perf_counter()
    del_stats = apply_delta(fr, del_delta)
    delete_ms = (time.perf_counter() - t0) * 1e3

    dis_reach_batch(fr, pairs)                     # recompile after deltas
    t0 = time.perf_counter()
    for _ in range(reps):
        dis_reach_batch(fr, pairs)
    warm_after_us = (time.perf_counter() - t0) / reps / len(pairs) * 1e6

    # the repaired cache still answers correctly (spot check vs host BFS)
    for s, t in pairs[:8]:
        assert bool(dis_reach_batch(fr, [(s, t)])[0]) == \
            bool(bfs_reachable(fr.g, s)[t]), (s, t)

    return dict(
        n=n, m=m, k=k, boundary=fr.B, n_deltas=n_deltas,
        edges_per_delta=edges_per_delta,
        cache_build_ms=build_ms,
        full_rebuild_ms_median=rebuild_med,
        repair_ms_median=repair_med,
        repair_speedup_median=rebuild_med / repair_med,
        delete_recompute_ms=delete_ms,
        delete_mode=del_stats.mode,
        warm_before_delta_us=warm_before_us,
        warm_after_delta_us=warm_after_us,
    )


def exp_session(n: int = 900, m: int = 3600, k: int = 4,
                n_q: int = 96) -> Dict:
    """Beyond-paper experiment (ISSUE 4): mixed reach+dist+RPQ batches
    through ONE ``session.run`` vs the status-quo per-kind serving loop
    (batched reach/dist + one ``rpq_cached`` call per RPQ — the pre-session
    engine had no RPQ batching at all).

    Locality-aware partition (the paper notes |V_f| is small in practice);
    the RPQ product closures scale with (|V_f| |Q|)^2, so this is the
    realistic regime for regular-query serving.
    """
    import repro
    from repro.core import Dist, Reach, Rpq

    g = erdos_renyi(n, m, n_labels=8, seed=0)
    fr = fragment_graph(g, bfs_partition(g, k, seed=1), k)
    automata = [build_query_automaton(rx, lambda x: int(x))
                for rx in ("(0|1)* 2", "0* 1*")]
    rng = np.random.default_rng(0)
    queries = []
    for i in range(n_q):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        kind = i % 3
        if kind == 0:
            queries.append(Reach(s, t))
        elif kind == 1:
            queries.append(Dist(s, t, bound=None if i % 2 else 10))
        else:
            queries.append(Rpq(s, t, automaton=automata[i % 2]))

    session = repro.connect(fr, backend="vmap")
    t0 = time.perf_counter()
    session.run(queries)         # builds every cache + compiles every group
    build_ms = (time.perf_counter() - t0) * 1e3

    reps = 3
    t0 = time.perf_counter()
    for _ in range(reps):
        session.run(queries)
    mixed_us = (time.perf_counter() - t0) / reps / n_q * 1e6
    n_groups = session.last_plan.n_groups

    # status-quo baseline: per-kind loops against the same warm caches
    reach_pairs = np.array([(q.s, q.t) for q in queries
                            if isinstance(q, Reach)], np.int64)
    dist_pairs = np.array([(q.s, q.t) for q in queries
                           if isinstance(q, Dist)], np.int64)
    rpq_queries = [q for q in queries if isinstance(q, Rpq)]

    def per_kind():
        dis_reach_batch(fr, reach_pairs)
        dis_dist_batch(fr, dist_pairs)
        for q in rpq_queries:                # RPQs had no batched path
            rpq_cached(fr, q.s, q.t, q.automaton)

    per_kind()                               # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        per_kind()
    per_kind_us = (time.perf_counter() - t0) / reps / n_q * 1e6

    # sanity: fused == per-kind loop answers on the RPQ slice
    fused = session.run(rpq_queries)
    for q, r in zip(rpq_queries, fused):
        assert r.answer == rpq_cached(fr, q.s, q.t, q.automaton), (q.s, q.t)

    return dict(
        n=n, m=m, k=k, boundary=fr.B, n_queries=n_q,
        n_groups=n_groups,
        cache_build_and_compile_ms=build_ms,
        mixed_per_query_us=mixed_us,
        per_kind_loop_per_query_us=per_kind_us,
        fused_speedup=per_kind_us / mixed_us,
        mixed_queries_per_sec=1e6 / mixed_us,
    )


_SHARDED_MIXED_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(k)d"
import json, sys, time
sys.path.insert(0, %(src)r)
import numpy as np
import repro
from repro.core import Dist, Reach, Rpq, build_query_automaton, fragment_graph
from repro.graph.graph import Graph

# locality workload (the paper notes |V_f| is small in practice): blocks of
# n/k nodes, 92%% intra-block edges, partitioned along the blocks -> small
# boundary, which is the regime where the (|V_f| |Q|)^2 closures stay cheap
n, m, k, n_q = %(n)d, %(m)d, %(k)d, %(n_q)d
rng = np.random.default_rng(0)
per = n // k
src, dst = [], []
for _ in range(m):
    if rng.random() < 0.92:
        b = int(rng.integers(k))
        src.append(b * per + int(rng.integers(per)))
        dst.append(b * per + int(rng.integers(per)))
    else:
        src.append(int(rng.integers(n)))
        dst.append(int(rng.integers(n)))
g = Graph(n, np.array(src), np.array(dst),
          rng.integers(0, 8, n).astype(np.int32))
fr = fragment_graph(g, (np.arange(n) // per).astype(np.int32), k)
automaton = build_query_automaton("(0|1)* 2", lambda x: int(x))
rng = np.random.default_rng(0)
queries = []
for i in range(n_q):
    s, t = int(rng.integers(n)), int(rng.integers(n))
    kind = i %% 3
    if kind == 0:
        queries.append(Reach(s, t))
    elif kind == 1:
        queries.append(Dist(s, t, bound=None if i %% 2 else 10))
    else:
        queries.append(Rpq(s, t, automaton=automaton))

def bench(backend):
    sess = repro.connect(fr, backend=backend)
    t0 = time.perf_counter()
    res = sess.run(queries)              # builds caches + compiles groups
    build_ms = (time.perf_counter() - t0) * 1e3
    reps = 3
    t0 = time.perf_counter()
    for _ in range(reps):
        res = sess.run(queries)
    us = (time.perf_counter() - t0) / reps / n_q * 1e6
    return sess, res, build_ms, us

sess_v, res_v, build_v, us_v = bench("vmap")
sess_s, res_s, build_s, us_s = bench("shard_map")
match = all((a.answer, a.distance) == (b.answer, b.distance)
            for a, b in zip(res_v, res_s))

# per-kind wire bits of the fused collectives + the sum-equals-wire check
payload = {}
bits_ok = True
for grp in sess_s.last_plan.groups:
    states = 1 if grp.automaton is None else grp.automaton.n_states
    total = fr.traffic_bits(grp.kind, states=states, batch=grp.padded_size)
    payload[grp.kind] = payload.get(grp.kind, 0) + total
    bits_ok &= sum(res_s[i].stats.payload_bits
                   for i in grp.indices) == total
    bits_ok &= sum(res_s[i].stats.collective_rounds
                   for i in grp.indices) == 1

print(json.dumps(dict(
    backend_checked=sess_s.backend, n=n, m=m, k=k, boundary=fr.B,
    n_queries=n_q, n_groups=sess_s.last_plan.n_groups,
    vmap_build_ms=build_v, shard_map_build_ms=build_s,
    vmap_per_query_us=us_v, shard_map_per_query_us=us_s,
    payload_bits_per_kind=payload, answers_match=bool(match),
    payload_bits_ok=bool(bits_ok))))
"""


def exp_sharded_mixed(n: int = 400, m: int = 1600, k: int = 8,
                      n_q: int = 48) -> Dict:
    """Beyond-paper experiment (ISSUE 5): mixed reach+dist+RPQ batch
    throughput on the vmap vs shard_map backends, now that every kind
    keeps the one-collective-per-fused-group guarantee, plus the per-kind
    wire bits of those collectives.  Runs in a subprocess with ``k`` fake
    host devices so the one-fragment-per-device engine actually shards
    (the timing compares the same workload on both backends on the same
    hardware; on real accelerators the sharded localEval runs in
    parallel instead of timeslicing one CPU)."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    code = _SHARDED_MIXED_SUBPROC % dict(src=src, n=n, m=m, k=k, n_q=n_q)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError("exp_sharded_mixed subprocess failed:\n"
                           + out.stderr[-2000:])
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["backend_checked"] == "shard_map", res
    assert res["answers_match"], "vmap and shard_map answers diverged"
    assert res["payload_bits_ok"], "group stats != one-collective wire size"
    return res


_SCALEOUT_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(d)d"
import json, sys, time
sys.path.insert(0, %(src)r)
import numpy as np
import repro
from repro.core import Dist, Reach, Rpq, build_query_automaton, fragment_graph
from repro.graph.graph import Graph

d, n, m, n_q = %(d)d, %(n)d, %(m)d, %(n_q)d
ks = %(ks)r
rows = []
for k in ks:
    # same locality workload as the sharded-mixed benchmark, refragmented
    # at each k: the graph is cut for locality, the mesh stays at d devices
    rng = np.random.default_rng(k)
    per = n // k
    src, dst = [], []
    for _ in range(m):
        if rng.random() < 0.92:
            b = int(rng.integers(k))
            src.append(b * per + int(rng.integers(per)))
            dst.append(b * per + int(rng.integers(per)))
        else:
            src.append(int(rng.integers(n)))
            dst.append(int(rng.integers(n)))
    g = Graph(n, np.array(src), np.array(dst),
              rng.integers(0, 8, n).astype(np.int32))
    part = np.minimum(np.arange(n) // per, k - 1).astype(np.int32)
    fr = fragment_graph(g, part, k)
    qa = build_query_automaton("(0|1)* 2", lambda x: int(x))
    queries = []
    for i in range(n_q):
        s, t = int(rng.integers(n)), int(rng.integers(n))
        queries.append([Reach(s, t), Dist(s, t),
                        Rpq(s, t, automaton=qa)][i %% 3])

    res_v = repro.connect(fr, backend="vmap").run(queries)
    sess = repro.connect(fr)            # auto -> shard_map, k packed on d
    res = sess.run(queries)             # builds caches + compiles groups
    reps = 3
    t0 = time.perf_counter()
    for _ in range(reps):
        res = sess.run(queries)
    us = (time.perf_counter() - t0) / reps / n_q * 1e6

    match = all((a.answer, a.distance) == (b.answer, b.distance)
                for a, b in zip(res_v, res))
    wire = {}
    bits_ok = True
    for grp in sess.last_plan.groups:
        states = 1 if grp.automaton is None else grp.automaton.n_states
        total = fr.traffic_bits(grp.kind, states=states,
                                batch=grp.padded_size)
        wire[grp.kind] = wire.get(grp.kind, 0) + total
        bits_ok &= sum(res[i].stats.payload_bits
                       for i in grp.indices) == total
        bits_ok &= sum(res[i].stats.collective_rounds
                       for i in grp.indices) == 1
    rows.append(dict(k=k, fragments_per_device=sess.placement.fpd,
                     boundary=fr.n_boundary, backend=sess.backend,
                     per_query_us=us, queries_per_sec=1e6 / us,
                     wire_bits_per_kind=wire,
                     wire_bits_total=sum(wire.values()),
                     answers_match=bool(match),
                     payload_bits_ok=bool(bits_ok)))
print(json.dumps(dict(d=d, n=n, m=m, n_queries=n_q, rows=rows)))
"""


def exp_scaleout(n: int = 400, m: int = 1600, d: int = 8,
                 ks=(8, 16, 32), n_q: int = 48) -> Dict:
    """Beyond-paper experiment (ISSUE 6): k >> d scale-out — the mesh
    stays at ``d`` fake devices while the graph is refragmented at
    growing ``k``, so fragments-per-device goes 1, 2, 4, ...  Reports
    mixed-batch queries/sec and the per-kind wire bits of the fused
    collectives at each packing factor, and asserts at every k that
    shard_map answers == vmap answers and that summed per-group
    ``QueryStats`` equal each group's one-collective wire (packing adds
    zero traffic)."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    code = _SCALEOUT_SUBPROC % dict(src=src, d=d, n=n, m=m,
                                    ks=tuple(ks), n_q=n_q)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError("exp_scaleout subprocess failed:\n"
                           + out.stderr[-2000:])
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for row in res["rows"]:
        assert row["backend"] == "shard_map", row
        assert row["fragments_per_device"] == -(-row["k"] // d), row
        assert row["answers_match"], f"k={row['k']}: answers diverged"
        assert row["payload_bits_ok"], \
            f"k={row['k']}: group stats != one-collective wire size"
    return res


_CHAOS_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(k)d"
import json, sys, time
sys.path.insert(0, %(src)r)
sys.path.insert(0, %(tests)r)
import numpy as np
from repro.core import GraphDelta, build_query_automaton, fragment_graph
from repro.graph import erdos_renyi, random_partition
from repro.graph.graph import Graph
from repro.serve import (FaultInjector, QueryServer, RetryPolicy,
                         UpdateRequest)
from oracles import oracle_dist, oracle_reach, oracle_rpq

n, m, k, rounds, per_round = %(n)d, %(m)d, %(k)d, %(rounds)d, %(per_round)d
g = erdos_renyi(n, m, n_labels=3, seed=7)
fr = fragment_graph(g, random_partition(g, k, 1), k,
                    reserve_boundary=24, reserve_edges=96, reserve_stubs=24)
# the acceptance schedule: every injection site at a seeded 1%% fault rate
chaos = FaultInjector(seed=9, rates={"engine.shard_map": 0.01,
                                     "engine.vmap": 0.01,
                                     "upload": 0.01,
                                     "delta.repair": 0.01})
# start=False: the deferred flush() reproduces the PR-7 drain execution
# order exactly, keeping the seeded per-site chaos draw sequences stable
srv = QueryServer(fr, batch_size=16, chaos=chaos, start=False,
                  retry=RetryPolicy(max_attempts=3, base_delay_ms=0.0))
qa = build_query_automaton("(0|1)*", lambda x: int(x))
rng = np.random.default_rng(1)

def submit_mixed(i):
    s, t = int(rng.integers(n)), int(rng.integers(n))
    kind = i %% 3
    if kind == 0:
        return srv.submit(s, t)
    if kind == 1:
        return srv.submit(s, t, kind="dist")
    return srv.submit(s, t, kind="rpq", automaton=qa)

# warm-up round: cache build + batched-program compiles stay out of the
# latency distribution (steady-state serving is what the p95 bounds)
for i in range(per_round):
    submit_mixed(i)
srv.flush()

submitted, lat_us = [], []
for _ in range(rounds):
    # delta first: flush() applies queued updates before the queries that
    # follow them, so the round's queries answer the post-delta graph
    edge = [(int(rng.integers(n)), int(rng.integers(n)))]
    batch = [srv.submit_delta(GraphDelta.insert(edge))]
    batch += [submit_mixed(i) for i in range(per_round)]
    t0 = time.perf_counter()
    srv.flush()
    lat_us.append((time.perf_counter() - t0) / per_round * 1e6)
    submitted.extend(batch)

# replay oracle: updates mutate the reference graph in submission order
# exactly when the server reported them applied (rollbacks leave it alone)
cur = g
answers_ok = True
n_queries = n_done = 0
for r in submitted:
    if isinstance(r, UpdateRequest):
        if r.status == "applied":
            cur = Graph(cur.n, np.concatenate([cur.src, r.delta.add_src]),
                        np.concatenate([cur.dst, r.delta.add_dst]),
                        cur.labels, cur.label_names)
        continue
    n_queries += 1
    if r.status != "done":
        continue
    n_done += 1
    if r.kind == "reach":
        want = oracle_reach(cur, r.s, r.t)
    elif r.kind == "dist":
        want = oracle_dist(cur, r.s, r.t)
    else:
        want = oracle_rpq(cur, r.s, r.t, qa)
    answers_ok = answers_ok and (r.value == want)

lat = sorted(lat_us)
pct = lambda p: lat[min(len(lat) - 1, int(p * len(lat)))]
print(json.dumps(dict(
    backend=srv.session.backend, n=n, m=m, k=k,
    n_queries=n_queries, n_done=n_done,
    success_rate=n_done / n_queries,
    answers_ok=bool(answers_ok),
    p50_per_query_us=pct(0.50),
    p95_per_query_us=pct(0.95),
    dead_letters=len(srv.dead_letters),
    retries=srv.retries,
    rollbacks=srv.session.stats.rollbacks,
    degraded_groups=srv.session.stats.degraded_groups,
    updates_applied=srv.updates_applied,
    updates_failed=srv.updates_failed,
    injected={site: cnt for site, cnt in chaos.failures.items() if cnt},
)))
"""


def exp_chaos(n: int = 48, m: int = 128, k: int = 8, rounds: int = 12,
              per_round: int = 15) -> Dict:
    """Beyond-paper experiment (ISSUE 7): serving under a seeded 1% fault
    schedule on all four injection sites.  A mixed reach+dist+RPQ workload
    with one graph delta per round runs against the 8-fake-device sharded
    backend; reports steady-state p50/p95 per-query latency (per-round
    drain time over the round's queries), the request success rate, and
    the retry/rollback/degraded counters — and replays every applied
    delta through a host oracle to assert all answered results are exact
    despite the injected failures."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    tests = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                         "tests"))
    code = _CHAOS_SUBPROC % dict(src=src, tests=tests, n=n, m=m, k=k,
                                 rounds=rounds, per_round=per_round)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError("exp_chaos subprocess failed:\n"
                           + out.stderr[-2000:])
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["backend"] == "shard_map", res
    assert res["answers_ok"], "answered results diverged from the oracle"
    return res


def exp4_mapreduce(n: int = 800, m: int = 3200, k: int = 4) -> List[Dict]:
    g = erdos_renyi(n, m, n_labels=8, seed=5)
    fr = fragment_graph(g, random_partition(g, k, 5), k)
    qa = build_query_automaton("(0|1)* 2", lambda x: int(x))
    res = mr_drpq(fr, 0, n - 1, qa)
    return [dict(
        MRdRPQ_us=_timed(lambda: mr_drpq(fr, 0, n - 1, qa), 1),
        disRPQ_us=_timed(lambda: dis_rpq(fr, 0, n - 1, qa), 1),
        ecc_bits=res.ecc_bits,
        reducer_input_bits=res.reducer_input_bits,
    )]
