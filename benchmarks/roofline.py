"""Roofline analysis over the dry-run records (deliverable (g)).

Reads results/dryrun.json (written by repro.launch.dryrun) and derives the
three roofline terms per (arch x shape x mesh):

  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / ICI_bw

cost_analysis() of the partitioned module is per-device, so no further
/chips is needed.  HLO_FLOPs/bytes use the loop-free cost probes (XLA
counts loop bodies once; see launch/dryrun.probe_costs).  Hardware: TPU
v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (we charge
the conservative single-link figure; a v5e 2D torus has more).
"""
from __future__ import annotations

import json
import sys

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link (conservative single-link)


def analyze(rec: dict) -> dict:
    n = rec["n_devices"]
    t_compute = rec["probe_flops"] / PEAK_FLOPS
    t_memory = rec["probe_bytes"] / HBM_BW
    t_coll = rec["probe_collective_bytes"] / ICI_BW
    terms = dict(compute=t_compute, memory=t_memory, collective=t_coll)
    dominant = max(terms, key=terms.get)
    t_ideal = rec["model_flops"] / (n * PEAK_FLOPS)
    t_bound = max(terms.values())
    frac = t_ideal / t_bound if t_bound > 0 else float("nan")
    useful = rec["model_flops"] / max(rec["probe_flops"] * n, 1.0)
    return dict(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
                dominant=dominant, t_ideal=t_ideal,
                roofline_fraction=frac, useful_flops_ratio=useful,
                peak_gib=rec["peak_bytes_per_dev"] / 2**30)


def main(path: str = "results/dryrun.json", mesh: str = "16x16"):
    recs = [r for r in json.load(open(path))
            if r.get("status") == "ok" and r["mesh"] == mesh]
    rows = [analyze(r) for r in recs]
    rows.sort(key=lambda r: r["roofline_fraction"])
    hdr = ("arch", "shape", "compute_s", "memory_s", "collective_s",
           "dominant", "ideal_s", "roofline_frac", "useful_ratio", "GiB/dev")
    print("| " + " | ".join(hdr) + " |")
    print("|" + "---|" * len(hdr))
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['t_compute']:.2e} | "
              f"{r['t_memory']:.2e} | {r['t_collective']:.2e} | "
              f"{r['dominant']} | {r['t_ideal']:.2e} | "
              f"{r['roofline_fraction']:.3f} | "
              f"{r['useful_flops_ratio']:.3f} | {r['peak_gib']:.1f} |")
    print()
    worst = rows[0] if rows else None
    coll_bound = [r for r in rows if r["dominant"] == "collective"]
    if worst:
        print(f"worst roofline fraction: {worst['arch']} x {worst['shape']}"
              f" ({worst['roofline_fraction']:.3f}, {worst['dominant']}-bound)")
    if coll_bound:
        c = min(coll_bound, key=lambda r: r["roofline_fraction"])
        print(f"most collective-bound: {c['arch']} x {c['shape']}"
              f" ({c['t_collective']:.2e}s collective)")
    return rows


if __name__ == "__main__":
    main(*sys.argv[1:])
