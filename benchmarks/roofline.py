"""Roofline analysis: dry-run records (deliverable (g)) + live kernels.

Two entry points:

* :func:`main` reads results/dryrun.json (written by repro.launch.dryrun)
  and derives the three roofline terms per (arch x shape x mesh);
* :func:`kernel_report` (PR-9) times the three semiring matmul kernels
  that dominate the amortized-cache path **live** — no dryrun.json
  needed — and reports achieved vs peak FLOP/s and bytes/s per kernel.
  ``benchmarks.run`` folds the result into ``BENCH_pr9*.json``
  (report-only: on the CPU CI runner the fractions of the TPU peaks are
  tiny by construction; the point is the trajectory and the arithmetic-
  intensity/ridge classification, which is hardware-independent).

Dry-run terms per record:

  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / ICI_bw

cost_analysis() of the partitioned module is per-device, so no further
/chips is needed.  HLO_FLOPs/bytes use the loop-free cost probes (XLA
counts loop bodies once; see launch/dryrun.probe_costs).  Hardware: TPU
v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (we charge
the conservative single-link figure; a v5e 2D torus has more).
"""
from __future__ import annotations

import json
import sys

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link (conservative single-link)
RIDGE = PEAK_FLOPS / HBM_BW   # FLOP/byte where compute overtakes memory


def _time_best(fn, repeats: int) -> float:
    """Best-of-``repeats`` wall time of a blocked jax call (post-compile)."""
    import time as _time

    import jax
    jax.block_until_ready(fn())          # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = _time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, _time.perf_counter() - t0)
    return best


def kernel_report(side: int = 256, batch: int = 64,
                  repeats: int = 10, seed: int = 0) -> dict:
    """Live achieved-vs-peak roofline for the semiring matmul kernels.

    Times the three kernels the amortized-cache query path is built from
    (``or_and_matmul``: the per-batch combine; ``min_plus_matmul``: its
    tropical twin; ``bool_closure``: the repeated-squaring closure build)
    on synthetic ``[batch, side] x [side, side]`` / ``[side, side]``
    operands.  FLOPs/bytes are the analytic model of each kernel (two ops
    per multiply-add; operands + result streamed once per matmul, the
    closure doing ceil(log2 side) squarings), so the achieved numbers are
    *model* FLOP/s — exactly the quantity the roofline bounds.
    """
    import math

    import jax.numpy as jnp
    import numpy as np

    from repro.core import bes
    from repro.kernels.bool_matmul.ops import or_and_matmul
    from repro.kernels.tropical_matmul.ops import min_plus_matmul

    rng = np.random.default_rng(seed)
    a_b = jnp.asarray(rng.random((batch, side)) < 0.05)
    c_b = jnp.asarray(rng.random((side, side)) < 0.05)
    a_t = jnp.asarray(rng.integers(0, 100, (batch, side)), jnp.int32)
    c_t = jnp.asarray(rng.integers(0, 100, (side, side)), jnp.int32)
    d0 = jnp.asarray(rng.random((side, side)) < (2.0 / side))
    squarings = max(1, math.ceil(math.log2(side)))

    kernels = {
        "or_and_matmul": dict(
            fn=lambda: or_and_matmul(a_b, c_b),
            flops=2.0 * batch * side * side,
            bytes=float(batch * side + side * side + batch * side)),
        "min_plus_matmul": dict(
            fn=lambda: min_plus_matmul(a_t, c_t),
            flops=2.0 * batch * side * side,
            bytes=4.0 * (batch * side + side * side + batch * side)),
        "bool_closure": dict(
            fn=lambda: bes.bool_closure(d0),
            flops=squarings * 2.0 * side ** 3,
            bytes=squarings * 3.0 * float(side * side)),
    }
    rows = {}
    for name, spec in kernels.items():
        t = _time_best(spec["fn"], repeats)
        flops, nbytes = spec["flops"], spec["bytes"]
        intensity = flops / nbytes
        rows[name] = dict(
            time_s=t,
            model_flops=flops, model_bytes=nbytes,
            achieved_flops_per_s=flops / t,
            achieved_bytes_per_s=nbytes / t,
            frac_peak_flops=flops / t / PEAK_FLOPS,
            frac_peak_bw=nbytes / t / HBM_BW,
            arithmetic_intensity=intensity,
            bound="compute" if intensity > RIDGE else "memory")
    return dict(side=side, batch=batch, repeats=repeats,
                peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW,
                ridge_flops_per_byte=RIDGE, kernels=rows)


def analyze(rec: dict) -> dict:
    n = rec["n_devices"]
    t_compute = rec["probe_flops"] / PEAK_FLOPS
    t_memory = rec["probe_bytes"] / HBM_BW
    t_coll = rec["probe_collective_bytes"] / ICI_BW
    terms = dict(compute=t_compute, memory=t_memory, collective=t_coll)
    dominant = max(terms, key=terms.get)
    t_ideal = rec["model_flops"] / (n * PEAK_FLOPS)
    t_bound = max(terms.values())
    frac = t_ideal / t_bound if t_bound > 0 else float("nan")
    useful = rec["model_flops"] / max(rec["probe_flops"] * n, 1.0)
    return dict(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
                dominant=dominant, t_ideal=t_ideal,
                roofline_fraction=frac, useful_flops_ratio=useful,
                peak_gib=rec["peak_bytes_per_dev"] / 2**30)


def main(path: str = "results/dryrun.json", mesh: str = "16x16"):
    recs = [r for r in json.load(open(path))
            if r.get("status") == "ok" and r["mesh"] == mesh]
    rows = [analyze(r) for r in recs]
    rows.sort(key=lambda r: r["roofline_fraction"])
    hdr = ("arch", "shape", "compute_s", "memory_s", "collective_s",
           "dominant", "ideal_s", "roofline_frac", "useful_ratio", "GiB/dev")
    print("| " + " | ".join(hdr) + " |")
    print("|" + "---|" * len(hdr))
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['t_compute']:.2e} | "
              f"{r['t_memory']:.2e} | {r['t_collective']:.2e} | "
              f"{r['dominant']} | {r['t_ideal']:.2e} | "
              f"{r['roofline_fraction']:.3f} | "
              f"{r['useful_flops_ratio']:.3f} | {r['peak_gib']:.1f} |")
    print()
    worst = rows[0] if rows else None
    coll_bound = [r for r in rows if r["dominant"] == "collective"]
    if worst:
        print(f"worst roofline fraction: {worst['arch']} x {worst['shape']}"
              f" ({worst['roofline_fraction']:.3f}, {worst['dominant']}-bound)")
    if coll_bound:
        c = min(coll_bound, key=lambda r: r["roofline_fraction"])
        print(f"most collective-bound: {c['arch']} x {c['shape']}"
              f" ({c['t_collective']:.2e}s collective)")
    return rows


if __name__ == "__main__":
    main(*sys.argv[1:])
