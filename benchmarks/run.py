"""Benchmark entry point: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (plus section headers) and
emits BENCH_pr2.json with the amortized-cache before/after numbers."""
from __future__ import annotations

import json
import sys

from . import paper_experiments as pe


def _emit(section: str, rows):
    for row in rows:
        us = next((v for k, v in row.items() if k.endswith("_us")
                   or k == "us_per_query"), 0.0)
        derived = ";".join(f"{k}={v}" for k, v in row.items()
                           if not (k.endswith("_us") or k == "us_per_query"))
        name = row.get("algo") or section
        print(f"{section}/{name},{us:.1f},{derived}")


def main() -> None:
    fast = "--fast" in sys.argv
    scale = 0.25 if fast else 1.0

    print("# paper Table 2: reachability time/traffic/visits")
    _emit("table2", pe.table2_reachability(n=int(3000 * scale) + 100,
                                           m=int(12000 * scale) + 400))
    print("# paper Fig 11(a): vary card(F)")
    _emit("fig11a", pe.fig11a_vary_fragments(n=int(4000 * scale) + 100,
                                             m=int(16000 * scale) + 400))
    print("# paper Fig 11(b): vary size(F)")
    sizes = (500, 1000, 2000) if fast else (1000, 2000, 4000, 8000)
    _emit("fig11b", pe.fig11b_vary_size(sizes=sizes))
    print("# paper Exp-2: bounded reachability")
    _emit("exp2", pe.exp2_bounded(n=int(3000 * scale) + 100,
                                  m=int(12000 * scale) + 400))
    print("# paper Exp-3: regular reachability + query complexity")
    _emit("exp3", pe.exp3_regular(n=int(800 * scale) + 100,
                                  m=int(3200 * scale) + 400))
    print("# paper Exp-4: MapReduce")
    _emit("exp4", pe.exp4_mapreduce(n=int(800 * scale) + 100,
                                    m=int(3200 * scale) + 400))

    print("# ISSUE-2: amortized rvset cache + batched queries (Table-2 cfg)")
    amort = pe.exp_amortized(n=int(3000 * scale) + 100,
                             m=int(12000 * scale) + 400,
                             n_q=16 if fast else 64)
    print(f"amortized/cold,{amort['cold_single_query_us']:.1f},")
    print(f"amortized/warm_batched,{amort['warm_batched_per_query_us']:.1f},"
          f"speedup={amort['speedup']:.1f};"
          f"payload_shrink={amort['payload_shrink_factor']:.2f}")
    out = "BENCH_pr2.json"
    with open(out, "w") as f:
        json.dump({"experiment": "amortized_rvset_cache",
                   "fast_mode": fast, **amort}, f, indent=2)
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()
