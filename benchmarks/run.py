"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus section headers) and
emits the amortized-cache (BENCH_pr2) and incremental-maintenance
(BENCH_pr3) result files.  ``--fast`` runs scaled-down configs and writes
``BENCH_*.fast.json`` so the committed full-run baselines stay intact —
``benchmarks.check_regression`` compares the two in CI.

Any sub-experiment failure is reported at the end and the process exits
non-zero, so a CI benchmark step cannot pass vacuously.
"""
from __future__ import annotations

import json
import sys
import traceback

from . import paper_experiments as pe
from .exp_async_serve import exp_async_serve
from .exp_mvcc import exp_mvcc
from .roofline import kernel_report


def _emit(section: str, rows):
    for row in rows:
        us = next((v for k, v in row.items() if k.endswith("_us")
                   or k == "us_per_query"), 0.0)
        derived = ";".join(f"{k}={v}" for k, v in row.items()
                           if not (k.endswith("_us") or k == "us_per_query"))
        name = row.get("algo") or section
        print(f"{section}/{name},{us:.1f},{derived}")


def main() -> None:
    fast = "--fast" in sys.argv
    scale = 0.25 if fast else 1.0
    suffix = ".fast.json" if fast else ".json"
    failures = []

    def section(title, fn):
        print(title)
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failures.append(title)

    def table2():
        _emit("table2", pe.table2_reachability(n=int(3000 * scale) + 100,
                                               m=int(12000 * scale) + 400))

    def fig11a():
        _emit("fig11a", pe.fig11a_vary_fragments(n=int(4000 * scale) + 100,
                                                 m=int(16000 * scale) + 400))

    def fig11b():
        sizes = (500, 1000, 2000) if fast else (1000, 2000, 4000, 8000)
        _emit("fig11b", pe.fig11b_vary_size(sizes=sizes))

    def exp2():
        _emit("exp2", pe.exp2_bounded(n=int(3000 * scale) + 100,
                                      m=int(12000 * scale) + 400))

    def exp3():
        _emit("exp3", pe.exp3_regular(n=int(800 * scale) + 100,
                                      m=int(3200 * scale) + 400))

    def exp4():
        _emit("exp4", pe.exp4_mapreduce(n=int(800 * scale) + 100,
                                        m=int(3200 * scale) + 400))

    def amortized():
        amort = pe.exp_amortized(n=int(3000 * scale) + 100,
                                 m=int(12000 * scale) + 400,
                                 n_q=16 if fast else 64)
        print(f"amortized/cold,{amort['cold_single_query_us']:.1f},")
        print("amortized/warm_batched,"
              f"{amort['warm_batched_per_query_us']:.1f},"
              f"speedup={amort['speedup']:.1f};"
              f"payload_shrink={amort['payload_shrink_factor']:.2f}")
        out = "BENCH_pr2" + suffix
        with open(out, "w") as f:
            json.dump({"experiment": "amortized_rvset_cache",
                       "fast_mode": fast, **amort}, f, indent=2)
        print(f"# wrote {out}")

    def incremental():
        inc = pe.exp_incremental(n=int(3000 * scale) + 100,
                                 m=int(12000 * scale) + 400,
                                 n_q=16 if fast else 64)
        print(f"incremental/repair,{inc['repair_ms_median'] * 1e3:.1f},"
              f"speedup_vs_rebuild={inc['repair_speedup_median']:.1f}")
        print("incremental/full_rebuild,"
              f"{inc['full_rebuild_ms_median'] * 1e3:.1f},")
        print("incremental/warm_query_after_deltas,"
              f"{inc['warm_after_delta_us']:.1f},"
              f"before={inc['warm_before_delta_us']:.1f}")
        out = "BENCH_pr3" + suffix
        with open(out, "w") as f:
            json.dump({"experiment": "incremental_cache_maintenance",
                       "fast_mode": fast, **inc}, f, indent=2)
        print(f"# wrote {out}")

    section("# paper Table 2: reachability time/traffic/visits", table2)
    section("# paper Fig 11(a): vary card(F)", fig11a)
    section("# paper Fig 11(b): vary size(F)", fig11b)
    section("# paper Exp-2: bounded reachability", exp2)
    section("# paper Exp-3: regular reachability + query complexity", exp3)
    section("# paper Exp-4: MapReduce", exp4)
    def session_bench():
        res = pe.exp_session(n=int(800 * scale) + 100,
                             m=int(3200 * scale) + 400,
                             n_q=24 if fast else 96)
        print(f"session/mixed_batch,{res['mixed_per_query_us']:.1f},"
              f"fused_speedup={res['fused_speedup']:.2f};"
              f"n_groups={res['n_groups']}")
        print("session/per_kind_loop,"
              f"{res['per_kind_loop_per_query_us']:.1f},")
        out = "BENCH_pr4" + suffix
        with open(out, "w") as f:
            json.dump({"experiment": "session_mixed_batches",
                       "fast_mode": fast, **res}, f, indent=2)
        print(f"# wrote {out}")

    def sharded_mixed():
        res = pe.exp_sharded_mixed(n=int(320 * scale) + 80,
                                   m=int(1280 * scale) + 320,
                                   n_q=24 if fast else 48)
        print("sharded_mixed/shard_map,"
              f"{res['shard_map_per_query_us']:.1f},"
              f"vmap_us={res['vmap_per_query_us']:.1f};"
              f"answers_match={res['answers_match']};"
              f"payload_bits_ok={res['payload_bits_ok']}")
        print(f"sharded_mixed/vmap,{res['vmap_per_query_us']:.1f},")
        out = "BENCH_pr5" + suffix
        with open(out, "w") as f:
            json.dump({"experiment": "sharded_mixed_batches",
                       "fast_mode": fast, **res}, f, indent=2)
        print(f"# wrote {out}")

    section("# ISSUE-2: amortized rvset cache + batched queries (Table-2 "
            "cfg)", amortized)
    section("# ISSUE-3: incremental cache maintenance under edge deltas",
            incremental)
    section("# ISSUE-4: unified session, mixed-kind fused batches",
            session_bench)
    def scaleout():
        res = pe.exp_scaleout(n=int(320 * scale) + 80,
                              m=int(1280 * scale) + 320,
                              n_q=24 if fast else 48)
        for row in res["rows"]:
            print(f"scaleout/k{row['k']}_fpd{row['fragments_per_device']},"
                  f"{row['per_query_us']:.1f},"
                  f"qps={row['queries_per_sec']:.0f};"
                  f"wire_bits={row['wire_bits_total']};"
                  f"answers_match={row['answers_match']};"
                  f"payload_bits_ok={row['payload_bits_ok']}")
        out = "BENCH_pr6" + suffix
        with open(out, "w") as f:
            json.dump({"experiment": "scaleout_fragments_per_device",
                       "fast_mode": fast, **res}, f, indent=2)
        print(f"# wrote {out}")

    def chaos_bench():
        res = pe.exp_chaos(n=int(160 * scale) + 8, m=int(480 * scale) + 8,
                           rounds=6 if fast else 12,
                           per_round=9 if fast else 15)
        print("chaos/p95_per_query,"
              f"{res['p95_per_query_us']:.1f},"
              f"p50={res['p50_per_query_us']:.1f};"
              f"success_rate={res['success_rate']:.3f};"
              f"answers_ok={res['answers_ok']};"
              f"retries={res['retries']};"
              f"rollbacks={res['rollbacks']};"
              f"degraded_groups={res['degraded_groups']}")
        out = "BENCH_pr7" + suffix
        with open(out, "w") as f:
            json.dump({"experiment": "chaos_serving",
                       "fast_mode": fast, **res}, f, indent=2)
        print(f"# wrote {out}")

    def async_serve():
        res = exp_async_serve(n=int(800 * scale) + 100,
                              m=int(3200 * scale) + 400,
                              n_q=96 if fast else 240,
                              open_loop_n=48 if fast else 120,
                              repeats=2 if fast else 3)
        print(f"async_serve/continuous,{1e6 / res['async_qps']:.1f},"
              f"qps={res['async_qps']:.0f};"
              f"throughput_ratio={res['throughput_ratio']:.2f};"
              f"answers_ok={res['answers_ok']}")
        print(f"async_serve/sync_drain,{1e6 / res['sync_qps']:.1f},"
              f"qps={res['sync_qps']:.0f}")
        ol = res["open_loop"]
        print(f"async_serve/open_loop,{ol['p99_ms'] * 1e3:.1f},"
              f"p50_ms={ol['p50_ms']:.1f};p95_ms={ol['p95_ms']:.1f};"
              f"p99_ms={ol['p99_ms']:.1f};"
              f"offered_qps={ol['offered_qps']:.0f};"
              f"occupancy={ol['batch_occupancy']:.2f}")
        out = "BENCH_pr8" + suffix
        with open(out, "w") as f:
            json.dump({"experiment": "async_continuous_batching",
                       "fast_mode": fast, **res}, f, indent=2)
        print(f"# wrote {out}")

    def mvcc_bench():
        res = exp_mvcc(n=int(800 * scale) + 100,
                       m=int(3200 * scale) + 400,
                       n_events=64 if fast else 160)
        for mix, row in res["mixes"].items():
            print(f"mvcc/{mix}_barrier,{row['barrier']['read_p95_ms'] * 1e3:.1f},"
                  f"read_p95_ms={row['barrier']['read_p95_ms']:.1f};"
                  f"update_p95_ms={row['barrier']['update_p95_ms']:.1f}")
            print(f"mvcc/{mix}_mvcc,{row['mvcc']['read_p95_ms'] * 1e3:.1f},"
                  f"read_p95_ms={row['mvcc']['read_p95_ms']:.1f};"
                  f"update_p95_ms={row['mvcc']['update_p95_ms']:.1f};"
                  f"read_p95_ratio={row['read_p95_ratio']:.2f}")
        print(f"mvcc/summary,0.0,"
              f"read_p95_ratio_min={res['read_p95_ratio_min']:.2f};"
              f"answers_ok={res['answers_ok']};"
              f"offered_qps={res['offered_qps']:.0f}")
        # report-only roofline trajectory for the semiring kernels (no
        # gate: CPU CI is far off the TPU peaks by construction)
        roof = kernel_report(side=128 if fast else 256,
                             batch=32 if fast else 64,
                             repeats=5 if fast else 10)
        for kname, r in roof["kernels"].items():
            print(f"roofline/{kname},{r['time_s'] * 1e6:.1f},"
                  f"frac_peak_flops={r['frac_peak_flops']:.2e};"
                  f"frac_peak_bw={r['frac_peak_bw']:.2e};"
                  f"intensity={r['arithmetic_intensity']:.2f};"
                  f"bound={r['bound']}")
        out = "BENCH_pr9" + suffix
        with open(out, "w") as f:
            json.dump({"experiment": "mvcc_snapshot_serving",
                       "fast_mode": fast, **res, "roofline": roof},
                      f, indent=2)
        print(f"# wrote {out}")

    section("# ISSUE-5: sharded one-collective batches, all query kinds",
            sharded_mixed)
    section("# ISSUE-6: k >> d scale-out, fragments packed per device",
            scaleout)
    section("# ISSUE-7: fault-tolerant serving under a seeded 1% fault "
            "schedule", chaos_bench)
    section("# ISSUE-8: continuous-batching async serving vs the sync "
            "drain pattern", async_serve)
    section("# ISSUE-9: MVCC non-blocking deltas vs the barrier write "
            "path + kernel roofline", mvcc_bench)

    if failures:
        print(f"# FAILED sections ({len(failures)}): {failures}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
