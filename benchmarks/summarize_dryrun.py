"""Merge per-arch dry-run JSONs and emit the EXPERIMENTS.md tables."""
from __future__ import annotations

import glob
import json
import sys


def merge(pattern: str = "results/dr_*.json",
          out: str = "results/dryrun_all.json"):
    by_key = {}
    for path in sorted(glob.glob(pattern)):
        for r in json.load(open(path)):
            key = (r["arch"], r["shape"], r["mesh"])
            prev = by_key.get(key)
            # prefer ok records (retries of previously failed cells)
            if prev is None or (prev["status"] == "error"
                                and r["status"] != "error"):
                by_key[key] = r
    records = list(by_key.values())
    with open(out, "w") as f:
        json.dump(records, f, indent=1)
    return records


def dryrun_table(records, mesh=None):
    rows = []
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if mesh and r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"SKIP | {r['reason'][:60]}... |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR | {r.get('error', '')[:60]} |")
            continue
        gib = r["peak_bytes_per_dev"] / 2**30
        coll_mib = r["collective_bytes"] / 2**20
        sched = "; ".join(r["collective_schedule"][:2])
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {gib:.2f} | "
            f"{r.get('probe_flops', r['hlo_flops']):.2e} | "
            f"{coll_mib:.0f} | {r['collective_count']} | {sched[:80]} |")
    hdr = ("| arch | shape | mesh | GiB/dev | HLO FLOPs/dev | coll MiB/dev "
           "| #coll | schedule (head) |")
    sep = "|---" * 8 + "|"
    return "\n".join([hdr, sep] + rows)


if __name__ == "__main__":
    recs = merge(*(sys.argv[1:2] or ["results/dr_*.json"]))
    ok = sum(1 for r in recs if r["status"] == "ok")
    sk = sum(1 for r in recs if r["status"] == "skipped")
    er = sum(1 for r in recs if r["status"] == "error")
    print(f"merged: {len(recs)} records ({ok} ok / {sk} skipped / {er} err)\n")
    print(dryrun_table(recs))
