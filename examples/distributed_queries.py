"""Distributed-engine demo: shard_map partial evaluation (one fragment
per fake device, then 32 fragments packed onto the same 8 devices) vs
the message-passing and centralized baselines — plus a ``repro.connect``
session answering a mixed reach+dist+RPQ batch with one fused execution
per (kind, automaton) group.

    PYTHONPATH=src python examples/distributed_queries.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys  # noqa: E402

sys.path.insert(0, "src")

import numpy as np                                       # noqa: E402

from repro.core import dis_reach, fragment_graph         # noqa: E402
from repro.core.baselines import dis_reach_m, dis_reach_n  # noqa: E402
from repro.core.distributed import dis_reach_sharded     # noqa: E402
from repro.graph import bfs_partition, erdos_renyi       # noqa: E402


def main():
    # demo-sized: 8 fake host devices timeslice one CPU, and CI runs this
    # script as a smoke test, so keep compiles and fixpoints small
    k = 8
    g = erdos_renyi(600, 2400, n_labels=8, seed=42)
    # locality-aware partition: the paper notes |V_f| is small in practice;
    # random partitioning of an ER graph makes nearly every node boundary
    part = bfs_partition(g, k, seed=1)
    fr = fragment_graph(g, part, k)
    print(f"graph |V|={g.n} |E|={g.m}; {k} fragments; "
          f"|V_f|={fr.B - 2}; |F_m|={fr.largest_fragment()}")

    rng = np.random.default_rng(0)
    for _ in range(5):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        if s == t:
            continue
        ans_sharded, _ = dis_reach_sharded(fr, s, t)
        res_vmap = dis_reach(fr, s, t)
        res_n = dis_reach_n(fr, s, t)
        res_m = dis_reach_m(fr, s, t)
        assert ans_sharded == res_vmap.answer == res_n.answer == res_m.answer
        print(f"q_r({s:4d},{t:4d}) = {str(ans_sharded):5s} | "
              f"partial-eval: 1 round, {res_vmap.stats.payload_bits}b | "
              f"message-passing: {res_m.rounds} rounds, "
              f"{res_m.site_visits} site visits | "
              f"ship-all: {res_n.traffic_bits}b")

    # session path: one handle owns the amortized caches and fuses a mixed
    # reach+dist+RPQ batch into one compiled execution per (kind, automaton)
    import time
    import repro
    from repro.core import Dist, Reach, Rpq
    session = repro.connect(fr, backend="vmap")
    t0 = time.perf_counter()
    session.warm(with_dist=True)
    build = time.perf_counter() - t0
    queries = []
    for i in range(36):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        queries.append(Reach(s, t) if i % 3 == 0 else
                       Dist(s, t) if i % 3 == 1 else
                       Rpq(s, t, regex="(0|1|2|3)* (4|5)*"))
    session.run(queries)                          # compile each group once
    t0 = time.perf_counter()
    results = session.run(queries)
    per_q = (time.perf_counter() - t0) / len(queries) * 1e6
    for q, r in zip(queries, results):
        if isinstance(q, Reach):
            assert r.answer == dis_reach(fr, q.s, q.t).answer
    print(session.last_plan.explain())
    print(f"warm mixed batch of {len(queries)}: {per_q:.0f}us/query "
          f"(caches built once in {build * 1e3:.0f}ms)")

    # shard_map backend: one fragment per device, and EVERY kind in the
    # mixed batch keeps the paper's one-collective-per-fused-group
    # guarantee (DESIGN.md Sec. 3.3).  Small locality graph so the
    # replicated (|V_f| |Q|)^2 RPQ closure stays demo-sized.
    per = 20
    blocks = np.arange(8 * per) // per
    src = rng.integers(0, per, 600) + per * rng.integers(8, size=600)
    dst = rng.integers(0, per, 600) + per * rng.integers(8, size=600)
    from repro.graph.graph import Graph
    gs = Graph(8 * per, src, dst, rng.integers(0, 8, 8 * per).astype(np.int32))
    frs = fragment_graph(gs, blocks.astype(np.int32), 8)
    sharded = repro.connect(frs, backend="shard_map")
    mixed = [Reach(0, 5), Dist(3, 150), Dist(9, 90, bound=4),
             Rpq(1, 140, regex="(0|1)* 2"), Reach(100, 17)]
    res = sharded.run(mixed)
    host = repro.connect(frs, backend="vmap").run(mixed)
    assert [(r.answer, r.distance) for r in res] == \
        [(r.answer, r.distance) for r in host]
    print(f"shard_map mixed batch over {frs.k} devices: "
          f"{sharded.last_plan.n_groups} fused groups, one collective each")
    for grp in sharded.last_plan.groups:
        states = 1 if grp.automaton is None else grp.automaton.n_states
        bits = frs.traffic_bits(grp.kind, states=states,
                                batch=grp.padded_size)
        assert sum(res[i].stats.payload_bits for i in grp.indices) == bits
        print(f"  {grp.kind}: {grp.n} queries -> {bits}b on the wire")

    # k >> d scale-out: refragment the same graph into 32 fragments and
    # pack them onto the SAME 8-device mesh (4 per device, balanced
    # placement).  Answers and the wire are identical to vmap — packing
    # is free (DESIGN.md Sec. 6).
    fr32 = fragment_graph(gs, (np.arange(8 * per) // (per // 4))
                          .astype(np.int32), 32)
    packed = repro.connect(fr32)          # auto -> shard_map, d=8 <= k=32
    pl = packed.placement
    res32 = packed.run(mixed)
    host32 = repro.connect(fr32, backend="vmap").run(mixed)
    assert [(r.answer, r.distance) for r in res32] == \
        [(r.answer, r.distance) for r in host32]
    w = pl.loads(pl.fragment_weights(fr32))
    print(f"packed scale-out: {fr32.k} fragments on {pl.d} devices "
          f"({pl.fpd}/device), per-device workload "
          f"{int(w.min())}..{int(w.max())} (balanced placement)")


if __name__ == "__main__":
    main()
