"""Distributed-engine demo: one fragment per (fake) device, shard_map
partial evaluation, vs the message-passing and centralized baselines —
plus the amortized rvset cache answering a whole query batch at once.

    PYTHONPATH=src python examples/distributed_queries.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys  # noqa: E402

sys.path.insert(0, "src")

import numpy as np                                       # noqa: E402

from repro.core import dis_reach, fragment_graph         # noqa: E402
from repro.core.baselines import dis_reach_m, dis_reach_n  # noqa: E402
from repro.core.distributed import dis_reach_sharded     # noqa: E402
from repro.graph import bfs_partition, erdos_renyi       # noqa: E402


def main():
    k = 8
    g = erdos_renyi(2000, 8000, n_labels=8, seed=42)
    # locality-aware partition: the paper notes |V_f| is small in practice;
    # random partitioning of an ER graph makes nearly every node boundary
    part = bfs_partition(g, k, seed=1)
    fr = fragment_graph(g, part, k)
    print(f"graph |V|={g.n} |E|={g.m}; {k} fragments; "
          f"|V_f|={fr.B - 2}; |F_m|={fr.largest_fragment()}")

    rng = np.random.default_rng(0)
    for _ in range(5):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        if s == t:
            continue
        ans_sharded, _ = dis_reach_sharded(fr, s, t)
        res_vmap = dis_reach(fr, s, t)
        res_n = dis_reach_n(fr, s, t)
        res_m = dis_reach_m(fr, s, t)
        assert ans_sharded == res_vmap.answer == res_n.answer == res_m.answer
        print(f"q_r({s:4d},{t:4d}) = {str(ans_sharded):5s} | "
              f"partial-eval: 1 round, {res_vmap.stats.payload_bits}b | "
              f"message-passing: {res_m.rounds} rounds, "
              f"{res_m.site_visits} site visits | "
              f"ship-all: {res_n.traffic_bits}b")

    # amortized path: build the rvset cache once, answer a batch in one call
    import time
    from repro.core import dis_reach_batch, prepare_rvset_cache
    t0 = time.perf_counter()
    prepare_rvset_cache(fr)
    build = time.perf_counter() - t0
    pairs = [(int(rng.integers(g.n)), int(rng.integers(g.n)))
             for _ in range(64)]
    dis_reach_batch(fr, pairs)                    # compile
    t0 = time.perf_counter()
    ans = dis_reach_batch(fr, pairs)
    per_q = (time.perf_counter() - t0) / len(pairs) * 1e6
    for (s, t), a in zip(pairs, ans):
        assert bool(a) == dis_reach(fr, s, t).answer
    print(f"warm-cache batch of {len(pairs)}: {per_q:.0f}us/query "
          f"(cache built once in {build * 1e3:.0f}ms)")


if __name__ == "__main__":
    main()
