"""Molecular-dynamics-style driver: train EGNN and MACE on batched small
molecules with an energy+forces objective (the `molecule` shape cell).

    PYTHONPATH=src python examples/gnn_forces.py
"""
import sys
sys.path.insert(0, "src")

import jax                                            # noqa: E402
import jax.numpy as jnp                               # noqa: E402
import numpy as np                                    # noqa: E402

from repro.models.gnn import common, egnn, equivariant  # noqa: E402
from repro.optim import adamw                         # noqa: E402


def make_batch(rng, n_mol=8, n_atoms=6):
    """Toy target: energy = sum of pairwise LJ-ish terms (rotation
    invariant), forces = -grad."""
    N = n_mol * n_atoms
    coords = rng.normal(size=(N, 3)).astype(np.float32)
    species = rng.integers(0, 4, N).astype(np.int32)
    gi = np.repeat(np.arange(n_mol), n_atoms).astype(np.int32)
    send, recv = [], []
    for m in range(n_mol):
        for i in range(n_atoms):
            for j in range(n_atoms):
                if i != j:
                    send.append(m * n_atoms + i)
                    recv.append(m * n_atoms + j)
    g = common.pad_graph(np.array(send), np.array(recv), N,
                         len(send), N, graph_ids=gi, n_graphs=n_mol)

    def true_energy(c):
        d2 = np.sum((c[send] - c[recv]) ** 2, -1) + 0.5
        e_edge = 1.0 / d2 - 1.0 / d2 ** 0.5
        out = np.zeros(n_mol)
        np.add.at(out, gi[np.array(send)], e_edge / 2)
        return out.astype(np.float32)

    return g, jnp.asarray(species), jnp.asarray(coords), \
        jnp.asarray(true_energy(coords))


def train(model_name: str, steps: int = 60):
    rng = np.random.default_rng(0)
    if model_name == "egnn":
        cfg = egnn.EGNNConfig(n_layers=3, d_hidden=32, d_in=4)
        params = egnn.init_params(cfg, jax.random.key(0))

        def energy_fn(p, species, coords, g):
            feats = jax.nn.one_hot(species, 4)
            return egnn.forward(cfg, p, feats, coords, g)[0]
    else:
        cfg = equivariant.EquivariantConfig(arch=model_name, n_layers=2,
                                            channels=16, l_max=2,
                                            correlation=3, n_species=4,
                                            cutoff=4.0)
        params = equivariant.init_params(cfg, jax.random.key(0))

        def energy_fn(p, species, coords, g):
            return equivariant.forward(cfg, p, species, coords, g)

    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=steps,
                                weight_decay=0.0)
    state = adamw.init(params)

    @jax.jit
    def step(params, state, species, coords, e_tgt, g_arrays):
        g = common.GraphData(*g_arrays, n_graphs=8)

        def loss_fn(p):
            e = energy_fn(p, species, coords, g)
            return jnp.mean((e - e_tgt) ** 2)

        l, grads = jax.value_and_grad(loss_fn)(params)
        params, state, _ = adamw.update(opt_cfg, grads, state, params)
        return params, state, l

    losses = []
    for i in range(steps):
        g, species, coords, e_tgt = make_batch(rng)
        ga = (g.senders, g.receivers, g.node_mask, g.edge_mask, g.graph_ids)
        params, state, l = step(params, state, species, coords, e_tgt, ga)
        losses.append(float(l))
    print(f"{model_name:7s} loss: {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    train("egnn")
    train("mace", steps=30)
