"""Quickstart: the paper's own Figure-1 example as code.

Builds the recommendation network from Fig. 1 (Ann the CTO, Mark the FA,
DB/HR chains), fragments it across three "data centers", and runs all
three query classes with the partial-evaluation engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (build_query_automaton, dis_dist, dis_reach,
                        dis_rpq, fragment_graph)
from repro.graph.graph import Graph

# --- the paper's Fig. 1 graph ------------------------------------------------
# labels: 0=CTO 1=DB 2=HR 3=FA (names attached for readability)
NAMES = ["Ann", "Walt", "Bill", "Mat", "Fred", "Emmy", "Pat", "Jack",
         "Ross", "Tom", "Mark"]
LBL = {"Ann": 0, "Walt": 2, "Bill": 1, "Mat": 2, "Fred": 2, "Emmy": 2,
       "Pat": 1, "Jack": 1, "Ross": 2, "Tom": 1, "Mark": 3}
EDGES = [("Ann", "Walt"), ("Ann", "Bill"), ("Walt", "Mat"), ("Bill", "Pat"),
         ("Mat", "Fred"), ("Fred", "Emmy"), ("Emmy", "Ross"),
         ("Pat", "Jack"), ("Jack", "Fred"), ("Ross", "Mark"),
         ("Tom", "Ross")]
# fragmentation: DC1 = {Ann, Walt, Bill, Fred}, DC2 = {Mat, Emmy, Jack, Tom},
# DC3 = {Pat, Ross, Mark}
PART = {"Ann": 0, "Walt": 0, "Bill": 0, "Fred": 0, "Mat": 1, "Emmy": 1,
        "Jack": 1, "Tom": 1, "Pat": 2, "Ross": 2, "Mark": 2}


def main():
    idx = {n: i for i, n in enumerate(NAMES)}
    g = Graph(
        n=len(NAMES),
        src=np.array([idx[a] for a, b in EDGES]),
        dst=np.array([idx[b] for a, b in EDGES]),
        labels=np.array([LBL[n] for n in NAMES], np.int32),
        label_names=["CTO", "DB", "HR", "FA"],
    )
    part = np.array([PART[n] for n in NAMES], np.int32)
    fr = fragment_graph(g, part, 3)
    print(f"fragments: 3 | boundary nodes |V_f|: {fr.B - 2} "
          f"| largest fragment |F_m|: {fr.largest_fragment()}")

    s, t = idx["Ann"], idx["Mark"]

    r = dis_reach(fr, s, t)
    print(f"\nq_r(Ann, Mark)        -> {r.answer}   "
          f"(payload {r.stats.payload_bits} bits, "
          f"{r.stats.collective_rounds} collective round)")

    d = dis_dist(fr, s, t, bound=6)
    print(f"q_br(Ann, Mark, 6)    -> {d.answer}   (dist = {d.distance})")

    qa = build_query_automaton("(DB* | HR*)", g.label_of)
    rr = dis_rpq(fr, s, t, qa)
    print(f"q_rr(Ann, Mark, DB*|HR*) -> {rr.answer}   "
          f"(|V_q| = {qa.n_states}, payload {rr.stats.payload_bits} bits)")

    qa2 = build_query_automaton("DB*", g.label_of)
    rr2 = dis_rpq(fr, s, t, qa2)
    print(f"q_rr(Ann, Mark, DB*)     -> {rr2.answer}   "
          "(no pure-DB chain exists — paper Ex. 1)")


if __name__ == "__main__":
    main()
