"""Quickstart: the paper's own Figure-1 example as code.

Builds the recommendation network from Fig. 1 (Ann the CTO, Mark the FA,
DB/HR chains), fragments it across three "data centers", opens a
``repro.connect`` session, and answers all three query classes in ONE
mixed batch — the planner fuses it into one compiled execution per
(kind, automaton) group.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro
from repro.core import Dist, Reach, Rpq, fragment_graph
from repro.graph.graph import Graph

# --- the paper's Fig. 1 graph ------------------------------------------------
# labels: 0=CTO 1=DB 2=HR 3=FA (names attached for readability)
NAMES = ["Ann", "Walt", "Bill", "Mat", "Fred", "Emmy", "Pat", "Jack",
         "Ross", "Tom", "Mark"]
LBL = {"Ann": 0, "Walt": 2, "Bill": 1, "Mat": 2, "Fred": 2, "Emmy": 2,
       "Pat": 1, "Jack": 1, "Ross": 2, "Tom": 1, "Mark": 3}
EDGES = [("Ann", "Walt"), ("Ann", "Bill"), ("Walt", "Mat"), ("Bill", "Pat"),
         ("Mat", "Fred"), ("Fred", "Emmy"), ("Emmy", "Ross"),
         ("Pat", "Jack"), ("Jack", "Fred"), ("Ross", "Mark"),
         ("Tom", "Ross")]
# fragmentation: DC1 = {Ann, Walt, Bill, Fred}, DC2 = {Mat, Emmy, Jack, Tom},
# DC3 = {Pat, Ross, Mark}
PART = {"Ann": 0, "Walt": 0, "Bill": 0, "Fred": 0, "Mat": 1, "Emmy": 1,
        "Jack": 1, "Tom": 1, "Pat": 2, "Ross": 2, "Mark": 2}


def main():
    idx = {n: i for i, n in enumerate(NAMES)}
    g = Graph(
        n=len(NAMES),
        src=np.array([idx[a] for a, b in EDGES]),
        dst=np.array([idx[b] for a, b in EDGES]),
        labels=np.array([LBL[n] for n in NAMES], np.int32),
        label_names=["CTO", "DB", "HR", "FA"],
    )
    part = np.array([PART[n] for n in NAMES], np.int32)
    fr = fragment_graph(g, part, 3)
    print(f"fragments: 3 | boundary nodes |V_f|: {fr.B - 2} "
          f"| largest fragment |F_m|: {fr.largest_fragment()}")

    s, t = idx["Ann"], idx["Mark"]

    session = repro.connect(fr)        # one handle for all three classes
    r, d, rr, rr2 = session.run([
        Reach(s, t),
        Dist(s, t, bound=6),
        Rpq(s, t, regex="(DB* | HR*)"),
        Rpq(s, t, regex="DB*"),
    ])
    print(session.last_plan.explain())

    print(f"\nq_r(Ann, Mark)        -> {r.answer}   "
          f"(payload {r.stats.payload_bits} bits, "
          f"{r.stats.collective_rounds} collective round)")
    print(f"q_br(Ann, Mark, 6)    -> {d.answer}   (dist = {d.distance})")
    print(f"q_rr(Ann, Mark, DB*|HR*) -> {rr.answer}   "
          f"(|V_q| = {rr.stats.states}, payload {rr.stats.payload_bits} bits)")
    print(f"q_rr(Ann, Mark, DB*)     -> {rr2.answer}   "
          "(no pure-DB chain exists — paper Ex. 1)")


if __name__ == "__main__":
    main()
