"""Serving driver: batched greedy decoding with the KV-cache engine
(ring-buffer SWA cache + optional int8 KV quantization).

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np                                   # noqa: E402
import jax                                           # noqa: E402

from repro.models import transformer as T            # noqa: E402
from repro.serve import Request, ServeEngine         # noqa: E402


def main():
    cfg = T.LMConfig(name="serve-demo", n_layers=4, d_model=256, n_heads=8,
                     n_kv_heads=4, d_head=32, d_ff=683, vocab=8192,
                     sliding_window=64, kv_quant_int8=True, remat=False)
    params = T.init_params(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params, batch=4, max_len=256)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, size=int(n)),
                    max_new_tokens=12)
            for n in rng.integers(3, 20, size=6)]
    done = engine.generate(reqs)
    for i, r in enumerate(done):
        print(f"req{i}: prompt[{len(r.prompt)} toks] -> {r.generated}")
    print("ring KV cache:", T.cache_len(cfg, 256), "slots (window=64), int8")


if __name__ == "__main__":
    main()
