"""End-to-end training driver: a small LM trained for a few hundred steps
with the production substrate — deterministic data stream, AdamW, grad
accumulation, async checkpointing, and crash-recovery.

    PYTHONPATH=src python examples/train_lm.py            # ~10M params, fast
    PYTHONPATH=src python examples/train_lm.py --full     # ~100M params
    PYTHONPATH=src python examples/train_lm.py --steps 300 --crash-at 120
"""
import argparse
import sys

import jax

sys.path.insert(0, "src")

from repro.data import TokenStream                      # noqa: E402
from repro.models import transformer as T               # noqa: E402
from repro.optim import adamw                           # noqa: E402
from repro.train import Trainer, TrainerConfig          # noqa: E402


def build_cfg(full: bool) -> T.LMConfig:
    if full:   # ~100M params
        return T.LMConfig(name="lm100m", n_layers=8, d_model=768,
                          n_heads=12, n_kv_heads=4, d_head=64, d_ff=2048,
                          vocab=32000, remat=False)
    return T.LMConfig(name="lm10m", n_layers=4, d_model=256, n_heads=8,
                      n_kv_heads=4, d_head=32, d_ff=683, vocab=8192,
                      remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="simulate a node failure at this step")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = build_cfg(args.full)
    params = T.init_params(cfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params: {n/1e6:.1f}M")

    stream = TokenStream(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq)
    loss_fn = lambda p, b: T.lm_loss(cfg, p, b["tokens"], b["targets"])
    trainer = Trainer(
        TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50,
                      ckpt_async=True),
        adamw.AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
        loss_fn, params)

    crashed = {"done": False}

    def fail_hook(step):
        if args.crash_at is not None and step == args.crash_at \
                and not crashed["done"]:
            crashed["done"] = True
            print(f"!! simulated node failure at step {step} — recovering "
                  "from checkpoint")
            raise RuntimeError("simulated failure")

    import time
    t0 = time.time()
    eval_batch = stream.batch_at(10_000_019)     # held-out step index

    def data_fn(step):
        if step % 20 == 0:
            ev = float(loss_fn(trainer.state["params"], eval_batch))
            print(f"step {step:4d}  eval_loss={ev:.4f}  "
                  f"({time.time()-t0:.0f}s)")
        return stream.batch_at(step)

    metrics = trainer.run(data_fn, args.steps, fail_hook=fail_hook)
    final_loss = float(loss_fn(trainer.state["params"], eval_batch))
    print(f"done: steps={int(trainer.state['step'])} "
          f"final_loss={final_loss:.4f} restarts={metrics['restarts']}")


if __name__ == "__main__":
    main()
