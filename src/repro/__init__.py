"""Distributed reachability queries with performance guarantees
(JAX/Pallas reproduction of "Performance Guarantees for Distributed
Reachability Queries", plus a serving stack around it).

The front door is :func:`repro.connect`::

    import repro
    from repro.core import Reach, Dist, Rpq

    session = repro.connect(fr)                # fr: a Fragmentation
    results = session.run([
        Reach(s, t),
        Dist(s, t, bound=6),
        Rpq(s, t, regex="(DB* | HR*)"),
    ])

One session serves all three query classes from shared amortized caches,
fuses mixed batches into one compiled execution per (kind, automaton)
group, and keeps everything valid under graph deltas
(``session.apply(delta)``).  See DESIGN.md Sec. 5.
"""
from .core.fragments import Placement
from .core.plan import Dist, Query, QueryResult, Reach, Rpq
from .core.session import QuerySession, connect
from .errors import (DeadLetterError, DeadlineExceeded, DeltaApplyFailed,
                     InjectedFault, QueryTooExpensive, ServingError, Status)

__all__ = ["connect", "QuerySession", "QueryResult", "Status",
           "Reach", "Dist", "Rpq", "Query", "Placement",
           "ServingError", "QueryTooExpensive", "DeadlineExceeded",
           "DeadLetterError", "DeltaApplyFailed", "InjectedFault"]
