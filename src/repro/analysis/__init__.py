"""repro.analysis: static guarantee verifier + concurrency lint
(DESIGN.md Sec. 10).

Three passes machine-check the paper's theorems and this repo's own
hard-won invariants on every lowered program:

* :mod:`.hlo_check` — parse the lowered HLO/StableHLO of the fused batch
  programs into a structured model and verify exactly one collective per
  group (Theorem 5.4's one visit per site), no collective inside a
  ``while`` body, payload bits == ``Fragmentation.traffic_bits`` and no
  ``|V|``/``|E|``-sized operand on the wire (Theorem 5.5).
* :mod:`.lint` — AST lint for the bug classes previous PRs actually hit
  (RPR001 ``jnp.asarray`` aliasing, RPR002 transfers under a lock,
  RPR003 unseeded randomness/wall-clock on serving paths, RPR004
  unbounded serving containers, RPR005 mutable state in cached
  closures).
* :mod:`.locks` — static lock-acquisition-graph extraction checked
  against the declared partial order, plus a runtime-instrumented mode
  used by the ``chaos``/``mvcc`` suites.

Run everything: ``python -m repro.analysis --all [--out report.json]``.
"""
from .hlo_check import (COLLECTIVE_KINDS, CollectiveOp, ProgramModel,
                        TensorType, check_program, parse_program,
                        verify_fragmentation, verify_session, verify_store)
from .lint import RULES, lint_paths, lint_source
from .locks import (LOCK_ORDER, InstrumentedLock, LockMonitor,
                    check_lock_order, extract_acquisition_graph, monitored)
from .report import Violation, dump_report, make_report

__all__ = [
    "COLLECTIVE_KINDS", "CollectiveOp", "ProgramModel", "TensorType",
    "parse_program", "check_program",
    "verify_fragmentation", "verify_session", "verify_store",
    "RULES", "lint_source", "lint_paths",
    "LOCK_ORDER", "check_lock_order", "extract_acquisition_graph",
    "LockMonitor", "InstrumentedLock", "monitored",
    "Violation", "make_report", "dump_report",
]
