"""CLI: ``python -m repro.analysis --all`` — run every static pass and
exit non-zero on violations.  See DESIGN.md Sec. 10.

The HLO pass lowers the real sharded programs and needs 8 host devices,
but ``python -m repro.analysis`` imports the ``repro`` package (and with
it the XLA backend) before this module runs — too late for
``XLA_FLAGS``.  When the backend came up with fewer devices, the CLI
re-execs itself once with the flag set in the child's environment.
"""
import argparse
import json
import os
import subprocess
import sys

_RESPAWN_SENTINEL = "_REPRO_ANALYSIS_RESPAWNED"
_DEVICE_FLAG = "--xla_force_host_platform_device_count=8"


def _ensure_devices(argv, min_devices=8):
    """Return None if enough devices are visible, else the exit code of a
    respawned child that has ``XLA_FLAGS`` set before Python starts."""
    import jax
    if jax.local_device_count() >= min_devices:
        return None
    if os.environ.get(_RESPAWN_SENTINEL):
        print(f"error: {jax.local_device_count()} device(s) visible even "
              f"under {_DEVICE_FLAG}", file=sys.stderr)
        return 2
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + _DEVICE_FLAG).strip()
    env[_RESPAWN_SENTINEL] = "1"
    return subprocess.call(
        [sys.executable, "-m", "repro.analysis", *argv], env=env)


def _hlo_section(batch):
    import repro
    from repro.core import GraphDelta, fragment_graph
    from repro.core.versions import VersionedCacheStore
    from repro.graph import erdos_renyi, random_partition

    from .hlo_check import verify_store

    reserve = dict(reserve_boundary=16, reserve_edges=32, reserve_stubs=16)
    configs = [
        # exact fit: k = d = 8, one fragment per device
        ("k8d8", erdos_renyi(48, 140, n_labels=4, seed=5), 8),
        # packed: k = 32 fragments on 8 devices, fpd = 4
        ("k32d8", erdos_renyi(96, 300, n_labels=4, seed=9), 32),
    ]
    violations, covered = [], []
    for name, g, k in configs:
        fr = fragment_graph(g, random_partition(g, k, 1), k, **reserve)
        sess = repro.connect(fr, backend="shard_map")
        store = VersionedCacheStore(sess, capacity=4)
        store.commit_delta(GraphDelta.insert([(0, 1)]))
        live = list(store.live())
        assert len(live) >= 2, f"{name}: expected >= 2 live versions"
        for v in verify_store(store, batch=batch):
            v.where = f"{name}:{v.where}"
            violations.append(v)
        covered.append(f"{name}: {len(live)} versions x 3 kinds "
                       f"(d={sess.placement.d}, fpd={sess.placement.fpd})")
    return violations, {"covered": covered}


def main(argv=None):
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static guarantee verifier + concurrency lint")
    p.add_argument("--all", action="store_true",
                   help="run every pass (default if none selected)")
    p.add_argument("--hlo", action="store_true",
                   help="lower + verify the sharded programs (HLO001-004)")
    p.add_argument("--lint", action="store_true",
                   help="AST lint over src/repro (RPR001-005)")
    p.add_argument("--locks", action="store_true",
                   help="static lock-order check (LCK001-003)")
    p.add_argument("--root", default=os.getcwd(),
                   help="repo root (default: cwd)")
    p.add_argument("--batch", type=int, default=2,
                   help="fused batch size for the HLO pass")
    p.add_argument("--out", default=None, help="write the JSON report here")
    argv = sys.argv[1:] if argv is None else list(argv)
    args = p.parse_args(argv)
    if args.all or not (args.hlo or args.lint or args.locks):
        args.hlo = args.lint = args.locks = True

    if args.hlo:
        rc = _ensure_devices(argv)
        if rc is not None:
            return rc

    from .report import dump_report, make_report

    sections, extra = {}, {}
    if args.hlo:
        sections["hlo"], extra["hlo"] = _hlo_section(args.batch)
    if args.lint:
        from .lint import lint_paths
        src = os.path.join(args.root, "src", "repro")
        sections["lint"] = lint_paths([src if os.path.isdir(src)
                                       else args.root])
    if args.locks:
        from .locks import LOCK_ORDER, check_lock_order
        vs, edges = check_lock_order(args.root)
        sections["locks"] = vs
        extra["locks"] = {"order": list(LOCK_ORDER),
                          "edges": sorted(f"{a} -> {b}" for a, b in edges)}

    report = make_report(sections, extra=extra)
    if args.out:
        dump_report(report, args.out)
    json.dump(report, sys.stdout, indent=2)
    print()
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
