"""HLO invariant checker: machine-check the paper's theorems on lowered
programs (DESIGN.md Sec. 10.1).

Parses the text of a lowered (StableHLO MLIR) or compiled (HLO dialect)
program into a structured model — collective ops with operand/result
dtypes and shapes, while-loop nesting (transitive through the call
graph), async ``-start``/``-done`` pairs — and verifies, per program:

* **HLO001** exactly one collective per fused group (Theorem 1: one
  visit per site == one communication round);
* **HLO002** no collective nested inside a ``while`` body, including
  collectives hiding in functions *called* from a loop body (a loop
  around the wire silently breaks the one-round bound);
* **HLO003** collective payload bits exactly equal the
  :meth:`Fragmentation.traffic_bits` wire model (closes the static
  model vs. actual lowering loop);
* **HLO004** no operand scaling with ``|V|`` or ``|E|`` crosses the wire
  (Theorem 2: traffic independent of ``|G|``).

This module owns the repo's ONE collective-matching pattern
(:data:`COLLECTIVE_KINDS` / :data:`COLLECTIVE_RE`): ``launch.hlo_stats``
and ``tests/test_guarantees.py`` both consume the structured parser
instead of keeping private regexes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .report import Violation

# --------------------------------------------------------------------------
# The canonical collective table.  Dash spelling is the HLO-dialect one;
# StableHLO spells the same ops with underscores — COLLECTIVE_RE accepts
# both, and every other matcher in the repo is built from this pattern.
COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

_KIND_PAT = "|".join(k.replace("-", "[-_]") for k in COLLECTIVE_KINDS)
COLLECTIVE_RE = re.compile(rf"\b({_KIND_PAT})(?:-(start|done))?\b")

# Dialect-anchored matchers (both derive from _KIND_PAT so a new kind is
# added in exactly one place).
_SHLO_COLL_RE = re.compile(rf"(?:stablehlo|mhlo)\.({_KIND_PAT})\b")
_HLO_COLL_RE = re.compile(
    rf"%(?P<name>[\w.\-]+)\s*=\s*"
    rf"(?:\((?P<tuple>[^)]*)\)|(?P<shape>\w+\[[\d,]*\]\S*))\s*"
    rf"(?P<kind>{_KIND_PAT})(?:-(?P<phase>start|done))?\(")

_DTYPE_BITS = {
    # HLO dialect names (pred occupies one byte on the wire)
    "pred": 8, "s8": 8, "u8": 8, "s16": 16, "u16": 16, "bf16": 16,
    "f16": 16, "s32": 32, "u32": 32, "f32": 32, "s64": 64, "u64": 64,
    "f64": 64, "c64": 64, "c128": 128,
    # StableHLO / MLIR element types
    "i1": 8, "i8": 8, "i16": 16, "i32": 32, "i64": 64,
    "ui8": 8, "ui16": 16, "ui32": 32, "ui64": 64,
}

_STR_RE = re.compile(r'"(?:[^"\\]|\\.)*"')
_FUNC_RE = re.compile(r"func\.func\s+(?:\w+\s+)?@([\w.$\-]+)")
_CALL_RE = re.compile(r"\bcall\s+@([\w.$\-]+)")
_WHILE_SHLO_RE = re.compile(r"\b(?:stablehlo|mhlo)\.while\b")
_TENSOR_RE = re.compile(r"tensor<([^>]*)>")
_HLO_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->")
_HLO_WHILE_RE = re.compile(r"\bwhile\(")
_HLO_REF_RE = re.compile(r"(?:to_apply|calls|condition|body)=%?([\w.\-]+)")
_HLO_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_HLO_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _strip_strings(line: str) -> str:
    return _STR_RE.sub('""', line)


def _dtype_bits(dtype: str) -> int:
    try:
        return _DTYPE_BITS[dtype]
    except KeyError:
        raise ValueError(
            f"unknown element type {dtype!r} in lowered program; add it to "
            "repro.analysis.hlo_check._DTYPE_BITS") from None


@dataclasses.dataclass(frozen=True)
class TensorType:
    """One tensor crossing (or produced by) a collective."""

    dtype: str
    dims: Tuple[int, ...]

    @property
    def bits(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n * _dtype_bits(self.dtype)

    @property
    def bytes(self) -> int:
        return self.bits // 8

    def __str__(self) -> str:
        return f"{self.dtype}[{','.join(str(d) for d in self.dims)}]"


def _mlir_tensor(inner: str) -> TensorType:
    toks = [t for t in inner.strip().split("x") if t]
    if not toks:
        raise ValueError(f"empty tensor type <{inner}>")
    dtype = toks[-1]
    dims = []
    for t in toks[:-1]:
        if not t.isdigit():
            raise ValueError(f"unsupported tensor dim {t!r} in <{inner}>")
        dims.append(int(t))
    _dtype_bits(dtype)  # validate eagerly
    return TensorType(dtype, tuple(dims))


def _hlo_tensor(dtype: str, dims: str) -> TensorType:
    _dtype_bits(dtype)
    return TensorType(dtype,
                      tuple(int(d) for d in dims.split(",") if d))


@dataclasses.dataclass
class CollectiveOp:
    """One collective in the parsed program (an async -start/-done pair
    counts as ONE op, payload taken from the -done result)."""

    kind: str                     # canonical dash spelling
    func: str                     # containing function / computation
    line: int                     # 1-based line of the op (start, if async)
    in_loop: bool                 # lexically or transitively in a while body
    operands: List[TensorType]
    results: List[TensorType]
    async_pair: bool = False

    @property
    def payload_bits(self) -> int:
        return sum(t.bits for t in self.results)

    def describe(self) -> str:
        res = ", ".join(str(t) for t in self.results)
        return f"{self.kind}({res}) in {self.func}"


@dataclasses.dataclass
class ProgramModel:
    """Structured view of one lowered/compiled program."""

    dialect: str                  # "stablehlo" | "hlo"
    collectives: List[CollectiveOp]
    n_while: int

    @property
    def payload_bits(self) -> int:
        return sum(c.payload_bits for c in self.collectives)


def _canon(kind: str) -> str:
    return kind.replace("_", "-")


# --------------------------------------------------------------------------
# StableHLO (MLIR) dialect


def _stablehlo_signature(raw_lines: List[str], i: int, col: int
                         ) -> Tuple[List[TensorType], List[TensorType]]:
    """Find the statement's ``: (operands) -> results`` type signature.

    Scans forward from just after the op name, tracking ``(){}`` depth
    (string literals skipped, so attribute payloads like
    ``mhlo.sharding = "{devices=[8,1]<=[8]}"`` cannot unbalance the
    scan); the signature is the first ``:`` found at depth 0 — colons
    inside attribute dictionaries or regions sit at depth >= 1.
    """
    depth = 0
    j, pos, sig = i, col, None
    for _ in range(400):
        if j >= len(raw_lines):
            break
        line = raw_lines[j]
        in_str = False
        while pos < len(line):
            ch = line[pos]
            if in_str:
                if ch == "\\":
                    pos += 2
                    continue
                if ch == '"':
                    in_str = False
            elif ch == '"':
                in_str = True
            elif ch in "({":
                depth += 1
            elif ch in ")}":
                depth -= 1
                if depth < 0:       # statement ended without a signature
                    return [], []
            elif ch == ":" and depth == 0:
                sig = line[pos + 1:]
                break
            pos += 1
        if sig is not None:
            break
        j, pos = j + 1, 0
    if sig is None:
        return [], []
    head, _, tail = sig.partition("->")
    operands = [_mlir_tensor(t) for t in _TENSOR_RE.findall(head)]
    results = ([_mlir_tensor(t) for t in _TENSOR_RE.findall(tail)]
               if tail else [])
    if not results:                 # `: tensor<...>` single-type form
        results = operands
    return operands, results


def _parse_stablehlo(text: str) -> ProgramModel:
    raw_lines = text.splitlines()
    brace: List[bool] = []        # True == this open brace is a loop region
    whiles: List[List[int]] = []  # pending [open-depth, regions-remaining]
    func = "<module>"
    n_while = 0
    collectives: List[CollectiveOp] = []
    call_edges: List[Tuple[str, str, bool]] = []
    for i, raw in enumerate(raw_lines):
        stripped = _strip_strings(raw)
        fm = _FUNC_RE.search(stripped)
        if fm:
            func = fm.group(1)
        in_loop_here = any(brace)
        if _WHILE_SHLO_RE.search(raw):
            n_while += 1
            whiles.append([len(brace), 2])
        for cm in _CALL_RE.finditer(stripped):
            call_edges.append((func, cm.group(1), in_loop_here))
        for cm in _SHLO_COLL_RE.finditer(raw):
            start = cm.end()
            if start < len(raw) and raw[start] == '"':
                start += 1          # generic form: op name is quoted
            operands, results = _stablehlo_signature(raw_lines, i, start)
            collectives.append(CollectiveOp(
                kind=_canon(cm.group(1)), func=func, line=i + 1,
                in_loop=in_loop_here, operands=operands, results=results))
        for ch in stripped:
            if ch == "{":
                tag = False
                if whiles and whiles[-1][0] == len(brace):
                    tag = True
                    whiles[-1][1] -= 1
                    if whiles[-1][1] == 0:
                        whiles.pop()
                brace.append(tag)
            elif ch == "}":
                if brace:
                    brace.pop()
    # taint functions reachable from any loop-context call site
    tainted: Set[str] = set()
    changed = True
    while changed:
        changed = False
        for caller, callee, at_loop in call_edges:
            if (at_loop or caller in tainted) and callee not in tainted:
                tainted.add(callee)
                changed = True
    for op in collectives:
        if op.func in tainted:
            op.in_loop = True
    return ProgramModel("stablehlo", collectives, n_while)


# --------------------------------------------------------------------------
# HLO dialect (compiled `.as_text()` / golden snippets)


def _parse_hlo(text: str) -> ProgramModel:
    comp = ""
    refs: Dict[str, Set[str]] = {}
    loop_roots: Set[str] = set()
    n_while = 0
    raw_ops: List[dict] = []
    for i, raw in enumerate(text.splitlines()):
        line = _strip_strings(raw)
        cm = _HLO_COMP_RE.match(line)
        if cm and line.rstrip().endswith("{"):
            comp = cm.group(1)
            continue
        if _HLO_WHILE_RE.search(line):
            n_while += 1
            for r in re.finditer(r"(?:condition|body)=%?([\w.\-]+)", line):
                loop_roots.add(r.group(1))
        for r in _HLO_REF_RE.finditer(line):
            refs.setdefault(comp, set()).add(r.group(1))
        for r in _HLO_BRANCH_RE.finditer(line):
            for name in re.findall(r"%?([\w.\-]+)", r.group(1)):
                refs.setdefault(comp, set()).add(name)
        m = _HLO_COLL_RE.search(line)
        if m:
            if m.group("tuple") is not None:
                results = [_hlo_tensor(d, s) for d, s in
                           _HLO_SHAPE_RE.findall(m.group("tuple"))]
            else:
                sm = _HLO_SHAPE_RE.match(m.group("shape"))
                results = [_hlo_tensor(sm.group(1), sm.group(2))]
            rest = line[m.end():]
            operands = [_hlo_tensor(d, s) for d, s in
                        _HLO_SHAPE_RE.findall(rest.split("),")[0])]
            first_arg = re.search(r"%([\w.\-]+)", rest)
            raw_ops.append({
                "name": m.group("name"), "kind": _canon(m.group("kind")),
                "phase": m.group("phase"), "results": results,
                "operands": operands, "comp": comp, "line": i + 1,
                "arg": first_arg.group(1) if first_arg else None,
            })
    # taint closure: computations reachable from any while condition/body
    tainted = set(loop_roots)
    changed = True
    while changed:
        changed = False
        for t in list(tainted):
            for callee in refs.get(t, ()):
                if callee not in tainted:
                    tainted.add(callee)
                    changed = True
    # pair async -start/-done: one CollectiveOp per pair, payload from done
    starts = {op["name"]: op for op in raw_ops if op["phase"] == "start"}
    consumed: Set[str] = set()
    collectives: List[CollectiveOp] = []
    for op in raw_ops:
        if op["phase"] == "start":
            continue
        if op["phase"] == "done":
            start = starts.get(op["arg"])
            if start is not None:
                consumed.add(start["name"])
            in_loop = (op["comp"] in tainted or
                       (start is not None and start["comp"] in tainted))
            collectives.append(CollectiveOp(
                kind=op["kind"],
                func=(start or op)["comp"],
                line=(start or op)["line"], in_loop=in_loop,
                operands=(start or op)["operands"],
                results=op["results"], async_pair=True))
            continue
        collectives.append(CollectiveOp(
            kind=op["kind"], func=op["comp"], line=op["line"],
            in_loop=op["comp"] in tainted,
            operands=op["operands"], results=op["results"]))
    for name, start in starts.items():
        if name not in consumed:    # dangling start still counts once
            collectives.append(CollectiveOp(
                kind=start["kind"], func=start["comp"], line=start["line"],
                in_loop=start["comp"] in tainted,
                operands=start["operands"], results=start["results"],
                async_pair=True))
    collectives.sort(key=lambda c: c.line)
    return ProgramModel("hlo", collectives, n_while)


def parse_program(text: str) -> ProgramModel:
    """Parse lowered StableHLO MLIR or compiled HLO text (auto-detected)."""
    if re.search(r"\bfunc\.func\b|\bstablehlo\.", text):
        return _parse_stablehlo(text)
    return _parse_hlo(text)


# --------------------------------------------------------------------------
# Invariant checks


def check_program(model: ProgramModel, *, program: str = "<program>",
                  expect_count: Optional[int] = 1,
                  expected_bits: Optional[int] = None,
                  forbidden_dims: Sequence[int] = (),
                  allowed_dims: Sequence[int] = ()) -> List[Violation]:
    """Run HLO001-HLO004 against one parsed program."""
    vs: List[Violation] = []
    if expect_count is not None and len(model.collectives) != expect_count:
        vs.append(Violation(
            "HLO001",
            f"expected exactly {expect_count} collective(s), found "
            f"{len(model.collectives)}",
            where=program,
            context=", ".join(c.describe() for c in model.collectives)))
    for c in model.collectives:
        if c.in_loop:
            vs.append(Violation(
                "HLO002",
                f"{c.kind} reachable from a while-loop body — breaks the "
                "one-visit-per-site bound",
                where=f"{program}:{c.func}", context=c.describe()))
    if expected_bits is not None:
        got = model.payload_bits
        if got != expected_bits:
            vs.append(Violation(
                "HLO003",
                f"collective payload {got} bits != traffic_bits model "
                f"{expected_bits} bits",
                where=program,
                context=", ".join(c.describe() for c in model.collectives)))
    if forbidden_dims:
        allowed = set(allowed_dims)
        forbidden = set(forbidden_dims) - allowed
        for c in model.collectives:
            seen = set()
            for t in list(c.operands) + list(c.results):
                for d in t.dims:
                    if d in forbidden and d not in seen:
                        seen.add(d)
                        vs.append(Violation(
                            "HLO004",
                            f"wire tensor {t} carries graph-sized dim {d} — "
                            "traffic must not scale with |G|",
                            where=f"{program}:{c.func}"))
    return vs


def _wire_model(fr, kind: str, batch: int, states: int
                ) -> Tuple[int, Tuple[int, int]]:
    """Expected (bits, (rows, cols)) of the one fused-batch collective."""
    side = fr.n_boundary * states
    rows, cols = side + 2 * batch, side + 1
    if kind in ("reach", "rpq"):
        cols = (cols + 31) // 32
    return fr.traffic_bits(kind, states=states, batch=batch), (rows, cols)


def verify_fragmentation(fr, *, batch: int = 2, qa=None, placement=None,
                         mesh=None, kinds: Sequence[str] = ("reach", "dist",
                                                            "rpq"),
                         tag: str = "") -> List[Violation]:
    """Lower the fused-batch program for every query kind on ``fr`` and
    check HLO001-HLO004 against the ``traffic_bits`` wire model.

    Requires >= 2 visible devices (the sharded lowering path); callers on
    a single-device host should run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in a fresh
    process (as ``python -m repro.analysis`` does).
    """
    from ..core import build_query_automaton
    from ..core.distributed import lower_batch_hlo

    if qa is None:
        qa = build_query_automaton("(0|1)*", lambda x: int(x))
    n = fr.g.n
    pairs = [(i % n, (i + 1) % n) for i in range(batch)]
    forbidden = {int(fr.g.n), int(fr.g.src.size)}
    vs: List[Violation] = []
    for kind in kinds:
        states = qa.n_states if kind == "rpq" else 1
        hlo = lower_batch_hlo(fr, pairs, kind,
                              qa=qa if kind == "rpq" else None,
                              mesh=mesh, placement=placement)
        model = parse_program(hlo)
        bits, (rows, cols) = _wire_model(fr, kind, batch, states)
        name = f"{tag}{kind}[batch={batch}]"
        vs.extend(check_program(
            model, program=name, expect_count=1, expected_bits=bits,
            forbidden_dims=forbidden, allowed_dims=(rows, cols)))
    return vs


def verify_session(session, *, batch: int = 2, qa=None,
                   kinds: Sequence[str] = ("reach", "dist", "rpq")
                   ) -> List[Violation]:
    """Public entry point: statically verify the paper's guarantees on a
    user's :class:`~repro.core.session.QuerySession` mesh/placement.

    Returns the (empty-on-success) violation list; raise-on-failure is one
    ``assert not verify_session(s)`` away.
    """
    return verify_fragmentation(
        session.fr, batch=batch, qa=qa, placement=session.placement,
        mesh=session._mesh, kinds=kinds)


def verify_store(store, *, batch: int = 2, qa=None,
                 kinds: Sequence[str] = ("reach", "dist", "rpq")
                 ) -> List[Violation]:
    """Verify every live MVCC version of a
    :class:`~repro.core.versions.VersionedCacheStore` (the PR-9 guarantee:
    one collective on every snapshot a reader can still pin)."""
    session = store.session
    vs: List[Violation] = []
    for ver in store.live():
        vs.extend(verify_fragmentation(
            ver.fr, batch=batch, qa=qa, placement=session.placement,
            mesh=session._mesh, kinds=kinds, tag=f"v{ver.vid}:"))
    return vs
