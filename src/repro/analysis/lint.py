"""Repo-specific AST lint: past bug classes as named rules
(DESIGN.md Sec. 10.2).

Each rule codifies a defect class that actually bit this codebase:

* **RPR001** ``jnp.asarray`` on (a view of) a mutable host buffer —
  ``Fragmentation.arrays`` entries are mutated in place by
  ``apply_delta``, and on CPU ``jnp.asarray`` can alias the host memory
  instead of copying it (the latent aliasing bug fixed in PR 7 for
  device refresh; use ``jnp.array`` which always copies).
* **RPR002** lock held across a synchronous device transfer
  (``jax.device_put`` / ``block_until_ready``) — stalls every thread
  queued on the lock for a device round-trip (PR 8/9 threaded serving).
* **RPR003** unseeded randomness or direct wall-clock reads on serving
  paths — breaks the deterministic fault injection and fake-clock
  scheduler tests introduced in PR 7/8.
* **RPR004** unbounded container growth on serving paths — the
  dead-letter retention leak capped in PR 9: anything a long-running
  server appends to must be windowed or drained.
* **RPR005** mutable state captured by an ``lru_cache``-ed program
  factory — cached closures outlive graph versions, so factories must
  take only hashable immutable parameters (PR 5/9 program caches).

Suppressions are inline and must be justified::

    with self._lock:   # repr: ignore[RPR002] upload is < 1 KiB, measured
        ...

A bare ``# repr: ignore[RPRnnn]`` with no justification is itself a
violation (**RPR000**) — zero silent baseline suppressions.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .report import Violation

RULES: Dict[str, str] = {
    "RPR000": "bare `# repr: ignore[...]` without a justification",
    "RPR001": "jnp.asarray on a (view of a) mutable host buffer; "
              "use jnp.array (copy=True)",
    "RPR002": "lock held across jax.device_put / block_until_ready",
    "RPR003": "unseeded np.random / wall-clock read on a serving path",
    "RPR004": "unbounded container growth on a serving path",
    "RPR005": "mutable state captured in an lru_cache-ed factory",
}

_IGNORE_RE = re.compile(
    r"#\s*repr:\s*ignore\[([A-Z0-9,\s]+)\]\s*(.*)")

# methods that return a VIEW of (or taint-preserving handle to) their
# receiver; anything else returns fresh storage
_VIEW_METHODS = {"reshape", "ravel", "transpose", "view", "swapaxes",
                 "squeeze", "items", "values", "get"}
_TRANSFER_CALLS = {"device_put", "block_until_ready"}
_SEEDED_RANDOM = {"default_rng", "Generator", "SeedSequence", "PCG64",
                  "Philox"}
_CLOCK_CALLS = {"time", "monotonic", "perf_counter"}
_GROW_METHODS = {"append", "appendleft", "add", "extend"}
_SHRINK_METHODS = {"pop", "popleft", "popitem", "clear", "remove",
                   "discard"}
_MUTATE_METHODS = {"append", "extend", "update", "add", "pop", "clear",
                   "setdefault", "__setitem__"}


def _parse_ignores(text: str) -> Tuple[Dict[int, Set[str]],
                                       List[Violation]]:
    """line -> suppressed rules; bare (unjustified) ignores are RPR000."""
    ignores: Dict[int, Set[str]] = {}
    bare: List[Violation] = []
    for i, line in enumerate(text.splitlines(), 1):
        m = _IGNORE_RE.search(line)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        ignores[i] = rules
        justification = m.group(2).strip(" -—:\t")
        if len(justification) < 8:
            bare.append(Violation(
                "RPR000",
                f"suppression of {sorted(rules)} has no justification",
                where=f"line {i}"))
    return ignores, bare


def _attr_chain(node: ast.AST) -> str:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


# --------------------------------------------------------------------------
# RPR001: host-buffer aliasing taint


def _fancy_index(idx: ast.AST) -> bool:
    """Advanced (copying) numpy indexing: array-valued or list index.
    A subscript expression as index (``x[owner[rows]]``) is array-valued
    in this codebase; bare names/constants/slices stay basic (views)."""
    if isinstance(idx, (ast.Call, ast.List, ast.ListComp, ast.Subscript)):
        return True
    if isinstance(idx, ast.Tuple):
        return any(_fancy_index(e) for e in idx.elts)
    return False


def _tainted(node: ast.AST, env: Dict[str, bool]) -> bool:
    """Does ``node`` evaluate to (a view of) a ``.arrays`` host buffer?"""
    if isinstance(node, ast.Name):
        return env.get(node.id, False)
    if isinstance(node, ast.Attribute):
        if node.attr == "arrays":
            return True             # the host-buffer dict itself
        if node.attr == "T":
            return _tainted(node.value, env)
        return False
    if isinstance(node, ast.Subscript):
        if not _tainted(node.value, env):
            return False
        return not _fancy_index(node.slice)   # basic indexing == view
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr in _VIEW_METHODS:
            return _tainted(f.value, env)
        return False                # any other call returns fresh storage
    return False


def _comp_taints(node: ast.AST, env: Dict[str, bool]) -> Dict[str, bool]:
    """Extra taint for comprehension targets iterating ``.arrays``."""
    extra: Dict[str, bool] = {}
    for gen in getattr(node, "generators", []):
        if _tainted(gen.iter, env):
            targets = (gen.target.elts
                       if isinstance(gen.target, ast.Tuple)
                       else [gen.target])
            for t in targets:
                if isinstance(t, ast.Name):
                    extra[t.id] = True
    return extra


class _AsarrayVisitor(ast.NodeVisitor):
    def __init__(self, env: Dict[str, bool]):
        self.env = dict(env)
        self.hits: List[ast.Call] = []

    def _visit_comp(self, node):
        saved = self.env
        self.env = {**saved, **_comp_taints(node, saved)}
        self.generic_visit(node)
        self.env = saved

    visit_ListComp = visit_SetComp = visit_GeneratorExp = _visit_comp
    visit_DictComp = _visit_comp

    def visit_Call(self, node: ast.Call):
        f = node.func
        if (isinstance(f, ast.Attribute) and f.attr == "asarray"
                and isinstance(f.value, ast.Name)
                and f.value.id in ("jnp", "jax")
                and node.args and _tainted(node.args[0], self.env)):
            self.hits.append(node)
        self.generic_visit(node)


def _scope_env(scope: ast.AST) -> Dict[str, bool]:
    """Fixpoint over simple ``name = expr`` bindings in one scope."""
    env: Dict[str, bool] = {}
    assigns = [n for n in ast.walk(scope) if isinstance(n, ast.Assign)]
    for _ in range(4):
        changed = False
        for a in assigns:
            val = _tainted(a.value, env)
            for tgt in a.targets:
                if isinstance(tgt, ast.Name) and env.get(tgt.id) != val:
                    env[tgt.id] = val
                    changed = True
        if not changed:
            break
    return env


def _check_rpr001(tree: ast.AST, path: str) -> List[Violation]:
    out: List[Violation] = []
    scopes = [tree] + [n for n in ast.walk(tree)
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))]
    seen: Set[int] = set()
    for scope in scopes:
        v = _AsarrayVisitor(_scope_env(scope))
        for stmt in (scope.body if isinstance(scope, ast.Module)
                     else scope.body):
            v.visit(stmt)
        for call in v.hits:
            if call.lineno in seen:
                continue
            seen.add(call.lineno)
            out.append(Violation(
                "RPR001",
                "jnp.asarray may alias a mutable Fragmentation.arrays "
                "host buffer — use jnp.array (copy=True)",
                where=f"{path}:{call.lineno}"))
    return out


# --------------------------------------------------------------------------
# RPR002: device transfer under a lock


def _is_lock_ctx(expr: ast.AST) -> bool:
    name = _attr_chain(expr).lower()
    return any(t in name for t in ("lock", "mutex", "cond"))


def _check_rpr002(tree: ast.AST, path: str) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.With, ast.AsyncWith)):
            continue
        if not any(_is_lock_ctx(item.context_expr)
                   for item in node.items):
            continue
        for sub in ast.walk(node):
            if (isinstance(sub, ast.Call)
                    and isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in _TRANSFER_CALLS):
                out.append(Violation(
                    "RPR002",
                    f"{sub.func.attr} while holding a lock stalls every "
                    "queued thread for a device round-trip",
                    where=f"{path}:{sub.lineno}",
                    context=f"lock taken at line {node.lineno}"))
    return out


# --------------------------------------------------------------------------
# RPR003: nondeterminism on serving paths


def _check_rpr003(tree: ast.AST, path: str) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if chain in (f"time.{c}" for c in _CLOCK_CALLS):
            out.append(Violation(
                "RPR003",
                f"direct wall-clock read {chain}() on a serving path — "
                "inject a clock so scheduler tests stay deterministic",
                where=f"{path}:{node.lineno}"))
        elif (chain.startswith("np.random.")
              or chain.startswith("numpy.random.")):
            fn = chain.rsplit(".", 1)[1]
            if fn not in _SEEDED_RANDOM:
                out.append(Violation(
                    "RPR003",
                    f"unseeded {chain}() on a serving path — use a "
                    "seeded np.random.default_rng",
                    where=f"{path}:{node.lineno}"))
        elif chain in ("random.random", "random.randint", "random.choice",
                       "random.shuffle", "random.uniform"):
            out.append(Violation(
                "RPR003",
                f"unseeded stdlib {chain}() on a serving path",
                where=f"{path}:{node.lineno}"))
    return out


# --------------------------------------------------------------------------
# RPR004: unbounded growth on serving paths


def _deque_has_maxlen(call: ast.Call) -> bool:
    return (len(call.args) >= 2
            or any(kw.arg == "maxlen" for kw in call.keywords))


def _check_rpr004(tree: ast.AST, path: str, text: str) -> List[Violation]:
    out = []
    candidates: Dict[str, int] = {}     # attr name -> assign line
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        tgt = node.targets[0]
        if not (isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self"):
            continue
        val = node.value
        unbounded = False
        if isinstance(val, (ast.List, ast.Set)) or (
                isinstance(val, ast.Call)
                and _attr_chain(val.func) in ("set", "list")):
            unbounded = True
        elif (isinstance(val, ast.Call)
              and _attr_chain(val.func) in ("deque", "collections.deque")
              and not _deque_has_maxlen(val)):
            unbounded = True
        if unbounded:
            candidates[tgt.attr] = node.lineno
    for attr, line in candidates.items():
        grows = re.search(
            rf"self\.{re.escape(attr)}\.({'|'.join(_GROW_METHODS)})\(",
            text)
        shrinks = (re.search(
            rf"self\.{re.escape(attr)}\.({'|'.join(_SHRINK_METHODS)})"
            rf"\b|del\s+self\.{re.escape(attr)}\b", text)
            # reassigned somewhere after __init__ == drained wholesale
            or len(re.findall(rf"self\.{re.escape(attr)}\s*=", text)) > 1)
        if grows and not shrinks:
            out.append(Violation(
                "RPR004",
                f"self.{attr} grows (.{grows.group(1)}) but is never "
                "drained/windowed — unbounded on a long-running server",
                where=f"{path}:{line}"))
    return out


# --------------------------------------------------------------------------
# RPR005: mutable capture in lru_cache factories


def _is_lru_cache(dec: ast.AST) -> bool:
    if isinstance(dec, ast.Call):
        dec = dec.func
    return _attr_chain(dec) in ("lru_cache", "functools.lru_cache",
                                "cache", "functools.cache")


def _check_rpr005(tree: ast.AST, path: str) -> List[Violation]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not any(_is_lru_cache(d) for d in node.decorator_list):
            continue
        if any(isinstance(d, (ast.List, ast.Dict, ast.Set))
               for d in node.args.defaults):
            out.append(Violation(
                "RPR005",
                f"lru_cache-ed {node.name} has a mutable default arg",
                where=f"{path}:{node.lineno}"))
        params = {a.arg for a in (node.args.args
                                  + node.args.kwonlyargs)} - {"self"}
        for sub in ast.walk(node):
            hit: Optional[str] = None
            if (isinstance(sub, ast.Attribute) and sub.attr == "arrays"
                    and isinstance(sub.value, ast.Name)
                    and sub.value.id in params):
                hit = f"{sub.value.id}.arrays"
            elif (isinstance(sub, ast.Subscript)
                  and isinstance(sub.value, ast.Name)
                  and sub.value.id in params):
                hit = f"{sub.value.id}[...]"
            elif (isinstance(sub, ast.Call)
                  and isinstance(sub.func, ast.Attribute)
                  and sub.func.attr in _MUTATE_METHODS
                  and isinstance(sub.func.value, ast.Name)
                  and sub.func.value.id in params):
                hit = f"{sub.func.value.id}.{sub.func.attr}()"
            if hit:
                out.append(Violation(
                    "RPR005",
                    f"lru_cache-ed {node.name} captures mutable state "
                    f"through parameter use {hit} — cached programs must "
                    "close over hashable immutable params only",
                    where=f"{path}:{sub.lineno}"))
                break
    return out


# --------------------------------------------------------------------------
# driver

_SERVE_RULES = ("RPR003", "RPR004")


def lint_source(text: str, path: str = "<memory>",
                serve_path: Optional[bool] = None) -> List[Violation]:
    """Lint one Python source. ``serve_path`` forces/suppresses the
    serving-only rules (default: inferred from the path)."""
    tree = ast.parse(text)
    ignores, bare = _parse_ignores(text)
    if serve_path is None:
        serve_path = f"{os.sep}serve{os.sep}" in path or "/serve/" in path
    found: List[Violation] = []
    found += _check_rpr001(tree, path)
    found += _check_rpr002(tree, path)
    if serve_path:
        found += _check_rpr003(tree, path)
        found += _check_rpr004(tree, path, text)
    found += _check_rpr005(tree, path)
    kept: List[Violation] = list(bare)
    for v in found:
        line = int(v.where.rsplit(":", 1)[-1]) if ":" in v.where else 0
        anchors = {line, line - 1}      # same line or the line above
        if v.context.startswith("lock taken at line "):
            anchors.add(int(v.context.rsplit(" ", 1)[-1]))
        if any(v.rule in ignores.get(a, ()) for a in anchors):
            continue
        kept.append(v)
    return kept


def lint_paths(roots: Sequence[str]) -> List[Violation]:
    """Lint every ``.py`` file under the given roots."""
    out: List[Violation] = []
    for root in roots:
        if os.path.isfile(root):
            files = [root]
        else:
            files = [os.path.join(dp, f)
                     for dp, _, fs in os.walk(root)
                     for f in sorted(fs) if f.endswith(".py")]
        for f in sorted(files):
            with open(f) as fh:
                text = fh.read()
            try:
                out.extend(lint_source(text, path=f))
            except SyntaxError as e:   # pragma: no cover - defensive
                out.append(Violation("RPR000",
                                     f"unparseable source: {e}", where=f))
    return out
