"""Lock-order checker for the threaded serving + MVCC stack
(DESIGN.md Sec. 10.3).

The PR-8/PR-9 stack spans five locks; the declared partial order (outer
first — a thread holding lock *i* may only acquire locks strictly later
in the list) is:

    engine._serve_mutex  ->  engine._mutex  ->  store._repair_lock
        ->  session._lock  ->  store._lock  ->  telemetry._lock

``engine._work`` and ``engine._repair_cond`` are Conditions built over
``engine._mutex`` and alias it.  ``session._lock`` and ``engine._mutex``
are RLocks (reentrant acquisition of the same lock is legal); everything
else is a plain Lock, so a same-name edge on those is a self-deadlock.

Two modes:

* **static** (:func:`check_lock_order`): extract the acquisition graph
  from the AST of the four lock-bearing modules — ``with`` nesting plus
  one level of receiver-resolved cross-module calls
  (``self.session.run(...)``, ``self.telemetry.record(...)``), with
  held-set propagation to a fixpoint — and reject any edge against the
  declared order (**LCK001**), same-name edge on a non-reentrant lock
  (**LCK002**), or undeclared lock (**LCK003**).
* **runtime** (:func:`monitored` / :class:`LockMonitor`): wrap the real
  locks with per-thread acquisition-stack recording; enabled by the
  conftest fixture under the ``chaos`` and ``mvcc`` suites so dynamic
  inversions static analysis cannot see are caught in CI.
"""
from __future__ import annotations

import ast
import contextlib
import os
import threading
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .report import Violation

LOCK_ORDER = (
    "engine._serve_mutex",
    "engine._mutex",
    "store._repair_lock",
    "session._lock",
    "store._lock",
    "telemetry._lock",
)
RANK = {name: i for i, name in enumerate(LOCK_ORDER)}
REENTRANT = frozenset({"session._lock", "engine._mutex"})

# which module plays which role (file basename -> role prefix)
DEFAULT_ROLES = {
    os.path.join("serve", "engine.py"): "engine",
    os.path.join("core", "session.py"): "session",
    os.path.join("core", "versions.py"): "store",
    os.path.join("serve", "telemetry.py"): "telemetry",
}
# attribute names that resolve a cross-object call receiver to a role
_RECEIVERS = {"session": "session", "store": "store", "_store": "store",
              "telemetry": "telemetry", "engine": "engine",
              "_engine": "engine"}


def _chain(node: ast.AST) -> Optional[str]:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _lock_name(chain: Optional[str], role: str) -> Optional[str]:
    if not chain:
        return None
    parts = chain.split(".")
    if parts[0] != "self":
        return None
    if len(parts) == 3 and parts[1] in _RECEIVERS and parts[2] == "_lock":
        return f"{_RECEIVERS[parts[1]]}._lock"
    if len(parts) != 2:
        return None
    attr = parts[1]
    if role == "engine":
        if attr in ("_mutex", "_work", "_repair_cond"):
            return "engine._mutex"          # Conditions alias the mutex
        if attr == "_serve_mutex":
            return "engine._serve_mutex"
    elif role == "store":
        if attr == "_lock":
            return "store._lock"
        if attr == "_repair_lock":
            return "store._repair_lock"
    elif role in ("session", "telemetry") and attr == "_lock":
        return f"{role}._lock"
    if attr.endswith(("_lock", "_mutex")):
        return f"{role}.{attr}"             # undeclared -> LCK003
    return None


def _resolve_call(chain: Optional[str], role: str
                  ) -> Optional[Tuple[str, str]]:
    if not chain:
        return None
    parts = chain.split(".")
    if parts[0] != "self":
        return None
    if len(parts) == 2:
        return (role, parts[1])
    if len(parts) == 3 and parts[1] in _RECEIVERS:
        return (_RECEIVERS[parts[1]], parts[2])
    if len(parts) == 4 and parts[1] in _RECEIVERS and parts[2] == "session":
        return ("session", parts[3])
    return None


class _MethodSummary:
    def __init__(self):
        # (locks already held within this method, lock acquired)
        self.acquires: List[Tuple[FrozenSet[str], str]] = []
        # (locks held within this method at the call site, callee)
        self.calls: List[Tuple[FrozenSet[str], Tuple[str, str]]] = []


def _summarize_method(fn: ast.AST, role: str) -> _MethodSummary:
    s = _MethodSummary()

    def walk(node: ast.AST, held: FrozenSet[str]):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            inner = held
            for item in node.items:
                lock = _lock_name(_chain(item.context_expr), role)
                if lock is None and isinstance(item.context_expr, ast.Call):
                    lock = _lock_name(_chain(item.context_expr.func), role)
                    lock = lock if lock and _chain(
                        item.context_expr.func).endswith(".acquire") else None
                if lock:
                    s.acquires.append((inner, lock))
                    inner = inner | {lock}
            for sub in node.body:
                walk(sub, inner)
            return
        if isinstance(node, ast.Call):
            callee = _resolve_call(_chain(node.func), role)
            if callee:
                s.calls.append((held, callee))
        for sub in ast.iter_child_nodes(node):
            # nested defs run later, under unknown locks — skip them
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                continue
            walk(sub, held)

    for stmt in fn.body:
        walk(stmt, frozenset())
    return s


def extract_acquisition_graph(files: Dict[str, str]
                              ) -> Set[Tuple[str, str]]:
    """``files``: path -> role.  Returns the set of (held, acquired)
    edges reachable through one-level receiver-resolved calls, to a
    fixpoint over entry hold-sets."""
    methods: Dict[Tuple[str, str], _MethodSummary] = {}
    for path, role in files.items():
        with open(path) as f:
            tree = ast.parse(f.read())
        for cls in ast.walk(tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            for fn in cls.body:
                if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    methods[(role, fn.name)] = _summarize_method(fn, role)

    entry: Dict[Tuple[str, str], Set[str]] = {m: set() for m in methods}
    changed = True
    while changed:
        changed = False
        for m, summ in methods.items():
            for held_local, callee in summ.calls:
                if callee not in entry:
                    continue
                add = set(held_local) | entry[m]
                if not add <= entry[callee]:
                    entry[callee] |= add
                    changed = True

    edges: Set[Tuple[str, str]] = set()
    for m, summ in methods.items():
        for held_local, lock in summ.acquires:
            for h in set(held_local) | entry[m]:
                edges.add((h, lock))
    return edges


def check_edges(edges: Set[Tuple[str, str]]) -> List[Violation]:
    vs: List[Violation] = []
    for a, b in sorted(edges):
        if a not in RANK or b not in RANK:
            missing = a if a not in RANK else b
            vs.append(Violation(
                "LCK003", f"undeclared lock {missing} in acquisition "
                f"edge {a} -> {b}; add it to LOCK_ORDER",
                where=f"{a} -> {b}"))
            continue
        if a == b:
            if a not in REENTRANT:
                vs.append(Violation(
                    "LCK002", f"{a} re-acquired while held but is not "
                    "reentrant — self-deadlock", where=f"{a} -> {b}"))
            continue
        if RANK[a] >= RANK[b]:
            vs.append(Violation(
                "LCK001", f"acquisition edge {a} -> {b} inverts the "
                f"declared order (rank {RANK[a]} -> {RANK[b]})",
                where=f"{a} -> {b}"))
    return vs


def default_files(root: str) -> Dict[str, str]:
    base = os.path.join(root, "src", "repro") if os.path.isdir(
        os.path.join(root, "src", "repro")) else root
    return {os.path.join(base, rel): role
            for rel, role in DEFAULT_ROLES.items()
            if os.path.exists(os.path.join(base, rel))}


def check_lock_order(root: str = ".", files: Optional[Dict[str, str]] = None
                     ) -> Tuple[List[Violation], Set[Tuple[str, str]]]:
    """Static pass: extract the acquisition graph and validate it."""
    files = files if files is not None else default_files(root)
    edges = extract_acquisition_graph(files)
    return check_edges(edges), edges


# --------------------------------------------------------------------------
# Runtime-instrumented mode


class LockMonitor:
    """Per-thread acquisition stacks + order validation at acquire time."""

    def __init__(self, order: Sequence[str] = LOCK_ORDER,
                 reentrant: FrozenSet[str] = REENTRANT):
        self._rank = {name: i for i, name in enumerate(order)}
        self._reentrant = frozenset(reentrant)
        self._tls = threading.local()
        self._mu = threading.Lock()
        self.violations: List[Violation] = []

    def _stack(self) -> List[str]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def note_acquire(self, name: str) -> None:
        st = self._stack()
        held = [h for h in st if h != name]
        if name in st and name not in self._reentrant:
            self._record(Violation(
                "LCK002", f"{name} re-acquired while held by the same "
                "thread but is not reentrant", where=" -> ".join(st + [name])))
        rank = self._rank.get(name)
        if rank is None:
            self._record(Violation(
                "LCK003", f"undeclared lock {name} acquired at runtime",
                where=name))
        else:
            for h in held:
                hr = self._rank.get(h)
                if hr is not None and hr >= rank:
                    self._record(Violation(
                        "LCK001", f"runtime inversion: {name} acquired "
                        f"while holding {h}",
                        where=" -> ".join(st + [name])))
        st.append(name)

    def note_release(self, name: str, all_depths: bool = False) -> None:
        st = self._stack()
        while name in st:
            for i in range(len(st) - 1, -1, -1):
                if st[i] == name:
                    del st[i]
                    break
            if not all_depths:
                break

    def _record(self, v: Violation) -> None:
        with self._mu:
            self.violations.append(v)


class InstrumentedLock:
    """Wraps a Lock/RLock, reporting acquisitions to a LockMonitor.

    Implements the private ``Condition`` protocol
    (``_is_owned`` / ``_release_save`` / ``_acquire_restore``) by
    delegation, so ``threading.Condition(InstrumentedLock(RLock()))``
    behaves exactly like a Condition over the raw lock.
    """

    def __init__(self, inner, name: str, monitor: LockMonitor):
        self._inner = inner
        self.name = name
        self._monitor = monitor

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._monitor.note_acquire(self.name)
        return got

    def release(self) -> None:
        self._monitor.note_release(self.name)
        self._inner.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self) -> bool:
        return self._inner.locked()

    # -- Condition protocol -------------------------------------------------

    def _is_owned(self) -> bool:
        if hasattr(self._inner, "_is_owned"):
            return self._inner._is_owned()
        if self._inner.acquire(False):
            self._inner.release()
            return False
        return True

    def _release_save(self):
        # RLock releases ALL recursion levels here
        self._monitor.note_release(self.name, all_depths=True)
        if hasattr(self._inner, "_release_save"):
            return self._inner._release_save()
        self._inner.release()
        return None

    def _acquire_restore(self, state) -> None:
        if hasattr(self._inner, "_acquire_restore"):
            self._inner._acquire_restore(state)
        else:
            self._inner.acquire()
        self._monitor.note_acquire(self.name)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"InstrumentedLock({self.name}, {self._inner!r})"


def instrument_session(session, monitor: LockMonitor) -> None:
    session._lock = InstrumentedLock(session._lock, "session._lock",
                                     monitor)


def instrument_store(store, monitor: LockMonitor) -> None:
    store._lock = InstrumentedLock(store._lock, "store._lock", monitor)
    store._repair_lock = InstrumentedLock(store._repair_lock,
                                          "store._repair_lock", monitor)


def instrument_telemetry(telemetry, monitor: LockMonitor) -> None:
    telemetry._lock = InstrumentedLock(telemetry._lock, "telemetry._lock",
                                       monitor)


def instrument_engine(engine, monitor: LockMonitor) -> None:
    engine._serve_mutex = InstrumentedLock(engine._serve_mutex,
                                           "engine._serve_mutex", monitor)
    engine._mutex = InstrumentedLock(engine._mutex, "engine._mutex",
                                     monitor)
    # the Conditions were built over the raw mutex — rebuild them over the
    # wrapper so waits keep the monitor's held-stack in sync
    engine._work = threading.Condition(engine._mutex)
    engine._repair_cond = threading.Condition(engine._mutex)


@contextlib.contextmanager
def monitored(monitor: Optional[LockMonitor] = None):
    """Patch the four lock-bearing constructors so every instance built
    inside the context runs on instrumented locks.  Yields the monitor;
    callers assert ``monitor.violations == []`` afterwards."""
    from ..core.session import QuerySession
    from ..core.versions import VersionedCacheStore
    from ..serve.engine import AsyncQueryEngine
    from ..serve.telemetry import Telemetry

    mon = monitor or LockMonitor()
    patches = [
        (QuerySession, instrument_session),
        (VersionedCacheStore, instrument_store),
        (AsyncQueryEngine, instrument_engine),
        (Telemetry, instrument_telemetry),
    ]
    originals = []
    for cls, hook in patches:
        orig = cls.__init__

        def wrapped(self, *a, _orig=orig, _hook=hook, **kw):
            _orig(self, *a, **kw)
            _hook(self, mon)

        originals.append((cls, orig))
        cls.__init__ = wrapped
    try:
        yield mon
    finally:
        for cls, orig in originals:
            cls.__init__ = orig
