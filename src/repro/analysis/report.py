"""Shared violation/report model for the analysis passes (DESIGN.md Sec. 10).

Every pass — the HLO invariant checker, the repo lint, and the lock-order
checker — reports findings as :class:`Violation` records so the CLI can
fold them into one JSON report and CI can fail on any non-empty list.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List


@dataclasses.dataclass
class Violation:
    """One finding from one pass.

    ``rule`` is the stable identifier (``HLO00x`` for program invariants,
    ``RPR00x`` for the repo lint, ``LCK00x`` for lock order); ``where``
    names the program / file:line / lock edge the finding is anchored to.
    """

    rule: str
    message: str
    where: str = ""
    context: str = ""

    def to_dict(self) -> Dict:
        return {k: v for k, v in dataclasses.asdict(self).items() if v}

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        loc = f" [{self.where}]" if self.where else ""
        return f"{self.rule}{loc}: {self.message}"


def make_report(sections: Dict[str, List[Violation]],
                extra: Dict = None) -> Dict:
    """Fold per-pass violation lists into the CLI's JSON report shape."""
    out = {
        "ok": all(not v for v in sections.values()),
        "violations": {
            name: [v.to_dict() for v in vs] for name, vs in sections.items()
        },
        "counts": {name: len(vs) for name, vs in sections.items()},
    }
    if extra:
        out.update(extra)
    return out


def dump_report(report: Dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
