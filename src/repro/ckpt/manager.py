"""Checkpoint manager: atomic versioned saves, latest-pointer restore, GC.

Fault-tolerance contract (used by train.Trainer):
  * ``save`` writes to a temp dir then os.rename's it into place — a crash
    mid-save never corrupts the latest checkpoint;
  * the ``LATEST`` pointer is written (atomically) only after the payload
    rename, so restore always sees a complete checkpoint;
  * ``restore`` rebuilds the exact pytree (structure pickled, leaves npz);
  * ``gc`` keeps the newest ``keep`` checkpoints.
Async mode hands the (host-copied) pytree to a background thread so the
training step loop never blocks on disk.
"""
from __future__ import annotations

import os
import pickle
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def _latest_file(self) -> str:
        return os.path.join(self.dir, "LATEST")

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        host_tree = jax.tree.map(np.asarray, tree)   # device -> host copy
        if blocking:
            self._write(step, host_tree)
        else:
            self.wait()                               # one in flight max
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = jax.tree.flatten(host_tree)
        np.savez(os.path.join(tmp, "leaves.npz"),
                 **{f"leaf_{i:05d}": np.asarray(x)
                    for i, x in enumerate(leaves)})
        with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
            pickle.dump(jax.tree.structure(host_tree), f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        # atomic latest-pointer update
        ptr_tmp = self._latest_file() + ".tmp"
        with open(ptr_tmp, "w") as f:
            f.write(str(step))
        os.replace(ptr_tmp, self._latest_file())
        self.gc()

    # -- restore ---------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        try:
            with open(self._latest_file()) as f:
                return int(f.read().strip())
        except FileNotFoundError:
            return None

    def restore(self, step: Optional[int] = None) -> Any:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        data = np.load(os.path.join(d, "leaves.npz"))
        leaves = [data[f"leaf_{i:05d}"] for i in range(len(data.files))]
        return jax.tree.unflatten(treedef, leaves)

    # -- gc ----------------------------------------------------------------------
    def gc(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
