"""Architecture registry: the 10 assigned architectures (+ the paper's own
graph-engine configs) as selectable ``--arch <id>`` entries.

Every arch exposes shape_ids(), skip_reason(shape), and
build(shape, multipod, reduced) -> CellProgram (see families/base.py).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from . import (bert4rec_cfg, chatglm3_6b, egnn_cfg, gat_cora, mace_cfg,
               mixtral_8x7b, nequip_cfg, olmoe_1b_7b, qwen1_5_32b,
               qwen2_1_5b)

ARCHS: Dict[str, object] = {
    a.ARCH.arch_id: a.ARCH
    for a in (olmoe_1b_7b, mixtral_8x7b, qwen1_5_32b, qwen2_1_5b,
              chatglm3_6b, egnn_cfg, mace_cfg, nequip_cfg, gat_cora,
              bert4rec_cfg)
}


def get_arch(arch_id: str):
    return ARCHS[arch_id]


def list_archs() -> List[str]:
    return list(ARCHS)


def all_cells() -> List[Tuple[str, str]]:
    """Every (arch, shape) pair — 40 cells."""
    out = []
    for aid, arch in ARCHS.items():
        for sid in arch.shape_ids():
            out.append((aid, sid))
    return out
