"""bert4rec [arXiv:1904.06690]: embed_dim=64, 2 blocks, 2 heads,
seq_len=200, bidirectional Cloze; 1M-item table for the retrieval cell."""
from ..models.bert4rec import Bert4RecConfig
from .families.recsys import RecsysArch

ARCH = RecsysArch(
    arch_id="bert4rec",
    full_cfg=Bert4RecConfig(n_items=1_000_000, embed_dim=64, n_blocks=2,
                            n_heads=2, seq_len=200),
    smoke_cfg=Bert4RecConfig(n_items=512, embed_dim=32, n_blocks=2,
                             n_heads=2, seq_len=16),
)
