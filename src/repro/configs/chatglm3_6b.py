"""chatglm3-6b [arXiv:2406.12793]: 28L d_model=4096 32H (GQA kv=2)
d_ff=13696 vocab=65024, 2d/partial RoPE (rope_pct=0.5), QKV bias."""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .families.lm import LMArch

ARCH = LMArch(
    arch_id="chatglm3-6b",
    base_cfg=LMConfig(
        name="chatglm3-6b", n_layers=28, d_model=4096, n_heads=32,
        n_kv_heads=2, d_head=128, d_ff=13696, vocab=65024, qkv_bias=True,
        rope_pct=0.5, tie_embeddings=False, dtype=jnp.bfloat16),
    smoke_cfg=LMConfig(
        name="chatglm3-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=128, qkv_bias=True,
        rope_pct=0.5, tie_embeddings=False, remat=False),
    long_ok=False,
)
