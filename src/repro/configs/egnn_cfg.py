"""egnn [arXiv:2102.09844]: 4 layers, d_hidden=64, E(n)-equivariant."""
from ..models.gnn.egnn import EGNNConfig
from .families.gnn import GNNArch

ARCH = GNNArch(
    arch_id="egnn",
    kind="egnn",
    full_cfg_fn=lambda d_feat: EGNNConfig(n_layers=4, d_hidden=64,
                                          d_in=d_feat),
    smoke_cfg_fn=lambda d_feat: EGNNConfig(n_layers=2, d_hidden=16,
                                           d_in=d_feat),
)
