"""Cell programs: the unit the dry-run lowers and the roofline reads.

A *cell* is one (architecture x input-shape) combination.  Each family
adapter builds a ``CellProgram``: a step function, abstract (ShapeDtypeStruct)
arguments, and PartitionSpec pytrees for the production mesh.  The same
machinery, with ``reduced=True``, yields a tiny concrete configuration that
the smoke tests actually execute on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from ...optim import adamw


@dataclasses.dataclass
class CellProgram:
    arch_id: str
    shape_id: str
    kind: str                      # train | prefill | decode | serve | retrieval
    step_fn: Callable              # positional-args function to lower
    abstract_args: Tuple           # pytrees of jax.ShapeDtypeStruct
    arg_specs: Tuple               # matching pytrees of PartitionSpec
    model_flops: float             # analytic useful FLOPs (6*N*D style)
    model_bytes: float             # analytic minimum HBM traffic (params+state)
    notes: str = ""
    # cost-probe support: XLA cost_analysis counts loop bodies once, so
    # probes lower loop-free variants and multiply by cost_scale (e.g. the
    # grad-accumulation factor, or the serve_bulk chunk count).
    cost_scale: float = 1.0


def dp(multipod: bool):
    """Data-parallel mesh axes (pod composes with data across pods)."""
    return ("pod", "data") if multipod else ("data",)


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def abstract_like(tree):
    return jax.tree.map(lambda x: sds(x.shape, x.dtype), tree)


def spec_tree(tree, fn):
    """Build a PartitionSpec pytree via fn(path_string, leaf)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(jax.tree_util.keystr(path), leaf), tree)


OPT_CFG = adamw.AdamWConfig(lr=1e-4, warmup_steps=200, total_steps=50_000)


def make_train_step(loss_fn, accum: bool):
    """Standard production train step: (params, m, v, step, *batch) ->
    (params, m, v, step, loss).  With ``accum`` the leading batch axis is
    scanned as microbatches (gradient accumulation)."""

    def step(params, m, v, stepno, *batch):
        if accum:
            def micro(c, mb):
                l, g = jax.value_and_grad(loss_fn)(params, *mb)
                return (c[0] + l, jax.tree.map(jnp.add, c[1], g)), None
            zero = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                params)
            n = jax.tree.leaves(batch)[0].shape[0]
            (loss, grads), _ = jax.lax.scan(micro, (jnp.float32(0), zero),
                                            batch)
            loss, grads = loss / n, jax.tree.map(lambda g: g / n, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, *batch)
        state = adamw.AdamWState(step=stepno, m=m, v=v)
        params, state, _ = adamw.update(OPT_CFG, grads, state, params)
        return params, state.m, state.v, state.step, loss

    return step


def opt_state_like(params_abs):
    f32 = lambda t: jax.tree.map(lambda s: sds(s.shape, jnp.float32), t)
    return f32(params_abs), f32(params_abs), sds((), jnp.int32)


def zeros_from_abstract(tree, seed: int = 0):
    """Materialize concrete arrays for smoke tests: small random floats,
    zeros for ints/bools (always-valid indices)."""
    key = jax.random.key(seed)
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for i, s in enumerate(leaves):
        if jnp.issubdtype(s.dtype, jnp.floating):
            # non-negative so optimizer second moments stay valid
            out.append(jnp.abs(jax.random.normal(
                jax.random.fold_in(key, i), s.shape, s.dtype)) * 0.05)
        else:
            out.append(jnp.zeros(s.shape, s.dtype))
    return treedef.unflatten(out)
