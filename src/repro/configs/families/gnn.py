"""GNN-family adapter: full-batch / sampled-minibatch / large-full-batch /
batched-molecule cell programs for the four assigned GNN architectures.

Tasks per shape (documented in DESIGN.md):
  * full_graph_sm / ogb_products: node-level prediction (classification for
    GAT, scalar regression for the equivariant nets) on one big graph;
  * minibatch_lg: same, on a fanout-sampled block (15-10), loss on seeds;
  * molecule: per-graph energy (+ forces for the equivariant nets) on a
    disjoint union of 128 small graphs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ...models.gnn import common, egnn, equivariant, gat
from .base import (CellProgram, dp, make_train_step, opt_state_like, sds,
                   spec_tree)

GNN_SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")


def _pad(x: int, mult: int = 512) -> int:
    return ((x + mult - 1) // mult) * mult


# (n_nodes, n_edges, d_feat, loss-node count) per shape, full scale
FULL_DIMS = dict(
    full_graph_sm=dict(N=_pad(2_708), E=_pad(10_556), d=1_433, seeds=2_708,
                       n_graphs=1),
    minibatch_lg=dict(N=_pad(1_024 + 15_360 + 153_600), E=_pad(168_960),
                      d=602, seeds=1_024, n_graphs=1),
    ogb_products=dict(N=_pad(2_449_029), E=_pad(61_859_140), d=100,
                      seeds=2_449_029, n_graphs=1),
    molecule=dict(N=_pad(30 * 128), E=_pad(64 * 128), d=16,
                  seeds=30 * 128, n_graphs=128),
)
REDUCED_DIMS = dict(
    full_graph_sm=dict(N=64, E=128, d=12, seeds=48, n_graphs=1),
    minibatch_lg=dict(N=64, E=128, d=12, seeds=16, n_graphs=1),
    ogb_products=dict(N=128, E=256, d=12, seeds=96, n_graphs=1),
    molecule=dict(N=64, E=128, d=8, seeds=64, n_graphs=8),
)


@dataclasses.dataclass
class GNNArch:
    arch_id: str
    kind: str                       # "gat" | "egnn" | "nequip" | "mace"
    full_cfg_fn: object             # callable(d_feat) -> model config
    smoke_cfg_fn: object
    family: str = "gnn"

    def shape_ids(self):
        return list(GNN_SHAPES)

    def skip_reason(self, shape_id: str) -> Optional[str]:
        return None

    # ------------------------------------------------------------------
    def build(self, shape_id: str, multipod: bool = False,
              reduced: bool = False, optimized: bool = False) -> CellProgram:
        dims = (REDUCED_DIMS if reduced else FULL_DIMS)[shape_id]
        N, E, d_feat = dims["N"], dims["E"], dims["d"]
        n_graphs = dims["n_graphs"]
        cfg = (self.smoke_cfg_fn if reduced else self.full_cfg_fn)(d_feat)
        axes = dp(multipod) + ("model",)      # flat device grid for graphs
        if optimized and self.kind in ("nequip", "mace"):
            cfg = dataclasses.replace(cfg, fused_agg=True, shard_axes=axes)

        g_abs = dict(senders=sds((E,), jnp.int32),
                     receivers=sds((E,), jnp.int32),
                     node_mask=sds((N,), jnp.bool_),
                     edge_mask=sds((E,), jnp.bool_),
                     graph_ids=sds((N,), jnp.int32))
        g_spec = dict(senders=P(axes), receivers=P(axes),
                      node_mask=P(axes), edge_mask=P(axes),
                      graph_ids=P(axes))

        def graph_of(g):
            return common.GraphData(g["senders"], g["receivers"],
                                    g["node_mask"], g["edge_mask"],
                                    g["graph_ids"], n_graphs)

        if self.kind == "gat":
            params_abs = jax.eval_shape(
                lambda: gat.init_params(cfg, jax.random.key(0)))

            if shape_id == "molecule":
                def loss(p, x, g, labels, mask):
                    gd = graph_of(g)
                    logits = gat.forward(cfg, p, x, gd)
                    glog = common.graph_readout(logits, gd.graph_ids,
                                                n_graphs, gd.node_mask,
                                                "mean").astype(jnp.float32)
                    logz = jax.nn.logsumexp(glog, axis=-1)
                    gold = jnp.take_along_axis(glog, labels[:, None],
                                               axis=-1)[:, 0]
                    return jnp.mean(logz - gold)
                labels_abs = sds((n_graphs,), jnp.int32)
                mask_abs = sds((n_graphs,), jnp.float32)
                lspec, mspec = P(), P()
            else:
                def loss(p, x, g, labels, mask):
                    return gat.loss(cfg, p, x, graph_of(g), labels, mask)
                labels_abs = sds((N,), jnp.int32)
                mask_abs = sds((N,), jnp.float32)
                lspec, mspec = P(axes), P(axes)

            x_abs = sds((N, d_feat), jnp.float32)
            x_spec = P(axes, None)
            n_params = sum(int(math.prod(l.shape))
                           for l in jax.tree.leaves(params_abs))
            flops = 4.0 * E * cfg.d_hidden * cfg.n_heads + \
                2.0 * N * d_feat * cfg.d_hidden * cfg.n_heads
            flops *= 3.0            # fwd + bwd
        else:
            params_abs = jax.eval_shape(
                lambda: _eq_init(self.kind, cfg, jax.random.key(0)))

            if self.kind == "egnn":
                def model_nodes(p, x, coords, g):
                    _, h, _ = egnn.forward(cfg, p, x, coords, graph_of(g))
                    return h
                def model_energy(p, x, coords, g):
                    e, _, _ = egnn.forward(cfg, p, x, coords, graph_of(g))
                    return e
                x_abs = sds((N, d_feat), jnp.float32)
                x_spec = P(axes, None)
                C = cfg.d_hidden
            else:
                def model_nodes(p, x, coords, g):
                    del coords
                    raise NotImplementedError
                def model_energy(p, species, coords, g):
                    return equivariant.forward(cfg, p, species, coords,
                                               graph_of(g))
                x_abs = sds((N,), jnp.int32)          # species ids
                x_spec = P(axes)
                C = cfg.channels

            if shape_id == "molecule":
                def loss(p, x, coords, g, e_tgt, f_tgt):
                    def efn(c):
                        return jnp.sum(model_energy(p, x, c, g))
                    e, negf = jax.value_and_grad(efn)(coords)
                    e_all = model_energy(p, x, coords, g)
                    return jnp.mean((e_all - e_tgt) ** 2) + \
                        0.1 * jnp.mean((-negf - f_tgt) ** 2)
                extra_abs = (sds((n_graphs,), jnp.float32),
                             sds((N, 3), jnp.float32))
                extra_spec = (P(), P(axes, None))
            else:
                def loss(p, x, coords, g, y_tgt, y_mask):
                    e = model_energy(p, x, coords, g)       # [n_graphs]
                    del y_mask
                    return jnp.mean((e - y_tgt) ** 2)
                extra_abs = (sds((n_graphs,), jnp.float32),
                             sds((n_graphs,), jnp.float32))
                extra_spec = (P(), P())

            coords_abs = sds((N, 3), jnp.float32)
            coords_spec = P(axes, None)
            n_params = sum(int(math.prod(l.shape))
                           for l in jax.tree.leaves(params_abs))
            flops = 3.0 * 2.0 * E * C * C * 15   # paths x channels, fwd+bwd

        step = make_train_step(loss, accum=False)
        m, v, st = opt_state_like(params_abs)
        pspec = spec_tree(params_abs, lambda path, leaf: P())

        if self.kind == "gat":
            args = (params_abs, m, v, st, x_abs, g_abs, labels_abs, mask_abs)
            specs = (pspec, pspec, pspec, P(), x_spec, g_spec, lspec, mspec)
        else:
            args = (params_abs, m, v, st, x_abs, coords_abs, g_abs) + extra_abs
            specs = (pspec, pspec, pspec, P(), x_spec, coords_spec,
                     g_spec) + extra_spec
        return CellProgram(self.arch_id, shape_id, "train", step, args,
                           specs, flops, 4.0 * 10.0 * n_params + 8.0 * E)


def _eq_init(kind, cfg, key):
    if kind == "egnn":
        return egnn.init_params(cfg, key)
    return equivariant.init_params(cfg, key)
