"""LM-family adapter: builds train/prefill/decode/long cell programs for
the five assigned transformer architectures."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ...models import transformer as T
from .base import (CellProgram, dp, make_train_step, opt_state_like,
                   sds, spec_tree)

LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


@dataclasses.dataclass(frozen=True)
class LMShapes:
    train_seq: int = 4096
    train_batch: int = 256
    grad_accum: int = 8
    prefill_seq: int = 32768
    prefill_batch: int = 32
    decode_seq: int = 32768
    decode_batch: int = 128
    long_seq: int = 524288
    long_batch: int = 1


@dataclasses.dataclass
class LMArch:
    arch_id: str
    base_cfg: T.LMConfig                 # full-size config (dtype bf16)
    smoke_cfg: T.LMConfig                # reduced config for CPU smoke
    long_ok: bool                        # sub-quadratic (SWA) => run long_500k
    kv_quant_decode: bool = False        # int8 KV for the huge caches
    shapes: LMShapes = dataclasses.field(default_factory=LMShapes)
    family: str = "lm"

    def shape_ids(self):
        return list(LM_SHAPES)

    def skip_reason(self, shape_id: str) -> Optional[str]:
        if shape_id == "long_500k" and not self.long_ok:
            return ("pure full-attention arch: 500k-token decode requires "
                    "sub-quadratic attention (assignment: skip + note)")
        return None

    # ------------------------------------------------------------------
    def _cfg(self, shape_id: str, reduced: bool,
             probe_layers: Optional[int] = None, multipod: bool = False,
             optimized: bool = False) -> T.LMConfig:
        cfg = self.smoke_cfg if reduced else self.base_cfg
        kw = {}
        if optimized:
            kw["dp_axes"] = dp(multipod)
        if shape_id in ("train_4k", "prefill_32k"):
            # 2048 at 32k keeps the unrolled block-pair count manageable
            kw["attn_chunk"] = 8 if reduced else \
                (2048 if shape_id == "prefill_32k" else 1024)
        if shape_id in ("decode_32k", "long_500k"):
            kw["decode_chunk"] = 16 if reduced else 2048
            if self.kv_quant_decode and shape_id == "decode_32k":
                kw["kv_quant_int8"] = True
        if probe_layers is not None:
            kw["n_layers"] = probe_layers
            kw["unroll"] = True
        return dataclasses.replace(cfg, **kw)

    def _dims(self, shape_id: str, reduced: bool) -> Dict[str, int]:
        s = self.shapes
        if reduced:
            return dict(train_seq=32, train_batch=8, grad_accum=2,
                        prefill_seq=64, prefill_batch=2, decode_seq=64,
                        decode_batch=4, long_seq=128, long_batch=1)
        return dict(train_seq=s.train_seq, train_batch=s.train_batch,
                    grad_accum=s.grad_accum, prefill_seq=s.prefill_seq,
                    prefill_batch=s.prefill_batch, decode_seq=s.decode_seq,
                    decode_batch=s.decode_batch, long_seq=s.long_seq,
                    long_batch=s.long_batch)

    # ------------------------------------------------------------------
    def build(self, shape_id: str, multipod: bool = False,
              reduced: bool = False,
              probe_layers: Optional[int] = None,
              optimized: bool = False) -> CellProgram:
        """probe_layers: build a loop-free cost probe at that layer count
        (and, for train, a single microbatch with cost_scale=grad_accum);
        the dry-run extrapolates HLO costs linearly in n_layers.
        optimized: beyond-paper sharding hints (EXPERIMENTS.md §Perf)."""
        cfg = self._cfg(shape_id, reduced, probe_layers, multipod, optimized)
        d = self._dims(shape_id, reduced)
        params_abs = jax.eval_shape(
            lambda: T.init_params(cfg, jax.random.key(0)))
        pspec = spec_tree(params_abs,
                          lambda path, leaf: _lm_param_spec(cfg, path, leaf))
        dpx = dp(multipod)

        if shape_id == "train_4k":
            A, B, S = d["grad_accum"], d["train_batch"], d["train_seq"]
            mb = B // A
            loss = lambda p, tok, tgt: T.lm_loss(cfg, p, tok, tgt)
            m, v, st = opt_state_like(params_abs)
            if probe_layers is not None:
                # one microbatch, loop-free; dry-run scales by A
                step = make_train_step(loss, accum=False)
                tok = sds((mb, S), jnp.int32)
                tok_spec = P(dpx, None)
                scale = float(A)
            else:
                step = make_train_step(loss, accum=True)
                tok = sds((A, mb, S), jnp.int32)
                tok_spec = P(None, dpx, None)
                scale = 1.0
            args = (params_abs, m, v, st, tok, tok)
            specs = (pspec, pspec, pspec, P(), tok_spec, tok_spec)
            n = self.base_cfg.n_active_params()
            flops = 6.0 * n * B * S
            return CellProgram(self.arch_id, shape_id, "train", step, args,
                               specs, flops, 10.0 * self.base_cfg.n_params(),
                               cost_scale=scale)

        mf_cfg = cfg if reduced else self.base_cfg   # model-flops reference

        if shape_id == "prefill_32k":
            B, S = d["prefill_batch"], d["prefill_seq"]

            def step(p, tok):
                logits, _ = T.forward(cfg, p, tok)
                return logits

            tok = sds((B, S), jnp.int32)
            args = (params_abs, tok)
            specs = (pspec, P(dpx, None))
            flops = 2.0 * mf_cfg.n_active_params() * B * S
            return CellProgram(self.arch_id, shape_id, "prefill", step, args,
                               specs, flops, 2.0 * mf_cfg.n_params())

        # decode cells lower serve_step: one token, existing KV cache
        B = d["decode_batch"] if shape_id == "decode_32k" else d["long_batch"]
        S = d["decode_seq"] if shape_id == "decode_32k" else d["long_seq"]
        cache_abs = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
        cache_spec = spec_tree(
            cache_abs, lambda path, leaf: _cache_spec(path, leaf, dpx, B))

        def step(p, cache, token, pos):
            return T.decode_step(cfg, p, cache, token, pos)

        args = (params_abs, cache_abs, sds((B,), jnp.int32),
                sds((B,), jnp.int32))
        bspec = P(dpx) if B > 1 else P()
        specs = (pspec, cache_spec, bspec, bspec)
        flops = 2.0 * mf_cfg.n_active_params() * B + \
            2.0 * 2 * mf_cfg.n_layers * mf_cfg.n_kv_heads * mf_cfg.d_head * \
            B * min(S, T.cache_len(mf_cfg, S)) * \
            (mf_cfg.n_heads // mf_cfg.n_kv_heads)
        kind = "decode" if shape_id == "decode_32k" else "long_decode"
        return CellProgram(self.arch_id, shape_id, kind, step, args, specs,
                           flops, 2.0 * cfg.n_params())


def _lm_param_spec(cfg: T.LMConfig, path: str, leaf) -> P:
    """FSDP(d_model->data) x TP(heads/ffn/vocab->model); MoE experts on
    model when divisible.  Pod axis left unmentioned => pure DP across pods.
    """
    nd = len(leaf.shape)
    if "embed" in path or "lm_head" in path:
        return P("model", None) if nd == 2 else P()
    if nd <= 2:                    # ln scales, biases [L, d]/[L, h*dh]
        return P()
    if "router" in path:           # [L, d, E]
        return P(None, "data", None)
    if nd == 4:                    # MoE experts [L, E, d, ffe] / [L, E, ffe, d]
        if cfg.n_experts % 16 == 0:
            return P(None, "model", "data", None)
        return P(None, None, "data", "model") if "w2" not in path else \
            P(None, None, "model", "data")
    # [L, d, out] projections: shard d on data (FSDP), out on model (TP)
    if "wo" in path or "w2" in path:
        return P(None, "model", "data")
    return P(None, "data", "model")


def _cache_spec(path: str, leaf, dpx, batch: int) -> P:
    bs = dpx if batch > 1 else None
    nd = len(leaf.shape)
    if nd == 5:                    # k/v [L, B, T, H, dh]
        return P(None, bs, "model", None, None)
    if nd == 4:                    # scales [L, B, T, H]
        return P(None, bs, "model", None)
    if nd == 3:                    # pos [L, B, T]
        return P(None, bs, "model")
    return P()
