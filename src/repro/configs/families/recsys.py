"""RecSys-family adapter: bert4rec train/serve/bulk/retrieval cells."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ...models import bert4rec as B
from .base import (CellProgram, dp, make_train_step, opt_state_like, sds,
                   spec_tree)

RECSYS_SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")

FULL = dict(train_batch=dict(batch=65_536, n_mask=20, n_neg=8_192),
            serve_p99=dict(batch=512),
            serve_bulk=dict(batch=262_144, topk=100, chunk=4_096),
            retrieval_cand=dict(n_cand=1_000_000))
REDUCED = dict(train_batch=dict(batch=8, n_mask=4, n_neg=32),
               serve_p99=dict(batch=4),
               serve_bulk=dict(batch=16, topk=8, chunk=8),
               retrieval_cand=dict(n_cand=64))


@dataclasses.dataclass
class RecsysArch:
    arch_id: str
    full_cfg: B.Bert4RecConfig
    smoke_cfg: B.Bert4RecConfig
    family: str = "recsys"

    def shape_ids(self):
        return list(RECSYS_SHAPES)

    def skip_reason(self, shape_id: str) -> Optional[str]:
        return None

    def build(self, shape_id: str, multipod: bool = False,
              reduced: bool = False, probe: bool = False,
              optimized: bool = False) -> CellProgram:
        """probe: loop-free cost variant — serve_bulk lowers ONE scoring
        chunk with cost_scale = n_chunks (everything else is loop-free
        already; encode is unrolled).
        optimized: two-stage sharded top-k (EXPERIMENTS.md §Perf)."""
        cfg = self.smoke_cfg if reduced else self.full_cfg
        if optimized:
            import dataclasses as _dc
            cfg = _dc.replace(cfg, topk_ways=16)
        dims = dict((REDUCED if reduced else FULL)[shape_id])
        cost_scale = 1.0
        if probe and shape_id == "serve_bulk":
            cost_scale = dims["batch"] / dims["chunk"]
            dims["batch"] = dims["chunk"]
        dpx = dp(multipod)
        params_abs = jax.eval_shape(
            lambda: B.init_params(cfg, jax.random.key(0)))
        pspec = spec_tree(params_abs, _param_spec)
        n_params = sum(int(math.prod(l.shape))
                       for l in jax.tree.leaves(params_abs))

        if shape_id == "train_batch":
            bsz, M, n_neg = dims["batch"], dims["n_mask"], dims["n_neg"]

            def loss(p, items, mpos, tgt, neg):
                return B.sampled_masked_loss(cfg, p, items, mpos, tgt, neg)

            step = make_train_step(loss, accum=False)
            m, v, st = opt_state_like(params_abs)
            args = (params_abs, m, v, st,
                    sds((bsz, cfg.seq_len), jnp.int32),
                    sds((bsz, M), jnp.int32), sds((bsz, M), jnp.int32),
                    sds((dims["n_neg"],), jnp.int32))
            specs = (pspec, pspec, pspec, P(), P(dpx, None), P(dpx, None),
                     P(dpx, None), P())
            # transformer flops + embedding/negatives scoring, fwd+bwd
            per_block = 12 * cfg.embed_dim ** 2
            flops = 3.0 * bsz * cfg.seq_len * cfg.n_blocks * per_block * 2 + \
                3.0 * 2.0 * bsz * M * n_neg * cfg.embed_dim
            return CellProgram(self.arch_id, shape_id, "train", step, args,
                               specs, flops, 10.0 * n_params)

        if shape_id in ("serve_p99", "serve_bulk"):
            bsz = dims["batch"]
            if shape_id == "serve_p99":
                def step(p, items):
                    return B.score_next(cfg, p, items)
            else:
                topk, chunk = dims["topk"], dims["chunk"]

                def step(p, items):
                    return B.score_topk(cfg, p, items, k=topk, chunk=chunk)

            args = (params_abs, sds((bsz, cfg.seq_len), jnp.int32))
            specs = (pspec, P(dpx, None))
            per_block = 12 * cfg.embed_dim ** 2
            full_b = (REDUCED if reduced else FULL)[shape_id]["batch"]
            flops = full_b * cfg.seq_len * cfg.n_blocks * per_block * 2 + \
                2.0 * full_b * cfg.n_items * cfg.embed_dim
            return CellProgram(self.arch_id, shape_id, "serve", step, args,
                               specs, flops, 2.0 * n_params,
                               cost_scale=cost_scale)

        # retrieval_cand: one query against n_cand candidates
        n_cand = dims["n_cand"]

        def step(p, items, cands):
            return B.score_candidates(cfg, p, items, cands)

        args = (params_abs, sds((1, cfg.seq_len), jnp.int32),
                sds((n_cand,), jnp.int32))
        # 1e6 candidates: shard on "model" only (divisible: 1e6/16);
        # the flat device grid (256/512-way) does not divide 1e6
        specs = (pspec, P(), P("model"))
        flops = 2.0 * n_cand * cfg.embed_dim + \
            cfg.seq_len * cfg.n_blocks * 12 * cfg.embed_dim ** 2 * 2
        return CellProgram(self.arch_id, shape_id, "retrieval", step, args,
                           specs, flops, 8.0 * n_cand * cfg.embed_dim)


def _param_spec(path: str, leaf) -> P:
    if "item_embed" in path:
        return P("model", None)       # 1M rows sharded over model axis
    return P()                        # d=64 blocks: replicate
