"""gat-cora [arXiv:1710.10903]: 2 layers, d_hidden=8, 8 heads, attention
aggregator (Cora: 2708 nodes / 10556 edges / 1433 features / 7 classes)."""
from ..models.gnn.gat import GATConfig
from .families.gnn import GNNArch

ARCH = GNNArch(
    arch_id="gat-cora",
    kind="gat",
    full_cfg_fn=lambda d_feat: GATConfig(n_layers=2, d_in=d_feat,
                                         d_hidden=8, n_heads=8,
                                         n_classes=47 if d_feat == 100 else 7),
    smoke_cfg_fn=lambda d_feat: GATConfig(n_layers=2, d_in=d_feat,
                                          d_hidden=4, n_heads=2,
                                          n_classes=5),
)
