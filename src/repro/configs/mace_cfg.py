"""mace [arXiv:2206.07697]: 2 layers, 128 channels, l_max=2,
correlation order 3, n_rbf=8, E(3)-ACE higher-order message passing."""
from ..models.gnn.equivariant import EquivariantConfig
from .families.gnn import GNNArch

ARCH = GNNArch(
    arch_id="mace",
    kind="mace",
    full_cfg_fn=lambda d_feat: EquivariantConfig(
        arch="mace", n_layers=2, channels=128, l_max=2, n_rbf=8,
        correlation=3, cutoff=5.0, n_species=64),
    smoke_cfg_fn=lambda d_feat: EquivariantConfig(
        arch="mace", n_layers=1, channels=8, l_max=2, n_rbf=4,
        correlation=2, cutoff=3.0, n_species=8),
)
