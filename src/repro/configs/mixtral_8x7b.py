"""mixtral-8x7b [arXiv:2401.04088]: 32L d_model=4096 32H (kv=8) MoE 8e
top-2, d_ff=14336, vocab=32000, sliding-window attention (4096)."""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .families.lm import LMArch

ARCH = LMArch(
    arch_id="mixtral-8x7b",
    base_cfg=LMConfig(
        name="mixtral-8x7b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_head=128, d_ff=0, vocab=32000, qkv_bias=False,
        sliding_window=4096, n_experts=8, top_k=2, d_ff_expert=14336,
        tie_embeddings=False, dtype=jnp.bfloat16),
    smoke_cfg=LMConfig(
        name="mixtral-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=0, vocab=128, sliding_window=16,
        n_experts=4, top_k=2, d_ff_expert=32, capacity_factor=4.0,
        tie_embeddings=False, remat=False),
    long_ok=True,    # SWA => O(window) per decoded token; ring KV cache
)
