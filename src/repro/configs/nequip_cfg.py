"""nequip [arXiv:2101.03164]: 5 layers, d_hidden=32, l_max=2, n_rbf=8,
cutoff=5, E(3) tensor-product convolutions."""
from ..models.gnn.equivariant import EquivariantConfig
from .families.gnn import GNNArch

ARCH = GNNArch(
    arch_id="nequip",
    kind="nequip",
    full_cfg_fn=lambda d_feat: EquivariantConfig(
        arch="nequip", n_layers=5, channels=32, l_max=2, n_rbf=8,
        correlation=1, cutoff=5.0, n_species=64),
    smoke_cfg_fn=lambda d_feat: EquivariantConfig(
        arch="nequip", n_layers=2, channels=8, l_max=2, n_rbf=4,
        correlation=1, cutoff=3.0, n_species=8),
)
