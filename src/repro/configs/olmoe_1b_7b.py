"""olmoe-1b-7b [arXiv:2409.02060]: 16L d_model=2048 16H (kv=16) MoE 64e
top-8, d_ff(expert)=1024, vocab=50304."""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .families.lm import LMArch

ARCH = LMArch(
    arch_id="olmoe-1b-7b",
    base_cfg=LMConfig(
        name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16,
        n_kv_heads=16, d_head=128, d_ff=0, vocab=50304, qkv_bias=False,
        n_experts=64, top_k=8, d_ff_expert=1024, tie_embeddings=False,
        dtype=jnp.bfloat16),
    smoke_cfg=LMConfig(
        name="olmoe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_head=16, d_ff=0, vocab=128, n_experts=8, top_k=2, d_ff_expert=32,
        capacity_factor=4.0, tie_embeddings=False, remat=False),
    long_ok=False,   # pure full attention -> long_500k skipped (DESIGN.md)
)
