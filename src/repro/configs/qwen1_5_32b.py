"""qwen1.5-32b [hf:Qwen/Qwen1.5-32B]: 64L d_model=5120 40H (kv=40, i.e.
MHA) d_ff=27392 vocab=152064, QKV bias.  decode_32k uses int8 KV quant —
the bf16 cache (5.5 TB global) exceeds a single v5e pod's HBM; int8 + ring
sharding fits (DESIGN.md / EXPERIMENTS.md §Dry-run)."""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .families.lm import LMArch

ARCH = LMArch(
    arch_id="qwen1.5-32b",
    base_cfg=LMConfig(
        name="qwen1.5-32b", n_layers=64, d_model=5120, n_heads=40,
        n_kv_heads=40, d_head=128, d_ff=27392, vocab=152064, qkv_bias=True,
        tie_embeddings=False, dtype=jnp.bfloat16),
    smoke_cfg=LMConfig(
        name="qwen32b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=128, vocab=128, qkv_bias=True,
        tie_embeddings=False, remat=False),
    long_ok=False,
    kv_quant_decode=True,
)
