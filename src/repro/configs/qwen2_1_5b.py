"""qwen2-1.5b [arXiv:2407.10671]: 28L d_model=1536 12H (GQA kv=2)
d_ff=8960 vocab=151936, QKV bias, tied embeddings."""
import jax.numpy as jnp

from ..models.transformer import LMConfig
from .families.lm import LMArch

ARCH = LMArch(
    arch_id="qwen2-1.5b",
    base_cfg=LMConfig(
        name="qwen2-1.5b", n_layers=28, d_model=1536, n_heads=12,
        n_kv_heads=2, d_head=128, d_ff=8960, vocab=151936, qkv_bias=True,
        tie_embeddings=True, dtype=jnp.bfloat16),
    smoke_cfg=LMConfig(
        name="qwen2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab=128, qkv_bias=True, tie_embeddings=True,
        remat=False),
    long_ok=False,
)
