"""The paper's primary contribution: distributed (bounded / regular)
reachability queries via partial evaluation, with performance guarantees.

Beyond the paper (DESIGN.md Secs. 3 & 5): an amortized rvset cache splits
localEval into a once-per-Fragmentation closure phase and a cheap per-query
phase, and a :class:`~repro.core.session.QuerySession`
(``repro.connect(fr)``) plans heterogeneous reach+dist+RPQ batches into
fused fixed-shape executions — one compiled program per (kind, automaton)
group.  The seed ``dis_*`` free functions are shims over default
sessions; the PR-4-deprecated cache-bearing ``dis_*_cached`` /
``dis_*_batch`` shims were removed in PR 8 (use a session).
"""
from .api import dis_dist, dis_reach, dis_rpq, dis_rpq_regex
from .automaton import QueryAutomaton, accepts, build_query_automaton
from .cache import RvsetCache, get_rvset_cache, prepare_rvset_cache
from .engine import INF, QueryStats
from .fragments import (DeltaReport, Fragmentation, GraphDelta, Placement,
                        fragment_graph, query_slots)
from .incremental import UpdateStats, apply_delta
from .plan import (Dist, ExecutionGroup, Query, QueryPlan, QueryResult,
                   Reach, Rpq)
from .session import QuerySession, SessionStats, connect

__all__ = [
    "QueryResult", "dis_dist", "dis_reach", "dis_rpq", "dis_rpq_regex",
    "RvsetCache", "prepare_rvset_cache", "get_rvset_cache",
    "QueryAutomaton", "accepts", "build_query_automaton",
    "INF", "QueryStats", "Fragmentation", "fragment_graph", "query_slots",
    "GraphDelta", "DeltaReport", "Placement", "apply_delta", "UpdateStats",
    "Reach", "Dist", "Rpq", "Query", "QueryPlan", "ExecutionGroup",
    "QuerySession", "SessionStats", "connect",
]
