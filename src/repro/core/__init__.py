"""The paper's primary contribution: distributed (bounded / regular)
reachability queries via partial evaluation, with performance guarantees.

Beyond the paper (DESIGN.md Secs. 3 & 5): an amortized rvset cache splits
localEval into a once-per-Fragmentation closure phase and a cheap per-query
phase, and a :class:`~repro.core.session.QuerySession`
(``repro.connect(fr)``) plans heterogeneous reach+dist+RPQ batches into
fused fixed-shape executions — one compiled program per (kind, automaton)
group.  The ``dis_*`` free functions are shims over default sessions.
"""
from .api import (QueryResult, dis_dist, dis_dist_batch, dis_dist_cached,
                  dis_reach, dis_reach_batch, dis_reach_cached, dis_rpq,
                  dis_rpq_batch, dis_rpq_cached, dis_rpq_regex)
from .automaton import QueryAutomaton, accepts, build_query_automaton
from .cache import RvsetCache, get_rvset_cache, prepare_rvset_cache
from .engine import INF, QueryStats
from .fragments import (DeltaReport, Fragmentation, GraphDelta, Placement,
                        fragment_graph, query_slots)
from .incremental import UpdateStats, apply_delta
from .plan import Dist, ExecutionGroup, Query, QueryPlan, Reach, Rpq
from .session import QuerySession, SessionStats, connect

__all__ = [
    "QueryResult", "dis_dist", "dis_reach", "dis_rpq", "dis_rpq_regex",
    "dis_reach_batch", "dis_dist_batch", "dis_rpq_batch",
    "dis_reach_cached", "dis_dist_cached", "dis_rpq_cached",
    "RvsetCache", "prepare_rvset_cache", "get_rvset_cache",
    "QueryAutomaton", "accepts", "build_query_automaton",
    "INF", "QueryStats", "Fragmentation", "fragment_graph", "query_slots",
    "GraphDelta", "DeltaReport", "Placement", "apply_delta", "UpdateStats",
    "Reach", "Dist", "Rpq", "Query", "QueryPlan", "ExecutionGroup",
    "QuerySession", "SessionStats", "connect",
]
