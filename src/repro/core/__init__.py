"""The paper's primary contribution: distributed (bounded / regular)
reachability queries via partial evaluation, with performance guarantees."""
from .api import QueryResult, dis_dist, dis_reach, dis_rpq, dis_rpq_regex
from .automaton import QueryAutomaton, accepts, build_query_automaton
from .engine import INF, QueryStats
from .fragments import Fragmentation, fragment_graph, query_slots

__all__ = [
    "QueryResult", "dis_dist", "dis_reach", "dis_rpq", "dis_rpq_regex",
    "QueryAutomaton", "accepts", "build_query_automaton",
    "INF", "QueryStats", "Fragmentation", "fragment_graph", "query_slots",
]
