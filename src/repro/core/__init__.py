"""The paper's primary contribution: distributed (bounded / regular)
reachability queries via partial evaluation, with performance guarantees.

Beyond the paper (DESIGN.md Sec. 3): an amortized rvset cache splits
localEval into a once-per-Fragmentation closure phase and a cheap per-query
phase, with batched multi-query entry points for serving workloads.
"""
from .api import (QueryResult, dis_dist, dis_dist_batch, dis_dist_cached,
                  dis_reach, dis_reach_batch, dis_reach_cached, dis_rpq,
                  dis_rpq_cached, dis_rpq_regex)
from .automaton import QueryAutomaton, accepts, build_query_automaton
from .cache import RvsetCache, get_rvset_cache, prepare_rvset_cache
from .engine import INF, QueryStats
from .fragments import (DeltaReport, Fragmentation, GraphDelta,
                        fragment_graph, query_slots)
from .incremental import UpdateStats, apply_delta

__all__ = [
    "QueryResult", "dis_dist", "dis_reach", "dis_rpq", "dis_rpq_regex",
    "dis_reach_batch", "dis_dist_batch",
    "dis_reach_cached", "dis_dist_cached", "dis_rpq_cached",
    "RvsetCache", "prepare_rvset_cache", "get_rvset_cache",
    "QueryAutomaton", "accepts", "build_query_automaton",
    "INF", "QueryStats", "Fragmentation", "fragment_graph", "query_slots",
    "GraphDelta", "DeltaReport", "apply_delta", "UpdateStats",
]
