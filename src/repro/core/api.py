"""Public query API: disReach, disDist, disRPQ (paper Figs. 3-7).

Single-host evaluation: the fragment axis is vmapped (every fragment's
localEval runs as one SPMD program — identical math to the shard_map
multi-device engine in ``distributed.py``, which is used on real meshes).

Answer extraction (coordinator side):
  * source row  = reserved row B-2 (s), in automaton state u_s for disRPQ;
  * target cols = reserved col B-1 (t arrivals internal to t's fragment)
                  plus the alias col b_index[t] when t itself is a boundary
                  in-node (arrivals via a cross edge landing exactly on t).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import cache as _cache
from . import engine
from .automaton import QueryAutomaton, build_query_automaton
from .cache import dis_dist_batch, dis_reach_batch
from .engine import INF, QueryStats
from .fragments import Fragmentation, fragment_graph, query_slots

__all__ = [      # including the batched entry points re-exported from .cache
    "QueryResult", "dis_reach", "dis_dist", "dis_rpq", "dis_rpq_regex",
    "dis_reach_batch", "dis_dist_batch",
    "dis_reach_cached", "dis_dist_cached", "dis_rpq_cached",
    "QueryAutomaton", "build_query_automaton",
    "Fragmentation", "fragment_graph", "query_slots", "INF", "QueryStats",
]


def _as_jnp(fr: Fragmentation):
    return {k: jnp.asarray(v) for k, v in fr.arrays.items()}


def _tgt_cols(fr: Fragmentation, t: int) -> jnp.ndarray:
    B = fr.B
    cols = np.zeros(B, dtype=bool)
    cols[fr.T_COL] = True
    bt = fr.b_index[t]
    if bt >= 0:
        cols[bt] = True
    return jnp.asarray(cols)


def _src_rows(fr: Fragmentation) -> jnp.ndarray:
    rows = np.zeros(fr.B, dtype=bool)
    rows[fr.S_ROW] = True
    return jnp.asarray(rows)


@dataclasses.dataclass
class QueryResult:
    answer: bool
    distance: Optional[int]
    stats: QueryStats
    dependency_matrix: Optional[np.ndarray] = None


# ---------------------------------------------------------------------------
# disReach (paper Fig. 3)
# ---------------------------------------------------------------------------

def dis_reach(fr: Fragmentation, s: int, t: int,
              return_matrix: bool = False) -> QueryResult:
    if s == t:
        return QueryResult(True, 0, QueryStats(0, 0, fr.B, 1))
    arrs = _as_jnp(fr)
    qs = query_slots(fr, s, t)
    local = jax.vmap(
        lambda es, ed, sl, sr, tl, sloc, tloc: engine.local_eval_reach(
            es, ed, sl, sr, tl, sloc, tloc, n_max=fr.n_max, B=fr.B))
    rlocs = local(arrs["esrc"], arrs["edst"], arrs["src_local"],
                  arrs["src_row"], arrs["tgt_local"],
                  jnp.asarray(qs["s_local"]), jnp.asarray(qs["t_local"]))
    D = jnp.any(rlocs, axis=0)                 # assemble (the one collective)
    ans = engine.evaldg_reach(D, _src_rows(fr), _tgt_cols(fr, t))
    stats = QueryStats(payload_bits=fr.packed_traffic_bits(),
                       collective_rounds=1, boundary=fr.B, states=1)
    return QueryResult(bool(ans), None, stats,
                       np.asarray(D) if return_matrix else None)


# ---------------------------------------------------------------------------
# disDist (paper Sec. 4)
# ---------------------------------------------------------------------------

def dis_dist(fr: Fragmentation, s: int, t: int,
             bound: Optional[int] = None) -> QueryResult:
    """Bounded reachability q_br(s, t, l); with bound=None returns exact
    dist(s, t) (INF -> unreachable -> distance None)."""
    if s == t:
        ok = bound is None or 0 <= bound
        return QueryResult(ok, 0, QueryStats(0, 0, fr.B, 1))
    cap = jnp.int32(bound) if bound is not None else INF
    arrs = _as_jnp(fr)
    qs = query_slots(fr, s, t)
    local = jax.vmap(
        lambda es, ed, sl, sr, tl, sloc, tloc: engine.local_eval_dist(
            es, ed, sl, sr, tl, sloc, tloc, cap, n_max=fr.n_max, B=fr.B))
    wlocs = local(arrs["esrc"], arrs["edst"], arrs["src_local"],
                  arrs["src_row"], arrs["tgt_local"],
                  jnp.asarray(qs["s_local"]), jnp.asarray(qs["t_local"]))
    W = jnp.min(wlocs, axis=0)
    d = engine.evaldg_dist(W, _src_rows(fr), _tgt_cols(fr, t))
    d = int(d)
    reachable = d < int(INF)
    answer = reachable if bound is None else (reachable and d <= bound)
    stats = QueryStats(payload_bits=fr.B * fr.B * 32, collective_rounds=1,
                       boundary=fr.B, states=1)
    # a failed bounded query reports no distance: with the propagation
    # capped at the bound, d is not the true distance past it (local
    # segments longer than the cap were pruned), so don't surface it
    return QueryResult(answer, d if (reachable and answer) else None, stats)


# ---------------------------------------------------------------------------
# disRPQ (paper Sec. 5)
# ---------------------------------------------------------------------------

def dis_rpq(fr: Fragmentation, s: int, t: int, qa: QueryAutomaton,
            return_matrix: bool = False) -> QueryResult:
    if s == t:
        return QueryResult(bool(qa.nullable), 0,
                           QueryStats(0, 0, fr.B, qa.n_states))
    Q = qa.n_states
    arrs = _as_jnp(fr)
    qs = query_slots(fr, s, t)
    q_labels = jnp.asarray(qa.state_labels)
    q_trans = jnp.asarray(qa.trans)
    local = jax.vmap(
        lambda es, ed, sl, sr, tl, lab, gid, sloc, tloc:
        engine.local_eval_regular(es, ed, sl, sr, tl, lab, gid,
                                  q_labels, q_trans, sloc, tloc,
                                  jnp.int32(s), jnp.int32(t),
                                  n_max=fr.n_max, B=fr.B))
    rlocs = local(arrs["esrc"], arrs["edst"], arrs["src_local"],
                  arrs["src_row"], arrs["tgt_local"], arrs["labels"],
                  arrs["gids"],
                  jnp.asarray(qs["s_local"]), jnp.asarray(qs["t_local"]))
    D = jnp.any(rlocs, axis=0)                  # [(B*Q), (B*Q)]

    src_rows = np.zeros(fr.B * Q, dtype=bool)
    src_rows[fr.S_ROW * Q + qa.start] = True
    tgt_cols = np.zeros(fr.B * Q, dtype=bool)
    tgt_cols[fr.T_COL * Q + qa.final] = True
    bt = fr.b_index[t]
    if bt >= 0:
        tgt_cols[bt * Q + qa.final] = True
    ans = engine.evaldg_reach(D, jnp.asarray(src_rows), jnp.asarray(tgt_cols))
    stats = QueryStats(payload_bits=fr.packed_traffic_bits(states=Q),
                       collective_rounds=1, boundary=fr.B, states=Q)
    return QueryResult(bool(ans), None, stats,
                       np.asarray(D) if return_matrix else None)


def dis_rpq_regex(fr: Fragmentation, s: int, t: int, regex: str,
                  **kw) -> QueryResult:
    g = fr.g
    if g.label_names is not None:
        qa = build_query_automaton(regex, g.label_of)
    else:
        qa = build_query_automaton(regex, lambda name: int(name))
    return dis_rpq(fr, s, t, qa, **kw)


# ---------------------------------------------------------------------------
# amortized-cache paths (core.cache): same answers, repeated queries cheap
# ---------------------------------------------------------------------------

def dis_reach_cached(fr: Fragmentation, s: int, t: int) -> QueryResult:
    """disReach against the rvset cache (built on first use).  The warm
    per-query cost is one single-source propagation + one or-and
    vector-matrix product instead of a full localEval."""
    if s == t:
        return QueryResult(True, 0, QueryStats(0, 0, fr.B, 1))
    ans = _cache.reach_cached(fr, s, t)
    stats = QueryStats(payload_bits=fr.packed_traffic_bits(),
                       collective_rounds=1, boundary=fr.B, states=1)
    return QueryResult(bool(ans), None, stats)


def dis_dist_cached(fr: Fragmentation, s: int, t: int,
                    bound: Optional[int] = None) -> QueryResult:
    if s == t:
        ok = bound is None or 0 <= bound
        return QueryResult(ok, 0, QueryStats(0, 0, fr.B, 1))
    d = _cache.dist_cached(fr, s, t)
    reachable = d is not None
    answer = reachable if bound is None else (reachable and d <= bound)
    # match the seed path: a bounded query that fails reports no distance
    # (dis_dist caps propagation at the bound, so it never sees the value)
    if bound is not None and not answer:
        d = None
    stats = QueryStats(payload_bits=fr.B * fr.B * 32, collective_rounds=1,
                       boundary=fr.B, states=1)
    return QueryResult(answer, d, stats)


def dis_rpq_cached(fr: Fragmentation, s: int, t: int,
                   qa: QueryAutomaton) -> QueryResult:
    if s == t:
        return QueryResult(bool(qa.nullable), 0,
                           QueryStats(0, 0, fr.B, qa.n_states))
    ans = _cache.rpq_cached(fr, s, t, qa)
    stats = QueryStats(payload_bits=fr.packed_traffic_bits(states=qa.n_states),
                       collective_rounds=1, boundary=fr.B, states=qa.n_states)
    return QueryResult(bool(ans), None, stats)
