"""Legacy free-function query API, re-expressed as thin shims over
per-fragmentation default sessions (DESIGN.md Sec. 5).

The one engine lives behind :func:`repro.connect` /
:class:`repro.core.session.QuerySession`; these entry points survive for
callers of the original API:

* ``dis_reach`` / ``dis_dist`` / ``dis_rpq`` / ``dis_rpq_regex`` — the
  paper's one-shot algorithms (Figs. 3-7); they run on the uncached default
  session (full localEval + evalDG per query, no state left behind).

The cache-bearing ``dis_*_cached`` / ``dis_*_batch`` shims that lived
here were deprecated in PR 4 and removed in PR 8: hold a session
(``repro.connect(fr)``) and ``run()`` mixed batches instead.  (The
internal fused-batch engines keep their homes in
:mod:`repro.core.cache`.)
"""
from __future__ import annotations

from typing import Optional

from .automaton import QueryAutomaton, build_query_automaton
from .engine import INF, QueryStats
from .fragments import Fragmentation, fragment_graph, query_slots
from .plan import Dist, QueryResult, Reach, Rpq
from .session import connect, default_session

__all__ = [
    "QueryResult", "dis_reach", "dis_dist", "dis_rpq", "dis_rpq_regex",
    "QueryAutomaton", "build_query_automaton", "connect",
    "Fragmentation", "fragment_graph", "query_slots", "INF", "QueryStats",
]


# ---------------------------------------------------------------------------
# one-shot paths (paper Figs. 3-7): uncached default session
# ---------------------------------------------------------------------------

def dis_reach(fr: Fragmentation, s: int, t: int,
              return_matrix: bool = False) -> QueryResult:
    q = Reach(int(s), int(t), return_matrix=return_matrix)
    return default_session(fr, cache="none").run([q])[0]


def dis_dist(fr: Fragmentation, s: int, t: int,
             bound: Optional[int] = None) -> QueryResult:
    """Bounded reachability q_br(s, t, l); with bound=None returns exact
    dist(s, t) (INF -> unreachable -> distance None)."""
    q = Dist(int(s), int(t), bound=bound)
    return default_session(fr, cache="none").run([q])[0]


def dis_rpq(fr: Fragmentation, s: int, t: int, qa: QueryAutomaton,
            return_matrix: bool = False) -> QueryResult:
    q = Rpq(int(s), int(t), automaton=qa, return_matrix=return_matrix)
    return default_session(fr, cache="none").run([q])[0]


def dis_rpq_regex(fr: Fragmentation, s: int, t: int, regex: str,
                  **kw) -> QueryResult:
    g = fr.g
    if g.label_names is not None:
        qa = build_query_automaton(regex, g.label_of)
    else:
        qa = build_query_automaton(regex, lambda name: int(name))
    return dis_rpq(fr, s, t, qa, **kw)
