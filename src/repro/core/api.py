"""Legacy free-function query API, re-expressed as thin shims over
per-fragmentation default sessions (DESIGN.md Sec. 5).

The one engine lives behind :func:`repro.connect` /
:class:`repro.core.session.QuerySession`; these entry points survive for
callers of the original API:

* ``dis_reach`` / ``dis_dist`` / ``dis_rpq`` / ``dis_rpq_regex`` — the
  paper's one-shot algorithms (Figs. 3-7); they run on the uncached default
  session (full localEval + evalDG per query, no state left behind).
* ``dis_*_cached`` / ``dis_*_batch`` — the amortized-cache entry points;
  they run on the cached default session and emit a
  ``DeprecationWarning``: new code should hold a session and ``run()``
  mixed batches instead (repro-internal modules are forbidden from calling
  them — the test suite escalates their warnings to errors inside
  ``repro.*``).
"""
from __future__ import annotations

import warnings
from typing import Optional

import numpy as np

from .automaton import QueryAutomaton, build_query_automaton
from .cache import _as_pairs
from .engine import INF, QueryStats
from .fragments import Fragmentation, fragment_graph, query_slots
from .plan import Dist, QueryResult, Reach, Rpq
from .session import connect, default_session

__all__ = [
    "QueryResult", "dis_reach", "dis_dist", "dis_rpq", "dis_rpq_regex",
    "dis_reach_batch", "dis_dist_batch", "dis_rpq_batch",
    "dis_reach_cached", "dis_dist_cached", "dis_rpq_cached",
    "QueryAutomaton", "build_query_automaton", "connect",
    "Fragmentation", "fragment_graph", "query_slots", "INF", "QueryStats",
]


def _warn_deprecated(name: str, hint: str) -> None:
    # stacklevel=3 attributes the warning to whoever called the shim, so
    # the repro.* -> error filter in pyproject catches internal callers
    warnings.warn(
        f"repro.core.{name} is deprecated: open a session with "
        f"repro.connect(fr) and {hint}", DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# one-shot paths (paper Figs. 3-7): uncached default session
# ---------------------------------------------------------------------------

def dis_reach(fr: Fragmentation, s: int, t: int,
              return_matrix: bool = False) -> QueryResult:
    q = Reach(int(s), int(t), return_matrix=return_matrix)
    return default_session(fr, cache="none").run([q])[0]


def dis_dist(fr: Fragmentation, s: int, t: int,
             bound: Optional[int] = None) -> QueryResult:
    """Bounded reachability q_br(s, t, l); with bound=None returns exact
    dist(s, t) (INF -> unreachable -> distance None)."""
    q = Dist(int(s), int(t), bound=bound)
    return default_session(fr, cache="none").run([q])[0]


def dis_rpq(fr: Fragmentation, s: int, t: int, qa: QueryAutomaton,
            return_matrix: bool = False) -> QueryResult:
    q = Rpq(int(s), int(t), automaton=qa, return_matrix=return_matrix)
    return default_session(fr, cache="none").run([q])[0]


def dis_rpq_regex(fr: Fragmentation, s: int, t: int, regex: str,
                  **kw) -> QueryResult:
    g = fr.g
    if g.label_names is not None:
        qa = build_query_automaton(regex, g.label_of)
    else:
        qa = build_query_automaton(regex, lambda name: int(name))
    return dis_rpq(fr, s, t, qa, **kw)


# ---------------------------------------------------------------------------
# amortized-cache paths: cached default session (deprecated shims)
# ---------------------------------------------------------------------------

def dis_reach_cached(fr: Fragmentation, s: int, t: int) -> QueryResult:
    """disReach against the rvset cache (built on first use)."""
    _warn_deprecated("dis_reach_cached", "run([Reach(s, t)])")
    return default_session(fr).run([Reach(int(s), int(t))])[0]


def dis_dist_cached(fr: Fragmentation, s: int, t: int,
                    bound: Optional[int] = None) -> QueryResult:
    _warn_deprecated("dis_dist_cached", "run([Dist(s, t, bound)])")
    return default_session(fr).run([Dist(int(s), int(t), bound=bound)])[0]


def dis_rpq_cached(fr: Fragmentation, s: int, t: int,
                   qa: QueryAutomaton) -> QueryResult:
    _warn_deprecated("dis_rpq_cached", "run([Rpq(s, t, automaton=qa)])")
    return default_session(fr).run([Rpq(int(s), int(t), automaton=qa)])[0]


def dis_reach_batch(fr: Fragmentation, pairs) -> np.ndarray:
    """Answer N (s, t) reachability queries in one fused execution.
    Returns [N] bool."""
    _warn_deprecated("dis_reach_batch", "run([Reach(s, t), ...])")
    qs = [Reach(int(s), int(t)) for s, t in _as_pairs(pairs)]
    res = default_session(fr).run(qs)
    return np.array([r.answer for r in res], dtype=bool)


def dis_dist_batch(fr: Fragmentation, pairs,
                   bound: Optional[int] = None) -> np.ndarray:
    """N shortest distances (or bounded-reachability answers when ``bound``
    is given: dist <= bound).  Returns [N] int64 distances with -1 for
    unreachable, or [N] bool when ``bound`` is not None."""
    _warn_deprecated("dis_dist_batch", "run([Dist(s, t, bound), ...])")
    qs = [Dist(int(s), int(t)) for s, t in _as_pairs(pairs)]
    if not qs:
        return np.zeros(0, dtype=bool if bound is not None else np.int64)
    res = default_session(fr).run(qs)
    d = np.array([-1 if r.distance is None else r.distance for r in res],
                 dtype=np.int64)
    if bound is not None:
        return (d >= 0) & (d <= bound)
    return d


def dis_rpq_batch(fr: Fragmentation, pairs, qa: QueryAutomaton) -> np.ndarray:
    """N regular path queries for one automaton in one fused execution.
    Returns [N] bool."""
    _warn_deprecated("dis_rpq_batch", "run([Rpq(s, t, automaton=qa), ...])")
    qs = [Rpq(int(s), int(t), automaton=qa) for s, t in _as_pairs(pairs)]
    res = default_session(fr).run(qs)
    return np.array([r.answer for r in res], dtype=bool)
