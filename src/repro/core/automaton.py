"""Query automata for regular reachability queries (paper Section 5.1).

``R ::= eps | a | RR | R|R | R*`` over node labels.  We build the Glushkov
(position) automaton — each state is an occurrence of a symbol in R and is
*labeled by that symbol*, exactly the paper's query-automaton semantics
("transitions are made by matching the labels of its states with the labels
on the paths").  Construction is the classical first/last/follow computation:
linear states in |R| (paper cites [15] for the O(|R| log |R|) variant; the
Glushkov automaton has the same state count, which is what the complexity
bounds use).

State layout:  0 = u_s (matches only the query's source node s),
1..m = symbol positions, m+1 = u_t (matches only the target node t).
State labels use sentinels:  >=0 symbol id, -1 s-only, -2 t-only,
-3 wildcard (matches any real node).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Set, Tuple

import numpy as np

L_S, L_T, L_WILD = -1, -2, -3


@dataclasses.dataclass
class QueryAutomaton:
    n_states: int
    state_labels: np.ndarray    # [Q] int32 (sentinel scheme above)
    trans: np.ndarray           # [Q, Q] bool adjacency
    nullable: bool              # eps in L(R): len-<=1 s..t paths accepted
    start: int = 0

    @property
    def final(self) -> int:
        return self.n_states - 1

    def size(self) -> int:
        """|R| proxy used in the complexity bounds: states + transitions."""
        return self.n_states + int(self.trans.sum())

    def cache_key(self) -> tuple:
        """Hashable identity used to key per-automaton cached artifacts
        (product closures in core.cache, execution groups in core.plan):
        two automata with equal keys are behaviourally identical —
        ``nullable`` is included because it decides s == t answers."""
        return (self.n_states, self.start, self.nullable,
                self.state_labels.tobytes(), self.trans.tobytes())


# --- regex AST -------------------------------------------------------------

class _Node:
    pass


@dataclasses.dataclass
class _Sym(_Node):
    label: int      # symbol id or L_WILD
    pos: int = -1


@dataclasses.dataclass
class _Cat(_Node):
    a: _Node
    b: _Node


@dataclasses.dataclass
class _Alt(_Node):
    a: _Node
    b: _Node


@dataclasses.dataclass
class _Star(_Node):
    a: _Node


@dataclasses.dataclass
class _Plus(_Node):
    a: _Node


@dataclasses.dataclass
class _Opt(_Node):
    a: _Node


@dataclasses.dataclass
class _Eps(_Node):
    pass


def _tokenize(rx: str) -> List[str]:
    toks, i = [], 0
    while i < len(rx):
        c = rx[i]
        if c.isspace():
            i += 1
        elif c in "()|*+?.":
            toks.append(c)
            i += 1
        else:
            j = i
            while j < len(rx) and (rx[j].isalnum() or rx[j] in "_-"):
                j += 1
            if j == i:
                raise ValueError(f"bad regex char {c!r} in {rx!r}")
            toks.append(rx[i:j])
            i = j
    return toks


def _parse(toks: List[str], label_of: Callable[[str], int]) -> _Node:
    pos = [0]

    def peek():
        return toks[pos[0]] if pos[0] < len(toks) else None

    def eat():
        t = toks[pos[0]]
        pos[0] += 1
        return t

    def parse_alt() -> _Node:
        n = parse_cat()
        while peek() == "|":
            eat()
            n = _Alt(n, parse_cat())
        return n

    def parse_cat() -> _Node:
        items = []
        while peek() is not None and peek() not in ")|":
            items.append(parse_rep())
        if not items:
            return _Eps()
        n = items[0]
        for x in items[1:]:
            n = _Cat(n, x)
        return n

    def parse_rep() -> _Node:
        n = parse_atom()
        while peek() in ("*", "+", "?"):
            op = eat()
            n = {"*": _Star, "+": _Plus, "?": _Opt}[op](n)
        return n

    def parse_atom() -> _Node:
        t = eat()
        if t == "(":
            n = parse_alt()
            assert eat() == ")", "unbalanced parens"
            return n
        if t == ".":
            return _Sym(L_WILD)
        if t in ("eps", "epsilon"):
            return _Eps()
        return _Sym(label_of(t))

    n = parse_alt()
    assert pos[0] == len(toks), f"trailing tokens: {toks[pos[0]:]}"
    return n


# --- Glushkov construction --------------------------------------------------

def _glushkov(n: _Node) -> Tuple[List[int], bool, Set[int], Set[int],
                                 Set[Tuple[int, int]]]:
    syms: List[int] = []

    def number(node: _Node):
        if isinstance(node, _Sym):
            node.pos = len(syms) + 1
            syms.append(node.label)
        elif isinstance(node, (_Cat, _Alt)):
            number(node.a)
            number(node.b)
        elif isinstance(node, (_Star, _Plus, _Opt)):
            number(node.a)

    number(n)
    follow: Set[Tuple[int, int]] = set()

    def visit(node: _Node) -> Tuple[bool, Set[int], Set[int]]:
        if isinstance(node, _Eps):
            return True, set(), set()
        if isinstance(node, _Sym):
            return False, {node.pos}, {node.pos}
        if isinstance(node, _Cat):
            na, fa, la = visit(node.a)
            nb, fb, lb = visit(node.b)
            for p in la:
                for q in fb:
                    follow.add((p, q))
            return (na and nb,
                    fa | (fb if na else set()),
                    lb | (la if nb else set()))
        if isinstance(node, _Alt):
            na, fa, la = visit(node.a)
            nb, fb, lb = visit(node.b)
            return na or nb, fa | fb, la | lb
        if isinstance(node, (_Star, _Plus)):
            _, fa, la = visit(node.a)
            for p in la:
                for q in fa:
                    follow.add((p, q))
            nullable = isinstance(node, _Star) or visit(node.a)[0]
            return nullable, fa, la
        if isinstance(node, _Opt):
            na, fa, la = visit(node.a)
            return True, fa, la
        raise TypeError(node)

    nullable, first, last = visit(n)
    return syms, nullable, first, last, follow


def build_query_automaton(regex: str,
                          label_of: Callable[[str], int]) -> QueryAutomaton:
    """Compile a regular expression into the paper's query automaton G_q(R)."""
    ast = _parse(_tokenize(regex), label_of)
    syms, nullable, first, last, follow = _glushkov(ast)
    m = len(syms)
    Q = m + 2
    labels = np.full(Q, 0, dtype=np.int32)
    labels[0] = L_S
    labels[Q - 1] = L_T
    for i, lab in enumerate(syms):
        labels[i + 1] = lab
    trans = np.zeros((Q, Q), dtype=bool)
    for p in first:
        trans[0, p] = True
    for (p, q) in follow:
        trans[p, q] = True
    for p in last:
        trans[p, Q - 1] = True
    if nullable:
        trans[0, Q - 1] = True
    return QueryAutomaton(n_states=Q, state_labels=labels, trans=trans,
                          nullable=nullable)


def accepts(qa: QueryAutomaton, word: List[int]) -> bool:
    """Host oracle: does the interior label word drive u_s to u_t?"""
    cur = {0}
    for a in word:
        nxt = set()
        for p in cur:
            for q in range(qa.n_states):
                if qa.trans[p, q]:
                    lq = qa.state_labels[q]
                    if lq == a or lq == L_WILD:
                        nxt.add(q)
        cur = nxt
        if not cur:
            return False
    return any(qa.trans[p, qa.final] for p in cur) or (not word and qa.nullable)
