"""Baselines the paper compares against (Section 7, 'Algorithms').

* ``dis_reach_n``  — ship every fragment to the coordinator, evaluate
  centrally (the paper's disReach_n).  Traffic = |G|.
* ``dis_reach_m``  — Pregel-style message passing following [21] as the
  paper describes it: per-superstep local BFS propagation inside each
  worker, newly-activated virtual nodes shipped via the master, repeat
  until quiescent.  No bound on visits per site — the experiment we
  reproduce (Table 2 / Fig. 11) measures exactly that contrast.

Both operate on the same padded ``Fragmentation`` as the engine, so the
comparison isolates the *algorithm*, not the data layout.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .engine import _propagate_bool
from .fragments import Fragmentation


@dataclasses.dataclass
class BaselineResult:
    answer: bool
    traffic_bits: int
    site_visits: int          # total visits summed over sites
    rounds: int               # collective/message rounds


# ---------------------------------------------------------------------------
# disReach_n: centralized
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n",))
def _bfs_full(src, dst, s, *, n):
    frontier = jnp.zeros((1, n + 1), dtype=bool).at[0, s].set(True)
    return _propagate_bool(src, dst, frontier)[0]


def dis_reach_n(fr: Fragmentation, s: int, t: int) -> BaselineResult:
    g = fr.g
    seen = _bfs_full(jnp.asarray(g.src, jnp.int32), jnp.asarray(g.dst, jnp.int32),
                     jnp.int32(s), n=g.n)
    # traffic: every fragment shipped whole (ids are 32-bit words)
    traffic = int((g.n + 2 * g.m) * 32)
    return BaselineResult(bool(seen[t]), traffic, fr.k, 1)


# ---------------------------------------------------------------------------
# disReach_m: message passing (Pregel-style, paper Sec. 7)
# ---------------------------------------------------------------------------

def dis_reach_m(fr: Fragmentation, s: int, t: int,
                max_rounds: Optional[int] = None) -> BaselineResult:
    if s == t:
        return BaselineResult(True, 0, 0, 0)
    arrs = {k: jnp.array(v) for k, v in fr.arrays.items()}
    k, n_max, B = fr.k, fr.n_max, fr.B
    max_rounds = max_rounds or (fr.B + 2)

    prop_ = jax.jit(jax.vmap(lambda es, ed, f: _propagate_bool(es, ed, f)))
    prop = lambda es, ed, act: prop_(es, ed, act[:, None, :])[:, 0, :]

    @jax.jit
    def exchange(active):
        # virtual-node activations -> global boundary activation vector
        stub_act = jnp.take_along_axis(active, arrs["tgt_local"].astype(jnp.int32),
                                       axis=1)                    # [k, B]
        stub_act = stub_act & (arrs["tgt_local"] != n_max)
        bact = jnp.any(stub_act, axis=0)                          # [B]
        # deliver to owning in-nodes
        recv = bact[arrs["src_row"].clip(0, B - 1)] & (arrs["src_row"] < B)
        new_active = jnp.zeros_like(active)
        new_active = new_active.at[
            jnp.arange(k)[:, None], arrs["src_local"]].max(recv)
        new_active = new_active.at[:, n_max].set(False)
        return bact, new_active

    active = np.zeros((k, n_max + 1), dtype=bool)
    i_s = fr.part[s]
    active[i_s, fr.owner_local[s]] = True
    active = jnp.asarray(active)

    rounds = 0
    msgs_bits = 0
    seen_b = jnp.zeros(B, dtype=bool)
    while rounds < max_rounds:
        rounds += 1
        active = prop(arrs["esrc"], arrs["edst"], active)
        # check t
        t_loc = int(fr.owner_local[t])
        if bool(active[fr.part[t], t_loc]):
            break
        bact, delivered = exchange(active)
        fresh = bact & ~seen_b
        n_fresh = int(jnp.sum(fresh))
        if n_fresh == 0:
            break
        # each fresh virtual-node message: 32-bit node id to master + redirect
        msgs_bits += n_fresh * 64
        seen_b = seen_b | bact
        active = active | delivered

    t_loc = int(fr.owner_local[t])
    ans = bool(active[fr.part[t], t_loc])
    return BaselineResult(ans, msgs_bits, fr.k * rounds, rounds)
