"""Boolean-equation-system / dependency-graph closure utilities.

The paper solves the assembled BES (a disjunctive system [14]) by
reachability on the dependency graph G_d.  Two regimes:

* single query  -> single-source fixpoint (``engine.evaldg_*``), O(diam B^2);
* many queries / reusable fragmentation -> **all-pairs closure** by repeated
  squaring: ceil(log2 B) semiring matmuls on the MXU.  Amortizes across a
  query workload; also the target of the Pallas kernels
  (``repro.kernels.bool_matmul`` / ``tropical_matmul`` / ``bitpack_ops``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .engine import INF


def _ceil_log2(b: int) -> int:
    return max(1, math.ceil(math.log2(max(b, 2))))


def bool_closure(D, use_pallas: bool = False):
    """Reflexive-transitive closure of a Boolean matrix [B, B].

    A := A | A@A, repeated ceil(log2 B) times over A = D | I.
    """
    B = D.shape[-1]
    if use_pallas:
        from ..kernels.bool_matmul import ops as bops
        matmul = bops.bool_matmul
    else:
        matmul = lambda a, b: (a.astype(jnp.float32) @ b.astype(jnp.float32)) > 0
    A = D | jnp.eye(B, dtype=bool)

    def body(_, A):
        return A | matmul(A, A)

    return jax.lax.fori_loop(0, _ceil_log2(B), body, A)


def tropical_closure(W, use_pallas: bool = False, row_chunk: int = 64):
    """Min-plus closure of a distance matrix [B, B] (diag forced to 0).

    W := min(W, W (min,+) W), repeated ceil(log2 B) times.
    The pure-jnp path chunks rows to avoid a B^3 intermediate.
    """
    B = W.shape[-1]
    W = jnp.where(jnp.eye(B, dtype=bool), 0, W).astype(jnp.int32)

    if use_pallas:
        from ..kernels.tropical_matmul import ops as tops
        mp = tops.tropical_matmul
    else:
        def mp(a, b):
            def one_chunk(rows):
                # rows [C, B] (min,+) b [B, B] -> [C, B]
                return jnp.min(rows[:, :, None] + b[None, :, :], axis=1)
            n_chunks = max(1, B // row_chunk)
            if B % row_chunk == 0 and n_chunks > 1:
                chunks = a.reshape(n_chunks, row_chunk, B)
                out = jax.lax.map(one_chunk, chunks)
                return out.reshape(B, B)
            return one_chunk(a)

    def body(_, W):
        return jnp.minimum(jnp.minimum(W, mp(W, W)), INF)

    return jax.lax.fori_loop(0, _ceil_log2(B), body, W)


def closure_answers(A, src_rows, tgt_cols):
    """Batch answer extraction: ans[q] = any A[src[q], tgt[q]] for index
    arrays src_rows/tgt_cols [nq]."""
    return A[src_rows, tgt_cols]
