"""Boolean-equation-system / dependency-graph closure utilities.

The paper solves the assembled BES (a disjunctive system [14]) by
reachability on the dependency graph G_d.  Two regimes:

* single query  -> single-source fixpoint (``engine.evaldg_*``), O(diam B^2);
* many queries / reusable fragmentation -> **all-pairs closure** by repeated
  squaring: ceil(log2 B) semiring matmuls on the MXU.  Amortizes across a
  query workload; also the target of the Pallas kernels
  (``repro.kernels.bool_matmul`` / ``tropical_matmul`` / ``bitpack_ops``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .engine import INF


def _ceil_log2(b: int) -> int:
    return max(1, math.ceil(math.log2(max(b, 2))))


def bool_closure(D, use_pallas="auto"):
    """Reflexive-transitive closure of a Boolean matrix [B, B].

    A := A | A@A, repeated ceil(log2 B) times over A = D | I.
    ``use_pallas``: True forces the Pallas kernel (interpret mode off-TPU,
    for tests), False forces the XLA fallback, "auto" dispatches on backend
    (MXU kernel on TPU, f32 matmul elsewhere).
    """
    B = D.shape[-1]
    if use_pallas == "auto":
        from ..kernels.bool_matmul import ops as bops
        matmul = bops.or_and_matmul
    elif use_pallas:
        from ..kernels.bool_matmul import ops as bops
        matmul = bops.bool_matmul
    else:
        matmul = lambda a, b: (a.astype(jnp.float32) @ b.astype(jnp.float32)) > 0
    A = D | jnp.eye(B, dtype=bool)
    if B == 0:
        return A

    # squaring doubles covered path length: fixpoint after ceil(log2 diam)
    # rounds, capped at ceil(log2 B) (worst case diam == B)
    def cond(state):
        _, i, changed = state
        return changed & (i < _ceil_log2(B))

    def body(state):
        A, i, _ = state
        A2 = A | matmul(A, A)
        return A2, i + 1, jnp.any(A2 != A)

    A, _, _ = jax.lax.while_loop(cond, body, (A, jnp.int32(0), jnp.bool_(True)))
    return A


def tropical_closure(W, use_pallas="auto", row_chunk: int = 16):
    """Min-plus closure of a distance matrix [B, B] (diag forced to 0).

    W := min(W, W (min,+) W), repeated ceil(log2 B) times.
    The pure-jnp path chunks rows (``row_chunk`` of them at a time) so the
    broadcast intermediate stays at row_chunk * B^2 int32, not B^3.
    ``use_pallas`` semantics as in :func:`bool_closure`.
    """
    B = W.shape[-1]
    W = jnp.where(jnp.eye(B, dtype=bool), 0, W).astype(jnp.int32)

    from ..kernels.tropical_matmul import ops as tops
    if use_pallas == "auto":
        mp = lambda a, b: tops.min_plus_matmul(a, b, row_chunk=row_chunk)
    elif use_pallas:
        mp = tops.tropical_matmul
    else:
        mp = lambda a, b: tops.min_plus_chunked(a, b, row_chunk=row_chunk)

    if B == 0:
        return W

    def cond(state):
        _, i, changed = state
        return changed & (i < _ceil_log2(B))

    def body(state):
        W, i, _ = state
        W2 = jnp.minimum(jnp.minimum(W, mp(W, W)), INF)
        return W2, i + 1, jnp.any(W2 != W)

    W, _, _ = jax.lax.while_loop(cond, body, (W, jnp.int32(0), jnp.bool_(True)))
    return W


def closure_answers(A, src_rows, tgt_cols):
    """Batch answer extraction: ans[q] = any A[src[q], tgt[q]] for index
    arrays src_rows/tgt_cols [nq]."""
    return A[src_rows, tgt_cols]
