"""Amortized rvset cache + batched multi-query engine (DESIGN.md Sec. 3).

The paper's guarantees are per-query, but a serving engine answers many
queries against the *same* fragmentation.  ``localEval`` splits cleanly:

* **query-independent phase** (expensive, once per Fragmentation):
  every fragment's all-sources local fixpoint — from each owned in-node to
  every local slot — assembled into the boundary-to-boundary dependency
  matrix ``D0 [|V_f|, |V_f|]`` and closed by repeated squaring
  (``bes.bool_closure`` / ``tropical_closure``: ceil(log2 |V_f|) semiring
  matmuls, the Pallas MXU kernels on TPU) instead of diam(G_f) relaxations
  per query;
* **per-query phase** (cheap): one single-source propagation from ``s`` in
  its own fragment, a pure gather of the ``t``-column out of the cached
  frontiers, and one or-and vector-matrix product through the closure.

Correctness identity (checked property-style in tests/test_batched_cache.py):

    reach(s, t) = direct(s, t)                                  # local path
                | OR_{u,v in V_f}  sb[u] & C[u, v] & tc[v]

where ``sb[u]`` = s locally reaches the stub of boundary node u, ``C`` is
the reflexive-transitive closure of D0, and ``tc[v]`` = in-node v locally
reaches t (gathered from the cached frontier of v's fragment — virtual-stub
slots included, so cross-edge arrivals at a boundary t need no special
aliasing).  The tropical and product-automaton variants replace (OR, AND)
with (min, +) and the state-expanded matrix respectively.

Batched: ``dis_reach_batch(fr, pairs)`` answers N pairs in ONE jitted call —
N vmapped single-source propagations + one [N, |V_f|] x [|V_f|, |V_f|]
or-and matmul against the cached closure.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import bes, engine
from .automaton import QueryAutomaton
from .engine import INF
from .fragments import Fragmentation

NO_NODE = np.int32(-(2 ** 30))     # gid that matches no L_S / L_T state


# ---------------------------------------------------------------------------
# cache container + construction
# ---------------------------------------------------------------------------

MAX_RPQ_CLOSURES = 32      # LRU-evicted: each is an [(nb*Q), (nb*Q)] matrix


@dataclasses.dataclass
class RvsetCache:
    """Query-independent closures + frontiers for one Fragmentation."""

    fr: Fragmentation
    arrays: Dict[str, jax.Array]      # fr.arrays uploaded once to device
    bl_frontier: jax.Array            # [nb, n_max+1] bool, in-node -> slot
    closure: jax.Array                # [nb, nb] bool, reflexive-transitive
    part_b: np.ndarray                # [nb] owning fragment of boundary node
    bl_dist: Optional[jax.Array] = None       # [nb, n_max+1] int32
    dist_closure: Optional[jax.Array] = None  # [nb, nb] int32, diag 0
    rpq_closures: Dict[Tuple, jax.Array] = dataclasses.field(
        default_factory=dict)         # automaton key -> [(nb*Q), (nb*Q)]
    # incremental-maintenance state (core.incremental; DESIGN.md Sec. 3.5)
    version: int = 0                  # bumped on every repair/recompute
    repair_debt: float = 0.0          # deletion-recompute cost accumulator

    @property
    def nb(self) -> int:
        return self.fr.n_boundary

    def refresh_device_arrays(self, touched=None) -> None:
        """Re-upload the (host-mutated) fragment arrays after a delta; the
        cached rpq closures are dropped (they bake in the old arrays) and
        rebuild lazily on the next regular query.

        ``touched`` names the subset of ``fr.arrays`` keys the delta
        actually mutated (``incremental.touched_arrays``); only those are
        re-uploaded and the rest keep their device buffers — the
        device-side half of the copy-on-write story that lets MVCC
        versions share untouched buffers (``None`` re-uploads everything).
        A *new* dict is always bound so cache clones sharing the old dict
        (``core.versions``) never observe the refresh.

        ``jnp.array`` (copy=True), NOT ``jnp.asarray``: on CPU the latter
        may zero-copy alias the host buffer, and these host arrays are
        mutated in place by ``Fragmentation.apply_delta`` — an aliased
        device array would see mid-update state and survive a rollback."""
        names = self.fr.arrays.keys() if touched is None else touched
        arrays = dict(self.arrays)
        for k in names:
            arrays[k] = jnp.array(self.fr.arrays[k])
        self.arrays = arrays
        self.part_b = self.fr.boundary_owner()
        self.rpq_closures.clear()
        self.version += 1

    # -- rollback snapshots (failed-delta recovery; DESIGN.md Sec. 7) ------

    _SNAP_FIELDS = ("arrays", "bl_frontier", "closure", "part_b", "bl_dist",
                    "dist_closure", "rpq_closures", "version", "repair_debt")

    def snapshot(self) -> dict:
        """Shallow state capture for rollback: repairs rebind immutable
        jax arrays (functional ``.at[].set``), so references suffice —
        except ``rpq_closures``, which repairs clear *in place*."""
        snap = {name: getattr(self, name) for name in self._SNAP_FIELDS}
        snap["rpq_closures"] = dict(self.rpq_closures)
        return snap

    def restore(self, snap: dict) -> None:
        for name in self._SNAP_FIELDS:
            setattr(self, name, snap[name])
        self.rpq_closures = dict(snap["rpq_closures"])


def _boundary_rows(fr: Fragmentation, frontiers, fill, combine):
    """Scatter stacked per-fragment source rows [k, S, n+1] into one
    [nb, n+1] matrix indexed by boundary position (each in-node is owned by
    exactly one fragment, so rows never collide)."""
    B = fr.B
    src_row = fr.arrays["src_row"]                  # [k, S]; pad rows == B
    flat_rows = jnp.array(src_row.reshape(-1))
    flat = frontiers.reshape(-1, frontiers.shape[-1])
    out = jnp.full((B + 1, frontiers.shape[-1]), fill, frontiers.dtype)
    out = combine(out.at[flat_rows], flat)
    return out[: fr.n_boundary]


def prepare_rvset_cache(fr: Fragmentation, with_dist: bool = False,
                        use_pallas="auto") -> RvsetCache:
    """Build (or extend) the amortized cache and attach it to ``fr``."""
    cache = fr.rvset_cache
    if cache is None:
        # jnp.array (copy=True), not asarray: see refresh_device_arrays.
        arrs = {k: jnp.array(v) for k, v in fr.arrays.items()}
        front = jax.vmap(functools.partial(
            engine.local_frontier_reach, n_max=fr.n_max))(
            arrs["esrc"], arrs["edst"], arrs["src_local"])   # [k, S, n+1]
        bl = _boundary_rows(fr, front, False, lambda ref, v: ref.max(v))
        D0 = _gather_boundary_matrix(fr, bl, fill=False)
        C = bes.bool_closure(D0, use_pallas=use_pallas)
        cache = RvsetCache(fr=fr, arrays=arrs, bl_frontier=bl, closure=C,
                           part_b=fr.boundary_owner())
        fr.rvset_cache = cache
    if with_dist and cache.bl_dist is None:
        arrs = cache.arrays
        front = jax.vmap(functools.partial(
            engine.local_frontier_dist, n_max=fr.n_max))(
            arrs["esrc"], arrs["edst"], arrs["src_local"])
        bl_d = _boundary_rows(fr, front, jnp.int32(INF),
                              lambda ref, v: ref.min(v))
        W0 = _gather_boundary_matrix(fr, bl_d, fill=INF)
        cache.bl_dist = bl_d
        cache.dist_closure = bes.tropical_closure(W0, use_pallas=use_pallas)
    return cache


def _gather_boundary_matrix(fr: Fragmentation, bl, fill):
    """D0[u, w] = cached frontier of in-node u read at the stub slot of
    boundary node w inside u's fragment (pad slot column carries ``fill``)."""
    nb = fr.n_boundary
    if nb == 0:
        return jnp.zeros((0, 0), bl.dtype)
    cols = fr.arrays["tgt_local"][fr.boundary_owner()][:, :nb]   # [nb, nb]
    return jnp.take_along_axis(bl, jnp.asarray(cols), axis=1)


def get_rvset_cache(fr: Fragmentation, with_dist: bool = False) -> RvsetCache:
    cache = fr.rvset_cache
    if cache is None or (with_dist and cache.bl_dist is None):
        cache = prepare_rvset_cache(fr, with_dist=with_dist)
    return cache


# ---------------------------------------------------------------------------
# replicated combine stage (shared by both backends: the vmap batched
# kernels below and the sharded one-collective programs in core.distributed)
# ---------------------------------------------------------------------------

def combine_bool(direct, sb, tc, C):
    """Boolean combine of the per-query phase through a closure:
    ``ans = direct | OR_u (sb (or-and) C)[u] & tc[u]``.

    ``sb``/``tc`` [N, side], ``C`` [side, side] with ``side = nb`` for plain
    reachability or ``nb * |Q|`` for the product-automaton (RPQ) case —
    the algebra is identical, only the state expansion differs.
    """
    if C.shape[0] == 0:
        return direct
    from ..kernels.bool_matmul.ops import or_and_matmul
    sbc = or_and_matmul(sb, C)                             # [N, side]
    return direct | jnp.any(sbc & tc, axis=1)


def combine_dist(direct, sb, tc, Cd):
    """Tropical twin of :func:`combine_bool`:
    ``min(direct, min_u (sb (min-plus) Cd)[u] + tc[u])`` clipped at INF."""
    if Cd.shape[0] == 0:
        return jnp.minimum(direct, INF)
    from ..kernels.tropical_matmul.ops import min_plus_matmul
    sbc = min_plus_matmul(sb, Cd)                          # [N, nb]
    via = jnp.min(jnp.minimum(sbc + tc, INF), axis=1)
    return jnp.minimum(jnp.minimum(direct, via), INF)


# ---------------------------------------------------------------------------
# per-device local stage (sharded backend: each device contributes its own
# fragment's D0/W0 rows, per-pair s-rows and t-column entries, which ride
# the ONE collective of core.distributed.dis_*_batch_sharded)
# ---------------------------------------------------------------------------

def local_stage_reach(esrc, edst, src_local, s_slot, t_slot, srcidx, own,
                      tgt_mine, *, n_max: int):
    """One device's local stage of a fused reach batch.

    Runs this fragment's all-sources fixpoint and N per-pair single-source
    propagations, then extracts the fragment's contributions: its owned
    ``D0`` rows, the s-row and direct bit of every pair whose source it
    owns, and the t-column entries of its own in-nodes.  Shapes:
    ``s_slot``/``t_slot`` [N] (local slot of s_j / t_j here, ``n_max`` if
    absent); ``srcidx`` [nb] (boundary position -> source-row index here,
    pad row elsewhere); ``own`` [nb] ownership mask; ``tgt_mine`` [nb]
    (stub slot of boundary w here).  Returns ``(d0 [nb, nb], sb [N, nb],
    direct [N], tc [N, nb])`` — all-false outside this device's ownership,
    so the cross-device merge is a plain bitwise OR.
    """
    F = engine.local_frontier_reach(esrc, edst, src_local,
                                    n_max=n_max)           # [S, n+1]
    rows = jnp.take(F, srcidx, axis=0)                     # [nb, n+1]
    d0 = jnp.take(rows, tgt_mine, axis=1) & own[:, None]   # [nb, nb]
    fS = jax.vmap(lambda sl: engine.single_source_reach(
        esrc, edst, sl, n_max=n_max))(s_slot)              # [N, n+1]
    sb = jnp.take(fS, tgt_mine, axis=1)                    # [N, nb]
    direct = jnp.take_along_axis(fS, t_slot[:, None], axis=1)[:, 0]
    tc = jnp.take(rows, t_slot, axis=1).T & own[None, :]   # [N, nb]
    return d0, sb, direct, tc


def local_stage_dist(esrc, edst, src_local, s_slot, t_slot, srcidx, own,
                     tgt_mine, *, n_max: int):
    """Tropical twin of :func:`local_stage_reach`: the semiring zero is INF,
    so non-owned entries ship INF and the cross-device merge is a min.
    Returns ``(w0 [nb, nb], sb [N, nb], direct [N], tc [N, nb])`` int32."""
    F = engine.local_frontier_dist(esrc, edst, src_local,
                                   n_max=n_max)            # [S, n+1]
    rows = jnp.take(F, srcidx, axis=0)                     # [nb, n+1]
    w0 = jnp.where(own[:, None], jnp.take(rows, tgt_mine, axis=1), INF)
    fS = jax.vmap(lambda sl: engine.single_source_dist(
        esrc, edst, sl, n_max=n_max))(s_slot)              # [N, n+1]
    sb = jnp.take(fS, tgt_mine, axis=1)                    # [N, nb]
    direct = jnp.take_along_axis(fS, t_slot[:, None], axis=1)[:, 0]
    tc = jnp.where(own[None, :], jnp.take(rows, t_slot, axis=1).T, INF)
    return w0, sb, direct, tc


def local_stage_rpq(esrc, edst, src_local, src_row, tgt_local, labels, gids,
                    q_labels, q_trans, q_start, s_slot, t_slot, s_gids,
                    t_gids, local_b, mine, *, n_max: int, B: int):
    """Product-automaton local stage of a fused RPQ batch (one device).

    The query-independent part is this fragment's product rvset rows
    (``local_eval_regular`` with the s/t sentinels matched off, exactly
    like :func:`product_closure`); the per-pair part is one forward product
    propagation from ``(s_j, u_s)`` and one reverse product propagation to
    ``(t_j, u_t)`` per pair.  ``local_b`` [nb] is the local slot of each
    boundary node inside its *owner*; ``mine`` [nb] masks the in-nodes this
    device owns.  Returns ``(d0 [(nb*Q), (nb*Q)], sb [N, nb*Q], direct [N],
    tc [N, nb*Q])``.
    """
    Q = q_labels.shape[0]
    nb = B - 2
    rloc = engine.local_eval_regular(
        esrc, edst, src_local, src_row, tgt_local, labels, gids,
        q_labels, q_trans, jnp.int32(n_max), jnp.int32(n_max),
        jnp.int32(NO_NODE), jnp.int32(NO_NODE), n_max=n_max, B=B)
    d0 = rloc.reshape(B, Q, B, Q)[:nb, :, :nb, :].reshape(nb * Q, nb * Q)
    f = jax.vmap(lambda sl, sg, tg: engine.single_source_regular(
        esrc, edst, labels, gids, q_labels, q_trans, sl, q_start, sg, tg,
        n_max=n_max))(s_slot, s_gids, t_gids)              # [N, n+1, Q]
    direct = jnp.take_along_axis(f[:, :, Q - 1], t_slot[:, None],
                                 axis=1)[:, 0]             # [N]
    sb = jnp.take(f, tgt_local[:nb], axis=1)               # [N, nb, Q]
    rev = jax.vmap(lambda ts, sg, tg: engine.reverse_target_regular(
        esrc, edst, labels, gids, q_labels, q_trans, ts, sg, tg,
        n_max=n_max))(t_slot, s_gids, t_gids)              # [N, n+1, Q]
    tc = jnp.take(rev, local_b, axis=1) & mine[None, :, None]  # [N, nb, Q]
    N = f.shape[0]
    return d0, sb.reshape(N, nb * Q), direct, tc.reshape(N, nb * Q)


# -- packed variants: one device owning SEVERAL fragments (k >> d) ----------
#
# Each wrapper vmaps its per-fragment stage over the leading owned-fragments
# axis (fpd) and merges the contributions on-device — OR for the Boolean
# kinds, min for the tropical one.  The merge is exact for the same reason
# the cross-device collective is: every d0/sb row and tc column is computed
# by exactly one fragment (the others contribute the semiring zero), and
# ownership stays disjoint whether fragments sit on different devices or
# share one.  Inert pad fragments (pad-only edge lists, all-false ownership
# masks, absent s/t slots) contribute zeros/INF and their propagations
# converge in zero while_loop iterations, so short devices cost nothing.

def local_stage_reach_packed(esrc, edst, src_local, s_slot, t_slot, srcidx,
                             own, tgt_mine, *, n_max: int):
    """:func:`local_stage_reach` for a device owning ``fpd`` fragments —
    every argument gains a leading ``[fpd, ...]`` axis; the returned
    ``(d0, sb, direct, tc)`` are OR-merged over it (shapes as unpacked)."""
    d0, sb, direct, tc = jax.vmap(
        functools.partial(local_stage_reach, n_max=n_max))(
        esrc, edst, src_local, s_slot, t_slot, srcidx, own, tgt_mine)
    return (jnp.any(d0, axis=0), jnp.any(sb, axis=0),
            jnp.any(direct, axis=0), jnp.any(tc, axis=0))


def local_stage_dist_packed(esrc, edst, src_local, s_slot, t_slot, srcidx,
                            own, tgt_mine, *, n_max: int):
    """Tropical twin of :func:`local_stage_reach_packed`: min-merge over
    the owned-fragments axis (non-owners ship INF, the tropical zero)."""
    w0, sb, direct, tc = jax.vmap(
        functools.partial(local_stage_dist, n_max=n_max))(
        esrc, edst, src_local, s_slot, t_slot, srcidx, own, tgt_mine)
    return (jnp.min(w0, axis=0), jnp.min(sb, axis=0),
            jnp.min(direct, axis=0), jnp.min(tc, axis=0))


def local_stage_rpq_packed(esrc, edst, src_local, src_row, tgt_local, labels,
                           gids, q_labels, q_trans, q_start, s_slot, t_slot,
                           s_gids, t_gids, local_b, mine, *, n_max: int,
                           B: int):
    """:func:`local_stage_rpq` over the owned-fragments axis.  Per-fragment
    arguments carry ``[fpd, ...]``; the automaton (``q_*``), the pair gids
    and ``local_b`` stay replicated."""
    d0, sb, direct, tc = jax.vmap(
        functools.partial(local_stage_rpq, n_max=n_max, B=B),
        in_axes=(0, 0, 0, 0, 0, 0, 0, None, None, None, 0, 0, None, None,
                 None, 0))(
        esrc, edst, src_local, src_row, tgt_local, labels, gids,
        q_labels, q_trans, q_start, s_slot, t_slot, s_gids, t_gids,
        local_b, mine)
    return (jnp.any(d0, axis=0), jnp.any(sb, axis=0),
            jnp.any(direct, axis=0), jnp.any(tc, axis=0))


# ---------------------------------------------------------------------------
# batched per-query phase (one jitted call for N pairs)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_max",))
def _batch_reach_kernel(esrc, edst, tgt_local, bl, C, frag_s, s_slot,
                        t_slot_sfrag, t_cols, *, n_max: int):
    """N pairs -> N answers.  Shapes: esrc/edst [k, E]; tgt_local [k, B];
    bl [nb, n+1]; C [nb, nb]; frag_s/s_slot/t_slot_sfrag [N];
    t_cols [N, nb] (slot of t_j inside the fragment owning boundary u)."""
    nb = C.shape[0]
    es = jnp.take(esrc, frag_s, axis=0)                    # [N, E]
    ed = jnp.take(edst, frag_s, axis=0)
    f = jax.vmap(functools.partial(engine.single_source_reach,
                                   n_max=n_max))(es, ed, s_slot)  # [N, n+1]
    direct = jnp.take_along_axis(f, t_slot_sfrag[:, None], axis=1)[:, 0]
    tgt_s = jnp.take(tgt_local, frag_s, axis=0)[:, :nb]    # [N, nb]
    sb = jnp.take_along_axis(f, tgt_s, axis=1)             # [N, nb]
    tc = jax.vmap(lambda c: bl[jnp.arange(nb), c])(t_cols)  # [N, nb]
    return combine_bool(direct, sb, tc, C)


@functools.partial(jax.jit, static_argnames=("n_max",))
def _batch_dist_kernel(esrc, edst, tgt_local, bl_d, Cd, frag_s, s_slot,
                       t_slot_sfrag, t_cols, *, n_max: int):
    """Tropical twin of :func:`_batch_reach_kernel`: N distances (INF if
    unreachable)."""
    nb = Cd.shape[0]
    es = jnp.take(esrc, frag_s, axis=0)
    ed = jnp.take(edst, frag_s, axis=0)
    f = jax.vmap(functools.partial(engine.single_source_dist,
                                   n_max=n_max))(es, ed, s_slot)  # [N, n+1]
    direct = jnp.take_along_axis(f, t_slot_sfrag[:, None], axis=1)[:, 0]
    tgt_s = jnp.take(tgt_local, frag_s, axis=0)[:, :nb]
    sb = jnp.take_along_axis(f, tgt_s, axis=1)             # [N, nb]
    tc = jax.vmap(lambda c: bl_d[jnp.arange(nb), c])(t_cols)
    return combine_dist(direct, sb, tc, Cd)


def _batch_inputs(fr: Fragmentation, cache: RvsetCache,
                  pairs: np.ndarray):
    """Host-side per-batch index arrays (pure numpy gathers)."""
    ss, tt = pairs[:, 0], pairs[:, 1]
    slot_of = fr.slot_index()                              # [n, k]
    frag_s = fr.part[ss].astype(np.int32)
    s_slot = fr.owner_local[ss].astype(np.int32)
    t_slot_sfrag = slot_of[tt, frag_s]                     # [N]
    # slot of t_j inside the fragment owning each boundary node u
    t_cols = slot_of[tt][:, cache.part_b]                  # [N, nb]
    return (jnp.asarray(frag_s), jnp.asarray(s_slot),
            jnp.asarray(t_slot_sfrag), jnp.asarray(t_cols))


def _as_pairs(pairs) -> np.ndarray:
    p = np.asarray(pairs, dtype=np.int64)
    if p.ndim != 2 or p.shape[1] != 2:
        raise ValueError(f"pairs must be [N, 2], got {p.shape}")
    return p


def dis_reach_batch(fr: Fragmentation, pairs) -> np.ndarray:
    """Answer N (s, t) reachability queries in one jitted call against the
    amortized rvset cache.  Returns [N] bool."""
    pairs = _as_pairs(pairs)
    if len(pairs) == 0:
        return np.zeros(0, dtype=bool)
    cache = get_rvset_cache(fr)
    arrs = cache.arrays
    out = _batch_reach_kernel(
        arrs["esrc"], arrs["edst"], arrs["tgt_local"],
        cache.bl_frontier, cache.closure,
        *_batch_inputs(fr, cache, pairs), n_max=fr.n_max)
    return np.asarray(out)


def dis_dist_batch(fr: Fragmentation, pairs,
                   bound: Optional[int] = None) -> np.ndarray:
    """N shortest distances (or bounded-reachability answers when ``bound``
    is given: dist <= bound).  Returns [N] int64 distances with -1 for
    unreachable, or [N] bool when ``bound`` is not None."""
    pairs = _as_pairs(pairs)
    if len(pairs) == 0:
        return np.zeros(0, dtype=bool if bound is not None else np.int64)
    cache = get_rvset_cache(fr, with_dist=True)
    arrs = cache.arrays
    d = np.asarray(_batch_dist_kernel(
        arrs["esrc"], arrs["edst"], arrs["tgt_local"],
        cache.bl_dist, cache.dist_closure,
        *_batch_inputs(fr, cache, pairs), n_max=fr.n_max)).astype(np.int64)
    if bound is not None:
        return d <= bound
    d[d >= int(INF)] = -1
    return d


# ---------------------------------------------------------------------------
# cached single-query wrappers (batch of one)
# ---------------------------------------------------------------------------

def reach_cached(fr: Fragmentation, s: int, t: int) -> bool:
    return bool(dis_reach_batch(fr, [(s, t)])[0])


def dist_cached(fr: Fragmentation, s: int, t: int) -> Optional[int]:
    d = int(dis_dist_batch(fr, [(s, t)])[0])
    return None if d < 0 else d


# ---------------------------------------------------------------------------
# regular (RPQ) cached path
# ---------------------------------------------------------------------------

def _qa_key(qa: QueryAutomaton) -> Tuple:
    return qa.cache_key()


def product_closure(fr: Fragmentation, qa: QueryAutomaton,
                    use_pallas="auto") -> jax.Array:
    """Query-independent product-automaton closure [(nb*Q), (nb*Q)].

    Sound because the Glushkov automaton's u_s has no incoming and u_t no
    outgoing transitions: neither s-only nor t-only states can occur strictly
    inside a boundary-to-boundary path, so matching them off (NO_NODE gid)
    loses nothing the per-query phase doesn't re-add.
    """
    cache = get_rvset_cache(fr)
    key = _qa_key(qa)
    C = cache.rpq_closures.get(key)
    if C is not None:
        # true LRU: a hit moves the key back to the MRU end of the (insert-
        # ordered) dict, so a hot automaton is never FIFO-evicted by churn
        cache.rpq_closures.pop(key)
        cache.rpq_closures[key] = C
        return C
    arrs = cache.arrays
    q_labels = jnp.asarray(qa.state_labels)
    q_trans = jnp.asarray(qa.trans)
    k, n_max, B, Q = fr.k, fr.n_max, fr.B, qa.n_states
    no_slot = jnp.full(k, n_max, jnp.int32)
    local = jax.vmap(
        lambda es, ed, sl, sr, tl, lab, gid, sloc, tloc:
        engine.local_eval_regular(es, ed, sl, sr, tl, lab, gid,
                                  q_labels, q_trans, sloc, tloc,
                                  jnp.int32(NO_NODE), jnp.int32(NO_NODE),
                                  n_max=n_max, B=B))
    rlocs = local(arrs["esrc"], arrs["edst"], arrs["src_local"],
                  arrs["src_row"], arrs["tgt_local"], arrs["labels"],
                  arrs["gids"], no_slot, no_slot)
    D = jnp.any(rlocs, axis=0)                              # [(B*Q), (B*Q)]
    nb = fr.n_boundary
    D = D.reshape(B, Q, B, Q)[:nb, :, :nb, :].reshape(nb * Q, nb * Q)
    C = bes.bool_closure(D, use_pallas=use_pallas)
    # bound the per-automaton cache: each closure is (nb*Q)^2 bools, and a
    # server facing user-supplied regexes must not grow without limit.
    # dict order is recency order (hits re-insert at the MRU end), so the
    # first key is the least recently used one
    while len(cache.rpq_closures) >= MAX_RPQ_CLOSURES:
        cache.rpq_closures.pop(next(iter(cache.rpq_closures)))
    cache.rpq_closures[key] = C
    return C


@functools.partial(jax.jit, static_argnames=("n_max",))
def _batch_rpq_kernel(esrc, edst, labels, gids, tgt_local, q_labels, q_trans,
                      q_start, C, part_b, local_b, frag_s, s_slot,
                      t_slot_sfrag, t_slots, s_gids, t_gids, *, n_max: int):
    """N pairs -> N answers for ONE automaton against its cached product
    closure.  Shapes: esrc/edst/labels/gids [k, ...]; tgt_local [k, B];
    C [(nb*Q), (nb*Q)]; part_b/local_b [nb]; frag_s/s_slot/t_slot_sfrag/
    s_gids/t_gids [N]; t_slots [N, k] (slot of t_j in every fragment).

    Per pair: one forward product propagation from (s, u_s) on s's fragment
    and k reverse product propagations to (t, u_t) (one per fragment — the
    t-column), both vmapped over the batch; then ONE or-and matmul
    [N, nb*Q] x [(nb*Q), (nb*Q)] composes them through the closure.
    """
    Q = q_labels.shape[0]
    nb = part_b.shape[0]
    es = jnp.take(esrc, frag_s, axis=0)                    # [N, E]
    ed = jnp.take(edst, frag_s, axis=0)
    lab = jnp.take(labels, frag_s, axis=0)
    gid = jnp.take(gids, frag_s, axis=0)
    f = jax.vmap(lambda a, b, c, d, sl, sg, tg: engine.single_source_regular(
        a, b, c, d, q_labels, q_trans, sl, q_start, sg, tg,
        n_max=n_max))(es, ed, lab, gid, s_slot, s_gids, t_gids)  # [N,n+1,Q]
    direct = jnp.take_along_axis(f[:, :, Q - 1], t_slot_sfrag[:, None],
                                 axis=1)[:, 0]             # [N]
    rev = jax.vmap(lambda ts, sg, tg: jax.vmap(
        lambda a, b, c, d, tslot: engine.reverse_target_regular(
            a, b, c, d, q_labels, q_trans, tslot, sg, tg,
            n_max=n_max))(esrc, edst, labels, gids, ts))(
        t_slots, s_gids, t_gids)                           # [N, k, n+1, Q]
    if nb == 0:
        return direct
    tgt_s = jnp.take(tgt_local, frag_s, axis=0)[:, :nb]    # [N, nb]
    sb = jnp.take_along_axis(f, tgt_s[:, :, None], axis=1)  # [N, nb, Q]
    # spare boundary slots read the (all-false) pad row of rev via local_b
    tc = rev[:, part_b, local_b, :]                        # [N, nb, Q]
    N = f.shape[0]
    return combine_bool(direct, sb.reshape(N, nb * Q),
                        tc.reshape(N, nb * Q), C)


def dis_rpq_batch(fr: Fragmentation, pairs, qa: QueryAutomaton) -> np.ndarray:
    """Answer N (s, t) regular path queries for one automaton in one jitted
    call against the cached product closure.  Returns [N] bool.

    One compiled program per (automaton, batch-shape) pair — the session
    planner pads batch sizes to buckets, so a mixed workload with R
    distinct automata steady-states at R compiled executions per batch.
    """
    pairs = _as_pairs(pairs)
    if len(pairs) == 0:
        return np.zeros(0, dtype=bool)
    C = product_closure(fr, qa)
    cache = get_rvset_cache(fr)
    arrs = cache.arrays
    ss, tt = pairs[:, 0], pairs[:, 1]
    slot_of = fr.slot_index()
    frag_s = fr.part[ss].astype(np.int32)
    out = _batch_rpq_kernel(
        arrs["esrc"], arrs["edst"], arrs["labels"], arrs["gids"],
        arrs["tgt_local"], jnp.asarray(qa.state_labels),
        jnp.asarray(qa.trans), jnp.int32(qa.start), C,
        jnp.asarray(cache.part_b), jnp.asarray(fr.boundary_local()),
        jnp.asarray(frag_s), jnp.asarray(fr.owner_local[ss].astype(np.int32)),
        jnp.asarray(slot_of[tt, frag_s]), jnp.asarray(slot_of[tt, :]),
        jnp.asarray(ss.astype(np.int32)), jnp.asarray(tt.astype(np.int32)),
        n_max=fr.n_max)
    ans = np.array(out)                    # copy: jax buffers are read-only
    ans[ss == tt] = bool(qa.nullable)      # convention: s==t is |R|-free
    return ans


def rpq_cached(fr: Fragmentation, s: int, t: int, qa: QueryAutomaton) -> bool:
    """Cached disRPQ (batch of one): per-automaton product closure
    (amortized) + one forward and k reverse product propagations."""
    if s == t:
        return bool(qa.nullable)
    return bool(dis_rpq_batch(fr, [(s, t)], qa)[0])
