"""shard_map engine: fragments packed onto a device mesh (d <= k).

This is the production path: a :class:`~repro.core.fragments.Placement`
maps every fragment to a mesh device (several fragments per device when
``k > d``); each device runs localEval on its owned fragments with *zero*
communication — a vmap over the owned-fragments axis, merged on-device —
then a single collective assembles the dependency matrix, and evalDG runs
replicated (see DESIGN.md Sec. 2 for why replication beats a coordinator on
a torus).

Performance-guarantee mapping (checked by tests/test_guarantees.py):
  * "each site visited once"        -> exactly one collective in the HLO;
  * "traffic O(|V_f|^2)" bits       -> the collective payload is the B x B
    Boolean matrix bitpacked into uint32 words (kernels.bitpack_ops): 8x
    fewer bits than the seed's uint8 shipping, independent of |G|.  pmax
    over packed words is exact because every payload row is owned by
    exactly one fragment (all other devices contribute zero words);
  * "time O(|F_m| |V_f|)"           -> per-device localEval work, done in
    parallel; evalDG adds O(diam(G_f) |V_f|^2) replicated FLOPs.

``dis_reach_batch_sharded`` is the batched equivalent (DESIGN.md Sec. 3.3):
one shard_map program answers N pairs with a SINGLE packed collective that
carries the boundary matrix rows and all per-pair s-row / t-column
contributions together.
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import cache as _cache
from . import engine
from ..kernels.bitpack_ops.ops import pack_payload, unpack_payload
from .automaton import QueryAutomaton
from .bes import bool_closure, tropical_closure
from .fragments import Fragmentation, Placement, query_slots

# jax.shard_map moved to the top level after 0.4.x; support both.  The
# experimental version cannot prove replication through while loops, so it
# additionally needs check_rep=False (the engine's fixpoints are loops).
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map_compat

    def shard_map(f, **kwargs):
        kwargs.setdefault("check_rep", False)
        return _shard_map_compat(f, **kwargs)

FRAG_AXIS = "frag"


def fragment_mesh(k: Optional[int] = None, devices=None) -> Mesh:
    """1-D mesh with one shard per fragment."""
    devices = np.array(jax.devices() if devices is None else devices)
    k = len(devices) if k is None else k
    assert len(devices) >= k, f"need >= {k} devices, have {len(devices)}"
    return jax.make_mesh((k,), (FRAG_AXIS,), devices=devices[:k])


def _shard_args(fr: Fragmentation, s: int, t: int):
    qs = query_slots(fr, s, t)
    args = {k: jnp.array(v) for k, v in fr.arrays.items()}
    args["s_local"] = jnp.asarray(qs["s_local"])
    args["t_local"] = jnp.asarray(qs["t_local"])
    return args


def _specs():
    sharded = P(FRAG_AXIS)
    return dict(esrc=sharded, edst=sharded, src_local=sharded,
                src_row=sharded, tgt_local=sharded, labels=sharded,
                gids=sharded, n_local=sharded,
                s_local=sharded, t_local=sharded)


def dis_reach_sharded(fr: Fragmentation, s: int, t: int,
                      mesh: Optional[Mesh] = None):
    """disReach over a device mesh; returns (answer, D) replicated —
    D is None for the trivial s == t case (nothing is evaluated)."""
    if s == t:
        return True, None
    mesh = mesh or fragment_mesh(fr.k)
    assert mesh.devices.size == fr.k, "one device (shard) per fragment"
    args = _shard_args(fr, s, t)
    specs = _specs()
    in_specs = tuple(specs[k] for k in
                     ("esrc", "edst", "src_local", "src_row", "tgt_local",
                      "s_local", "t_local"))
    tgt_cols, src_rows, bt = _answer_masks(fr, t)

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=(P(), P()))
    def run(esrc, edst, src_local, src_row, tgt_local, s_local, t_local):
        rloc = engine.local_eval_reach(
            esrc[0], edst[0], src_local[0], src_row[0], tgt_local[0],
            s_local[0], t_local[0], n_max=fr.n_max, B=fr.B)
        # the single collective: OR-reduce the bitpacked boundary matrices
        # (row ownership is disjoint, so pmax over uint32 words == OR)
        Dp = jax.lax.pmax(pack_payload(rloc), FRAG_AXIS)
        D = unpack_payload(Dp, fr.B)
        ans = engine.evaldg_reach(D, src_rows, tgt_cols)
        return ans, D

    ans, D = jax.jit(run)(*(args[k] for k in
                            ("esrc", "edst", "src_local", "src_row",
                             "tgt_local", "s_local", "t_local")))
    return bool(ans), np.asarray(D)


def _answer_masks(fr: Fragmentation, t: int):
    tgt_cols = np.zeros(fr.B, dtype=bool)
    tgt_cols[fr.T_COL] = True
    bt = int(fr.b_index[t])
    if bt >= 0:
        tgt_cols[bt] = True
    src_rows = np.zeros(fr.B, dtype=bool)
    src_rows[fr.S_ROW] = True
    return jnp.asarray(tgt_cols), jnp.asarray(src_rows), bt


def dis_rpq_sharded(fr: Fragmentation, s: int, t: int, qa: QueryAutomaton,
                    mesh: Optional[Mesh] = None):
    if s == t:
        return bool(qa.nullable)
    mesh = mesh or fragment_mesh(fr.k)
    args = _shard_args(fr, s, t)
    Q = qa.n_states
    q_labels = jnp.asarray(qa.state_labels)
    q_trans = jnp.asarray(qa.trans)

    src_rows = np.zeros(fr.B * Q, dtype=bool)
    src_rows[fr.S_ROW * Q + qa.start] = True
    tgt_cols = np.zeros(fr.B * Q, dtype=bool)
    tgt_cols[fr.T_COL * Q + qa.final] = True
    bt = int(fr.b_index[t])
    if bt >= 0:
        tgt_cols[bt * Q + qa.final] = True
    src_rows, tgt_cols = jnp.asarray(src_rows), jnp.asarray(tgt_cols)

    names = ("esrc", "edst", "src_local", "src_row", "tgt_local", "labels",
             "gids", "s_local", "t_local")
    specs = _specs()
    in_specs = tuple(specs[k] for k in names)

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=P())
    def run(esrc, edst, src_local, src_row, tgt_local, labels, gids,
            s_local, t_local):
        rloc = engine.local_eval_regular(
            esrc[0], edst[0], src_local[0], src_row[0], tgt_local[0],
            labels[0], gids[0], q_labels, q_trans,
            s_local[0], t_local[0], jnp.int32(s), jnp.int32(t),
            n_max=fr.n_max, B=fr.B)
        Dp = jax.lax.pmax(pack_payload(rloc), FRAG_AXIS)
        D = unpack_payload(Dp, fr.B * Q)
        return engine.evaldg_reach(D, src_rows, tgt_cols)

    ans = jax.jit(run)(*(args[k] for k in names))
    return bool(ans)


def lower_reach_hlo(fr: Fragmentation, s: int, t: int,
                    mesh: Optional[Mesh] = None) -> str:
    """Lowered HLO text of the sharded disReach — used by tests to assert
    the one-collective-round guarantee structurally."""
    mesh = mesh or fragment_mesh(fr.k)
    args = _shard_args(fr, s, t)
    specs = _specs()
    names = ("esrc", "edst", "src_local", "src_row", "tgt_local",
             "s_local", "t_local")
    in_specs = tuple(specs[k] for k in names)
    tgt_cols, src_rows, _ = _answer_masks(fr, t)

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=P())
    def run(esrc, edst, src_local, src_row, tgt_local, s_local, t_local):
        rloc = engine.local_eval_reach(
            esrc[0], edst[0], src_local[0], src_row[0], tgt_local[0],
            s_local[0], t_local[0], n_max=fr.n_max, B=fr.B)
        Dp = jax.lax.pmax(pack_payload(rloc), FRAG_AXIS)
        D = unpack_payload(Dp, fr.B)
        return engine.evaldg_reach(D, src_rows, tgt_cols)

    lowered = jax.jit(run).lower(*(args[k] for k in names))
    return lowered.as_text()


# ---------------------------------------------------------------------------
# batched sharded engine: N pairs, ONE packed collective per fused group,
# for ALL THREE query classes (DESIGN.md Sec. 3.3)
# ---------------------------------------------------------------------------
#
# Shared structure (the local stage lives in core.cache.local_stage_*, the
# combine in core.cache.combine_*, so both backends evolve together): each
# device runs its owned fragments' query-independent rows (D0 / W0 /
# product rvset) plus the per-pair s-rows, direct entries, and t-column
# entries they own — vmapped over the owned-fragments axis and OR/min-
# merged on-device (core.cache.local_stage_*_packed) — concatenates
# everything into ONE payload of shape [side + 2N, side + 1] (side = nb,
# or nb*|Q| for RPQs; the extra column carries the per-pair direct
# answer), and a single collective merges it: psum over bitpacked uint32
# words for the Boolean payloads (no carries — every bit is computed on
# exactly one device: d0/sb rows by their owner, tc[:, u] by frag(u)),
# pmin over raw int32 for the tropical wire (exact because non-owners
# ship INF).  Closure + combine run replicated, exactly like evalDG.  The
# compiled programs are cached per (mesh, geometry, fpd, N) — fpd is the
# only shape the placement adds; the assignment itself rides in as packed
# argument data — so steady-state batches neither retrace nor recompile,
# and survive in-place deltas (no fragment data is baked in).

def _split_merged(merged, side: int, N: int):
    """Undo the payload concatenation: (d0, sb, direct, tc)."""
    return (merged[:side, :side], merged[side:side + N, :side],
            merged[side:side + N, side], merged[side + N:, :side])


def _resolve_placement(fr: Fragmentation, mesh: Optional[Mesh],
                       placement: Optional[Placement]):
    """Normalize (mesh, placement) for the packed sharded engines.

    Default placement is :meth:`Placement.balanced` over the mesh size (or
    over ``min(devices, k)`` when no mesh is given); default mesh is the
    first ``placement.d`` process devices.  Raises ValueError on any
    mismatch — including the d > k case, which the sharded engines cannot
    serve (a fragment is never split across devices)."""
    if placement is None:
        d = int(mesh.devices.size) if mesh is not None \
            else min(len(jax.devices()), fr.k)
        placement = Placement.balanced(fr, d)
    if placement.k != fr.k:
        raise ValueError(f"placement maps {placement.k} fragments but the "
                         f"fragmentation has {fr.k}")
    mesh = mesh or fragment_mesh(placement.d)
    if mesh.devices.size != placement.d:
        raise ValueError(f"mesh has {mesh.devices.size} devices but the "
                         f"placement expects {placement.d}")
    return mesh, placement


def _pack_rows(arr: np.ndarray, perm: np.ndarray, pad) -> np.ndarray:
    """Reorder a stacked [k, ...] per-fragment array into the device-major
    [d*fpd, ...] packed layout; pad slots (perm == -1) are filled with the
    array's inert value."""
    out = np.full((len(perm),) + arr.shape[1:], pad, dtype=arr.dtype)
    valid = perm >= 0
    out[valid] = arr[perm[valid]]
    return out


@functools.lru_cache(maxsize=64)
def _batch_reach_jitted(mesh: Mesh, nb: int, n_max: int, fpd: int, N: int):
    in_specs = tuple(P(FRAG_AXIS) for _ in range(8))

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=P())
    def run(esrc, edst, src_local, tgt_local, s_slot, t_slot, srcidx, own):
        # each arg arrives [fpd, ...]: this device's owned fragments
        d0, sb, direct, tc = _cache.local_stage_reach_packed(
            esrc, edst, src_local, s_slot, t_slot,
            srcidx, own, tgt_local[:, :nb], n_max=n_max)
        payload = jnp.concatenate([
            jnp.concatenate([d0, jnp.zeros((nb, 1), bool)], axis=1),
            jnp.concatenate([sb, direct[:, None]], axis=1),
            jnp.concatenate([tc, jnp.zeros((N, 1), bool)], axis=1),
        ], axis=0)                                         # [nb+2N, nb+1]
        merged = unpack_payload(
            jax.lax.psum(pack_payload(payload), FRAG_AXIS), nb + 1)
        d0_m, sb_m, direct_m, tc_m = _split_merged(merged, nb, N)
        # replicated: closure by repeated squaring + per-pair combine
        return _cache.combine_bool(direct_m, sb_m, tc_m,
                                         bool_closure(d0_m))

    return jax.jit(run)


@functools.lru_cache(maxsize=64)
def _batch_dist_jitted(mesh: Mesh, nb: int, n_max: int, fpd: int, N: int):
    in_specs = tuple(P(FRAG_AXIS) for _ in range(8))

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=P())
    def run(esrc, edst, src_local, tgt_local, s_slot, t_slot, srcidx, own):
        w0, sb, direct, tc = _cache.local_stage_dist_packed(
            esrc, edst, src_local, s_slot, t_slot,
            srcidx, own, tgt_local[:, :nb], n_max=n_max)
        inf_b = jnp.full((nb, 1), engine.INF, jnp.int32)
        inf_n = jnp.full((N, 1), engine.INF, jnp.int32)
        payload = jnp.concatenate([
            jnp.concatenate([w0, inf_b], axis=1),
            jnp.concatenate([sb, direct[:, None]], axis=1),
            jnp.concatenate([tc, inf_n], axis=1),
        ], axis=0)                                         # [nb+2N, nb+1]
        # the ONE collective: min-reduce the int32 tropical wire — exact
        # because every entry is computed on exactly one device (w0 and sb
        # rows by their owner, tc[:, u] by frag(u)) and all others ship
        # INF, the tropical zero.  int32 rows do not bitpack, so the wire
        # carries the rows actually contributed, never the B^2 matrix.
        merged = jax.lax.pmin(payload, FRAG_AXIS)
        w0_m, sb_m, direct_m, tc_m = _split_merged(merged, nb, N)
        return _cache.combine_dist(direct_m, sb_m, tc_m,
                                         tropical_closure(w0_m))

    return jax.jit(run)


@functools.lru_cache(maxsize=64)
def _batch_rpq_jitted(mesh: Mesh, nb: int, n_max: int, B: int, Q: int,
                      q_start: int, fpd: int, N: int):
    side = nb * Q
    in_specs = tuple(P(FRAG_AXIS) for _ in range(10)) + \
        tuple(P() for _ in range(5))

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=P())
    def run(esrc, edst, src_local, src_row, tgt_local, labels, gids,
            s_slot, t_slot, mine, q_labels, q_trans, s_gids, t_gids,
            local_b):
        d0, sb, direct, tc = _cache.local_stage_rpq_packed(
            esrc, edst, src_local, src_row, tgt_local,
            labels, gids, q_labels, q_trans, jnp.int32(q_start),
            s_slot, t_slot, s_gids, t_gids, local_b, mine,
            n_max=n_max, B=B)
        payload = jnp.concatenate([
            jnp.concatenate([d0, jnp.zeros((side, 1), bool)], axis=1),
            jnp.concatenate([sb, direct[:, None]], axis=1),
            jnp.concatenate([tc, jnp.zeros((N, 1), bool)], axis=1),
        ], axis=0)                                 # [side+2N, side+1]
        merged = unpack_payload(
            jax.lax.psum(pack_payload(payload), FRAG_AXIS), side + 1)
        d0_m, sb_m, direct_m, tc_m = _split_merged(merged, side, N)
        return _cache.combine_bool(direct_m, sb_m, tc_m,
                                         bool_closure(d0_m))

    return jax.jit(run)



def _srcidx_own(fr: Fragmentation):
    """Host-side inverse of ``src_row``: for each fragment, the source-row
    index of every boundary position it owns (pad row ``S-1`` — the
    reserved s slot, never a real in-node row — elsewhere) plus the
    ownership mask.  [k, nb] each."""
    src_row = fr.arrays["src_row"]                         # [k, S]
    k, S, nb = fr.k, src_row.shape[1], fr.n_boundary
    srcidx = np.full((k, nb), S - 1, dtype=np.int32)
    own = np.zeros((k, nb), dtype=bool)
    for i in range(k):
        mine = src_row[i] < fr.B - 2
        srcidx[i, src_row[i, mine]] = np.nonzero(mine)[0]
        own[i, src_row[i, mine]] = True
    return srcidx, own


# inert pad values per fragment array: pad fragments must read as "no
# edges, no sources, no ownership" so their local stages converge in zero
# iterations and contribute only semiring zeros to the on-device merge
def _array_pads(fr: Fragmentation) -> dict:
    return dict(esrc=fr.n_max, edst=fr.n_max, src_local=fr.n_max,
                src_row=fr.B, tgt_local=fr.n_max, labels=-9, gids=-1,
                n_local=0)


# live entries in a Fragmentation's device-upload memo.  More than one
# because the MVCC store (core.versions) keeps several versions live and
# each version's repair re-uploads under a new arrays_version; a small LRU
# stops versions from thrashing each other's uploads while bounding device
# memory held by stale versions.
_UPLOAD_MEMO_CAP = 4


def _device_inputs(fr: Fragmentation, placement: Placement) -> dict:
    """Query-independent device uploads for the batched sharded engines —
    the fragment arrays plus the boundary-ownership gathers, packed into
    the placement's device-major [d*fpd, ...] layout — memoized in a small
    per-Fragmentation LRU keyed on ``(fr.arrays_version, placement)`` so
    steady-state batches skip the host-to-device copy of the edge lists
    entirely; any ``apply_delta``/``rebuild`` (which mutates the host
    arrays in place and bumps the version) starts a fresh entry, as does
    switching placements.  Several keys stay live so MVCC versions and
    alternate placements don't thrash each other's uploads."""
    memos = fr.__dict__.get("_sharded_device_inputs")
    if memos is None:
        memos = fr.__dict__["_sharded_device_inputs"] = OrderedDict()
    key = (fr.arrays_version, placement.cache_key())
    memo = memos.get(key)
    if memo is not None:
        memos.move_to_end(key)
        return memo
    perm = placement.perm()
    pads = _array_pads(fr)
    srcidx, own = _srcidx_own(fr)
    mine = fr.boundary_owner()[None, :] == np.arange(fr.k)[:, None]
    mine[:, fr.nb_active:] = False     # spare slots are owned by nobody
    memo = dict(
        version=fr.arrays_version, placement=placement.cache_key(),
        perm=perm,
        arrs={key: jnp.asarray(_pack_rows(v, perm, pads[key]))
              for key, v in fr.arrays.items()},
        srcidx=jnp.asarray(_pack_rows(srcidx, perm, fr.s_max - 1)),
        own=jnp.asarray(_pack_rows(own, perm, False)),
        mine=jnp.asarray(_pack_rows(mine, perm, False)),
        local_b=jnp.asarray(fr.boundary_local()))
    memos[key] = memo
    while len(memos) > _UPLOAD_MEMO_CAP:
        memos.popitem(last=False)
    return memo


def _batch_sharded_program(fr: Fragmentation, pairs: np.ndarray, kind: str,
                           qa: Optional[QueryAutomaton] = None,
                           mesh: Optional[Mesh] = None,
                           placement: Optional[Placement] = None,
                           chaos=None):
    """(compiled-program, args) for one fused N-pair sharded batch of
    ``kind``.  All fragment data rides in as arguments, so one compiled
    program per (mesh, geometry, fragments-per-device, batch-bucket)
    serves every batch and stays valid across in-place graph deltas and
    re-placements."""
    mesh, placement = _resolve_placement(fr, mesh, placement)
    if chaos is not None:
        chaos.maybe_fail("upload")     # guards the _device_inputs transfer
    k, n_max, N = fr.k, fr.n_max, len(pairs)
    ss, tt = pairs[:, 0], pairs[:, 1]
    # per-fragment query inputs: [k, N] local slots of s and t (n_max
    # absent), packed below into the device-major layout
    s_slots = np.full((k, N), n_max, dtype=np.int32)
    s_slots[fr.part[ss], np.arange(N)] = fr.owner_local[ss]
    t_slots = fr.slot_index()[tt, :].T.copy()              # [k, N]
    dev = _device_inputs(fr, placement)
    perm, fpd = dev["perm"], placement.fpd
    s_slots = jnp.asarray(_pack_rows(s_slots, perm, n_max))
    t_slots = jnp.asarray(_pack_rows(t_slots, perm, n_max))
    arrs = dev["arrs"]
    if kind == "rpq":
        run = _batch_rpq_jitted(mesh, fr.n_boundary, n_max, fr.B,
                                qa.n_states, int(qa.start), fpd, N)
        args = (arrs["esrc"], arrs["edst"], arrs["src_local"],
                arrs["src_row"], arrs["tgt_local"], arrs["labels"],
                arrs["gids"], s_slots, t_slots,
                dev["mine"], jnp.asarray(qa.state_labels),
                jnp.asarray(qa.trans), jnp.asarray(ss.astype(np.int32)),
                jnp.asarray(tt.astype(np.int32)), dev["local_b"])
        return run, args
    jitted = {"reach": _batch_reach_jitted, "dist": _batch_dist_jitted}
    run = jitted[kind](mesh, fr.n_boundary, n_max, fpd, N)
    args = (arrs["esrc"], arrs["edst"], arrs["src_local"],
            arrs["tgt_local"], s_slots, t_slots,
            dev["srcidx"], dev["own"])
    return run, args


def _as_batch_pairs(pairs) -> np.ndarray:
    return np.asarray(pairs, dtype=np.int64).reshape(-1, 2)


def dis_reach_batch_sharded(fr: Fragmentation, pairs,
                            mesh: Optional[Mesh] = None,
                            placement: Optional[Placement] = None,
                            chaos=None) -> np.ndarray:
    """Answer N (s, t) pairs over the device mesh with a single collective.

    Each device contributes, for its owned fragments (one or several,
    per ``placement``): their rows of the boundary dependency matrix D0
    (all-sources local fixpoints), the s-row of every pair whose source
    they own, and the t-column entries of their own in-nodes — OR-merged
    on-device first, so the wire is identical to the one-fragment-per-
    device layout.  All three ride ONE bitpacked psum (== OR: every bit
    is computed on exactly one device); the closure and the per-pair
    combine run replicated.
    """
    pairs = _as_batch_pairs(pairs)
    if len(pairs) == 0:
        return np.zeros(0, dtype=bool)
    run, args = _batch_sharded_program(fr, pairs, "reach", mesh=mesh,
                                       placement=placement, chaos=chaos)
    if chaos is not None:
        chaos.maybe_fail("engine.shard_map", pairs=pairs)
    ans = np.array(run(*args))
    ans[pairs[:, 0] == pairs[:, 1]] = True
    return ans


def dis_dist_batch_sharded(fr: Fragmentation, pairs,
                           mesh: Optional[Mesh] = None,
                           placement: Optional[Placement] = None,
                           chaos=None) -> np.ndarray:
    """Tropical twin of :func:`dis_reach_batch_sharded`: N shortest
    distances with ONE int32 pmin collective (W0 rows + per-pair tropical
    s-rows and t-columns; a device's owned fragments min-merge on-device
    first).  Returns [N] int64 with -1 for unreachable — the same
    contract as the host ``cache.dis_dist_batch``."""
    pairs = _as_batch_pairs(pairs)
    if len(pairs) == 0:
        return np.zeros(0, dtype=np.int64)
    run, args = _batch_sharded_program(fr, pairs, "dist", mesh=mesh,
                                       placement=placement, chaos=chaos)
    if chaos is not None:
        chaos.maybe_fail("engine.shard_map", pairs=pairs)
    d = np.asarray(run(*args)).astype(np.int64)
    d[d >= int(engine.INF)] = -1
    return d


def dis_rpq_batch_sharded(fr: Fragmentation, pairs, qa: QueryAutomaton,
                          mesh: Optional[Mesh] = None,
                          placement: Optional[Placement] = None,
                          chaos=None) -> np.ndarray:
    """Product-automaton twin of :func:`dis_reach_batch_sharded` for one
    automaton: each device ships its owned fragments' product rvset rows
    plus N forward / reverse product propagations' contributions in ONE
    bitpacked psum; the (nb|Q|)^2 closure and combine run replicated.
    Returns [N] bool (s == t answered by nullability, like
    ``cache.dis_rpq_batch``)."""
    pairs = _as_batch_pairs(pairs)
    if len(pairs) == 0:
        return np.zeros(0, dtype=bool)
    run, args = _batch_sharded_program(fr, pairs, "rpq", qa=qa, mesh=mesh,
                                       placement=placement, chaos=chaos)
    if chaos is not None:
        chaos.maybe_fail("engine.shard_map", pairs=pairs)
    ans = np.array(run(*args))
    ans[pairs[:, 0] == pairs[:, 1]] = bool(qa.nullable)
    return ans


def lower_batch_hlo(fr: Fragmentation, pairs, kind: str,
                    qa: Optional[QueryAutomaton] = None,
                    mesh: Optional[Mesh] = None,
                    placement: Optional[Placement] = None) -> str:
    """Lowered HLO text of one fused sharded batch of ``kind`` — used by
    tests to assert the one-collective-per-group guarantee and the payload
    dtype/shape structurally, for all three query classes (including
    packed d < k placements)."""
    pairs = _as_batch_pairs(pairs)
    run, args = _batch_sharded_program(fr, pairs, kind, qa=qa, mesh=mesh,
                                       placement=placement)
    return run.lower(*args).as_text()


# ---------------------------------------------------------------------------
# sharded incremental cache maintenance (DESIGN.md Sec. 3.5)
# ---------------------------------------------------------------------------

def _changed_row_inputs(fr: Fragmentation, row_ids: np.ndarray):
    """Per-device gather indices for the changed boundary rows: for each
    fragment, the source-row index of every changed position it owns
    (pad ``s_max-1`` — the reserved s slot, never a real in-node row —
    elsewhere) plus the ownership mask."""
    k, S = fr.k, fr.s_max
    src_row = fr.arrays["src_row"]                         # [k, S]
    srcidx = np.full((k, len(row_ids)), S - 1, dtype=np.int32)
    own = np.zeros((k, len(row_ids)), dtype=bool)
    inv = {}
    for f in range(k):
        for j in np.nonzero(src_row[f] < fr.B - 2)[0]:
            inv[int(src_row[f, j])] = (f, int(j))
    for c, r in enumerate(row_ids):
        f, j = inv[int(r)]
        srcidx[f, c] = j
        own[f, c] = True
    return srcidx, own


@functools.lru_cache(maxsize=32)
def _update_rows_jitted(mesh: Mesh, nb: int, n_max: int, fpd: int):
    """Compiled-program cache for the sharded update: one entry per
    (mesh, boundary, slot, fragments-per-device) geometry; jit then caches
    per changed-row bucket shape, so steady-state deltas never retrace."""
    in_specs = tuple(P(FRAG_AXIS) for _ in range(6))

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=(P(), P(FRAG_AXIS)))
    def run(esrc, edst, init, srcidx, own, tgt_local):
        # [fpd, ...] per device: resume every owned fragment's fixpoint
        # (fragments untouched by the delta — including ones co-packed
        # with a dirty neighbour — start at fixpoint and converge in one
        # relaxation; inert pads converge in zero)
        F = jax.vmap(functools.partial(
            engine.resume_frontier_reach, n_max=n_max))(
            esrc, edst, init)                              # [fpd, S, n+1]

        def one(Ff, sidx, ownf, tloc):
            rows = jnp.take(Ff, sidx, axis=0)              # [r, n+1]
            return jnp.take(rows, tloc[:nb], axis=1) & ownf[:, None]

        d0r = jnp.any(jax.vmap(one)(F, srcidx, own, tgt_local), axis=0)
        # the ONE update collective: changed rows only, bitpacked (pmax ==
        # OR: each row is owned by exactly one device, others ship zeros)
        merged = unpack_payload(jax.lax.pmax(pack_payload(d0r), FRAG_AXIS),
                                nb)
        return merged, F

    return jax.jit(run)


def _update_rows_program(fr: Fragmentation, warm_init: np.ndarray,
                         row_ids: np.ndarray, mesh: Mesh,
                         placement: Placement):
    perm = placement.perm()
    srcidx, own = _changed_row_inputs(fr, row_ids)
    dev = _device_inputs(fr, placement)
    arrs = (dev["arrs"]["esrc"], dev["arrs"]["edst"],
            jnp.asarray(_pack_rows(np.asarray(warm_init), perm, False)),
            jnp.asarray(_pack_rows(srcidx, perm, fr.s_max - 1)),
            jnp.asarray(_pack_rows(own, perm, False)),
            dev["arrs"]["tgt_local"])
    return (_update_rows_jitted(mesh, fr.n_boundary, fr.n_max,
                                placement.fpd), arrs)


def _unpack_rows(packed: np.ndarray, perm: np.ndarray, k: int) -> np.ndarray:
    """Invert :func:`_pack_rows`: device-major [d*fpd, ...] back to the
    stacked per-fragment [k, ...] order (pad slots dropped)."""
    valid = perm >= 0
    out = np.zeros((k,) + packed.shape[1:], dtype=packed.dtype)
    out[perm[valid]] = packed[valid]
    return out


def update_rows_sharded(fr: Fragmentation, warm_init: np.ndarray,
                        row_ids: np.ndarray, mesh: Optional[Mesh] = None,
                        placement: Optional[Placement] = None):
    """Recompute the changed D0 rows over the device mesh.

    Every device resumes its owned fragments' all-sources fixpoints from
    ``warm_init`` (clean fragments are already at fixpoint and converge in
    one relaxation), then contributes the rows of ``row_ids`` it owns.
    The ONE collective ships only the *changed* bitpacked rows —
    ``len(row_ids) x ceil(nb/32)`` uint32 words, not the whole matrix.

    Returns ``(rows, frontiers)``: the merged [r, nb] changed rows
    (replicated) and the per-fragment [k, S, n_max+1] frontiers (sharded
    outputs unpacked from the device-major layout, no extra
    communication).
    """
    mesh, placement = _resolve_placement(fr, mesh, placement)
    run, arrs = _update_rows_program(fr, warm_init, row_ids, mesh,
                                     placement)
    rows, fronts = run(*arrs)
    fronts = _unpack_rows(np.asarray(fronts), placement.perm(), fr.k)
    return rows, jnp.asarray(fronts)


def lower_update_hlo(fr: Fragmentation, warm_init: np.ndarray,
                     row_ids: np.ndarray,
                     mesh: Optional[Mesh] = None,
                     placement: Optional[Placement] = None) -> str:
    """Lowered HLO of the sharded cache-update program — used by tests to
    assert the changed-rows-only payload structurally."""
    mesh, placement = _resolve_placement(fr, mesh, placement)
    run, arrs = _update_rows_program(fr, warm_init, row_ids, mesh,
                                     placement)
    return run.lower(*arrs).as_text()


def apply_delta_sharded(fr: Fragmentation, delta, mesh: Optional[Mesh] = None,
                        placement: Optional[Placement] = None, chaos=None):
    """Sharded twin of :func:`repro.core.incremental.apply_delta` for
    insert-only deltas against a reach cache: each fragment's frontier
    resume runs on its owning device (dirty fragments co-packed with
    clean ones only redo their own fixpoint) and the update collective
    ships only the changed bitpacked D0 rows; the rank-style closure
    update runs replicated (exactly like evalDG).  Deletions, rebuilds,
    and tropical caches fall back to the host path.

    Like the host path, the ``delta.repair`` chaos site fires *after* the
    host arrays mutate — rollback is the caller's job.
    """
    from . import incremental
    from .cache import _boundary_rows, get_rvset_cache

    cache = get_rvset_cache(fr)
    if (delta.is_empty() or delta.n_del or cache.bl_dist is not None):
        return incremental.apply_delta(fr, delta, chaos=chaos)
    warm = np.zeros((fr.k, fr.s_max, fr.n_max + 1), dtype=bool)
    bl_host = np.asarray(cache.bl_frontier)
    report = fr.apply_delta(delta)
    if chaos is not None:
        chaos.maybe_fail("delta.repair")
    if report.rebuilt:
        return incremental.rebuild_cache(fr, cache.version, report,
                                         with_dist=False,
                                         reason=report.reason)
    for f in range(fr.k):
        init, _, _ = incremental._frontier_init(fr, f, bl_host, dist=False)
        warm[f] = np.asarray(init)
    row_ids = incremental.changed_row_ids(fr, report.dirty)
    if row_ids.size == 0:      # dirty fragments own no boundary rows:
        incremental._update_frontiers(cache, report.dirty, warm=True)
        cache.refresh_device_arrays(incremental.touched_arrays(report))
        return incremental.UpdateStats(mode="repair_sharded",
                                       **incremental._stats_base(report))
    padded = incremental.pad_row_ids(row_ids, cap=fr.n_boundary)
    rows_new, fronts = update_rows_sharded(fr, warm, padded, mesh=mesh,
                                           placement=placement)
    cache.bl_frontier = _boundary_rows(fr, fronts, False,
                                       lambda ref, v: ref.max(v))
    cache.closure = incremental._rank_update_bool(cache.closure, rows_new,
                                                  padded)
    cache.refresh_device_arrays(incremental.touched_arrays(report))
    return incremental.UpdateStats(mode="repair_sharded",
                                   changed_rows=int(row_ids.size),
                                   **incremental._stats_base(report))
