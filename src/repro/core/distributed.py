"""shard_map engine: one fragment per device (or device group).

This is the production path: fragments live sharded across the mesh, each
device runs localEval on its own fragment with *zero* communication, then a
single collective assembles the dependency matrix, and evalDG runs
replicated (see DESIGN.md Sec. 2 for why replication beats a coordinator on
a torus).

Performance-guarantee mapping (checked by tests/test_distributed.py):
  * "each site visited once"        -> exactly one collective in the HLO;
  * "traffic O(|V_f|^2)" bits       -> the collective payload is the B x B
    (bit-packable) Boolean matrix, independent of |G|;
  * "time O(|F_m| |V_f|)"           -> per-device localEval work, done in
    parallel; evalDG adds O(diam(G_f) |V_f|^2) replicated FLOPs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import engine
from .automaton import QueryAutomaton
from .fragments import Fragmentation, query_slots

FRAG_AXIS = "frag"


def fragment_mesh(k: Optional[int] = None, devices=None) -> Mesh:
    """1-D mesh with one shard per fragment."""
    devices = np.array(jax.devices() if devices is None else devices)
    k = len(devices) if k is None else k
    assert len(devices) >= k, f"need >= {k} devices, have {len(devices)}"
    return jax.make_mesh((k,), (FRAG_AXIS,), devices=devices[:k])


def _shard_args(fr: Fragmentation, s: int, t: int):
    qs = query_slots(fr, s, t)
    args = {k: jnp.asarray(v) for k, v in fr.arrays.items()}
    args["s_local"] = jnp.asarray(qs["s_local"])
    args["t_local"] = jnp.asarray(qs["t_local"])
    return args


def _specs():
    sharded = P(FRAG_AXIS)
    return dict(esrc=sharded, edst=sharded, src_local=sharded,
                src_row=sharded, tgt_local=sharded, labels=sharded,
                gids=sharded, n_local=sharded,
                s_local=sharded, t_local=sharded)


def dis_reach_sharded(fr: Fragmentation, s: int, t: int,
                      mesh: Optional[Mesh] = None):
    """disReach over a device mesh; returns (answer, D) replicated."""
    if s == t:
        return True
    mesh = mesh or fragment_mesh(fr.k)
    assert mesh.devices.size == fr.k, "one device (shard) per fragment"
    args = _shard_args(fr, s, t)
    specs = _specs()
    in_specs = tuple(specs[k] for k in
                     ("esrc", "edst", "src_local", "src_row", "tgt_local",
                      "s_local", "t_local"))
    tgt_cols, src_rows, bt = _answer_masks(fr, t)

    @functools.partial(jax.shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=(P(), P()))
    def run(esrc, edst, src_local, src_row, tgt_local, s_local, t_local):
        rloc = engine.local_eval_reach(
            esrc[0], edst[0], src_local[0], src_row[0], tgt_local[0],
            s_local[0], t_local[0], n_max=fr.n_max, B=fr.B)
        # the single collective: OR-reduce the boundary matrices
        D = jax.lax.pmax(rloc.astype(jnp.uint8), FRAG_AXIS) > 0
        ans = engine.evaldg_reach(D, src_rows, tgt_cols)
        return ans, D

    ans, D = jax.jit(run)(*(args[k] for k in
                            ("esrc", "edst", "src_local", "src_row",
                             "tgt_local", "s_local", "t_local")))
    return bool(ans), np.asarray(D)


def _answer_masks(fr: Fragmentation, t: int):
    tgt_cols = np.zeros(fr.B, dtype=bool)
    tgt_cols[fr.T_COL] = True
    bt = int(fr.b_index[t])
    if bt >= 0:
        tgt_cols[bt] = True
    src_rows = np.zeros(fr.B, dtype=bool)
    src_rows[fr.S_ROW] = True
    return jnp.asarray(tgt_cols), jnp.asarray(src_rows), bt


def dis_rpq_sharded(fr: Fragmentation, s: int, t: int, qa: QueryAutomaton,
                    mesh: Optional[Mesh] = None):
    if s == t:
        return bool(qa.nullable)
    mesh = mesh or fragment_mesh(fr.k)
    args = _shard_args(fr, s, t)
    Q = qa.n_states
    q_labels = jnp.asarray(qa.state_labels)
    q_trans = jnp.asarray(qa.trans)

    src_rows = np.zeros(fr.B * Q, dtype=bool)
    src_rows[fr.S_ROW * Q + qa.start] = True
    tgt_cols = np.zeros(fr.B * Q, dtype=bool)
    tgt_cols[fr.T_COL * Q + qa.final] = True
    bt = int(fr.b_index[t])
    if bt >= 0:
        tgt_cols[bt * Q + qa.final] = True
    src_rows, tgt_cols = jnp.asarray(src_rows), jnp.asarray(tgt_cols)

    names = ("esrc", "edst", "src_local", "src_row", "tgt_local", "labels",
             "gids", "s_local", "t_local")
    specs = _specs()
    in_specs = tuple(specs[k] for k in names)

    @functools.partial(jax.shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=P())
    def run(esrc, edst, src_local, src_row, tgt_local, labels, gids,
            s_local, t_local):
        rloc = engine.local_eval_regular(
            esrc[0], edst[0], src_local[0], src_row[0], tgt_local[0],
            labels[0], gids[0], q_labels, q_trans,
            s_local[0], t_local[0], jnp.int32(s), jnp.int32(t),
            n_max=fr.n_max, B=fr.B)
        D = jax.lax.pmax(rloc.astype(jnp.uint8), FRAG_AXIS) > 0
        return engine.evaldg_reach(D, src_rows, tgt_cols)

    ans = jax.jit(run)(*(args[k] for k in names))
    return bool(ans)


def lower_reach_hlo(fr: Fragmentation, s: int, t: int,
                    mesh: Optional[Mesh] = None) -> str:
    """Lowered HLO text of the sharded disReach — used by tests to assert
    the one-collective-round guarantee structurally."""
    mesh = mesh or fragment_mesh(fr.k)
    args = _shard_args(fr, s, t)
    specs = _specs()
    names = ("esrc", "edst", "src_local", "src_row", "tgt_local",
             "s_local", "t_local")
    in_specs = tuple(specs[k] for k in names)
    tgt_cols, src_rows, _ = _answer_masks(fr, t)

    @functools.partial(jax.shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=P())
    def run(esrc, edst, src_local, src_row, tgt_local, s_local, t_local):
        rloc = engine.local_eval_reach(
            esrc[0], edst[0], src_local[0], src_row[0], tgt_local[0],
            s_local[0], t_local[0], n_max=fr.n_max, B=fr.B)
        D = jax.lax.pmax(rloc.astype(jnp.uint8), FRAG_AXIS) > 0
        return engine.evaldg_reach(D, src_rows, tgt_cols)

    lowered = jax.jit(run).lower(*(args[k] for k in names))
    return lowered.as_text()
