"""Partial-evaluation engine: localEval + evalDG in pure JAX.

This is the paper's contribution (Sections 3-5), restructured for SPMD
hardware (see DESIGN.md Section 2):

* ``local_eval_reach``   — procedure localEval  (Fig. 3): per-fragment
  Boolean reachability from every owned in-node (and s) to every virtual
  node (and t), computed as *batched frontier propagation* over the
  fragment's padded edge list instead of per-source DFS.  One call == one
  site's partial answer; it never communicates.
* ``local_eval_dist``    — procedure localEval_d (Sec. 4): same, over the
  tropical (min, +) semiring, values clipped at the query bound.
* ``local_eval_regular`` — procedure localEval_r (Fig. 7): same, lifted to
  the product with the query automaton G_q(R).
* ``evaldg_reach / evaldg_dist`` — procedures evalDG / evalDG_d / evalDG_r:
  the coordinator's Boolean-equation-system solve, expressed as
  single-source fixpoint iteration on the dependency-graph matrix (or-and /
  min-plus vector-matrix products) — O(diam(G_f) * |V_f|^2) work.  evalDG_r
  reuses ``evaldg_reach`` on the (|V_f|*|Q|)-sized product matrix.

All functions are shape-static and jit/vmap/shard_map-compatible; the
fragment axis is mapped *outside* (``api.py`` uses vmap for single-host
evaluation, ``distributed.py`` uses shard_map across a device mesh).

Conventions (set up by ``fragments.fragment_graph``):
  * local node slots 0..n_max-1 are real nodes + virtual stubs; slot n_max is
    the pad node; pad edges self-loop on it; pad target columns point at it.
  * boundary rows/cols 0..B-3 are V_f in-nodes; row B-2 is s; col B-1 is t;
    row index B means "dropped" (scatter mode='drop').
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

INF = jnp.int32(1 << 29)


class QueryStats(NamedTuple):
    """Measured guarantees (paper Theorems 1-3).

    Queries served inside a fused batch carry *group-amortized* stats
    (core.session): the group's ONE collective is split across its
    queries, so summing over any group yields exactly the wire size of
    that collective and one round — never N copies of it.
    """
    payload_bits: int        # rvset bits shipped (<= |V_f|^2 or |R|^2|V_f|^2;
                             # amortized share of the group wire when fused)
    collective_rounds: int   # visits per site (seed: 1; fused: 1 per group,
                             # stamped on the group's first query)
    boundary: int            # |V_f| + 2 query slots
    states: int              # |Q| (1 for plain/bounded reachability)


# ---------------------------------------------------------------------------
# local propagation primitives (one fragment; vmapped/shard_mapped outside)
# ---------------------------------------------------------------------------

def _propagate_bool(esrc, edst, frontier):
    """Fixpoint of frontier[v'] |= OR_{(v,v') in E} frontier[v].

    frontier: [S, n_max+1] bool.  Batched over S sources; iterates until no
    change (<= fragment diameter steps).
    """
    n_slots = frontier.shape[-1]

    def step(state):
        seen, _ = state
        msgs = jnp.take(seen, esrc, axis=1)                       # [S, E]
        agg = jax.ops.segment_max(msgs.T.astype(jnp.int8), edst,
                                  num_segments=n_slots)           # [n+1, S]
        new = seen | (agg.T > 0)
        return new, jnp.any(new != seen)

    # init flag derived from the (possibly device-varying) data so the carry
    # type matches under shard_map; all-False frontier needs no iterations.
    frontier, _ = jax.lax.while_loop(lambda st: st[1], step,
                                     (frontier, jnp.any(frontier)))
    return frontier


def _propagate_dist(esrc, edst, dist, cap):
    """Fixpoint of dist[v'] = min(dist[v'], min_{(v,v') in E} dist[v] + 1),
    entries above ``cap`` snapped to INF (paper Sec. 4 keeps dist < l only).
    """
    n_slots = dist.shape[-1]

    def step(state):
        d, _ = state
        msgs = jnp.take(d, esrc, axis=1) + 1                      # [S, E]
        agg = jax.ops.segment_min(msgs.T, edst, num_segments=n_slots)
        new = jnp.minimum(d, agg.T)
        new = jnp.where(new > cap, INF, new)
        return new, jnp.any(new != d)

    dist, _ = jax.lax.while_loop(lambda st: st[1], step,
                                 (dist, jnp.any(dist < INF)))
    return dist


def _with_query_source(src_local, src_row, s_local, n_max: int, B: int):
    """Fill the reserved last source slot with the query source s
    (active only in the fragment owning s; dropped elsewhere)."""
    s_row = jnp.where(s_local < n_max, jnp.int32(B - 2), jnp.int32(B))
    return src_local.at[-1].set(s_local), src_row.at[-1].set(s_row)


# ---------------------------------------------------------------------------
# query-independent frontiers (rvset cache phase; DESIGN.md Sec. 3)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_max",))
def local_frontier_reach(esrc, edst, src_local, *, n_max: int):
    """All-sources local fixpoint WITHOUT the query slots: frontier[j, v] = 1
    iff in-node source j reaches local slot v inside this fragment.

    This is the expensive part of localEval and depends only on the
    fragmentation, so ``core.cache`` computes it once per Fragmentation and
    reuses it for every subsequent query (amortized rvset).
    """
    S = src_local.shape[0]
    frontier = jnp.zeros((S, n_max + 1), dtype=bool)
    frontier = frontier.at[jnp.arange(S), src_local].set(True)
    frontier = frontier.at[:, n_max].set(False)
    return _propagate_bool(esrc, edst, frontier)


@functools.partial(jax.jit, static_argnames=("n_max",))
def local_frontier_dist(esrc, edst, src_local, *, n_max: int):
    """Tropical counterpart of :func:`local_frontier_reach` (uncapped; the
    per-query bound is applied at answer time, which is equivalent for
    shortest distances)."""
    S = src_local.shape[0]
    dist = jnp.full((S, n_max + 1), INF, dtype=jnp.int32)
    dist = dist.at[jnp.arange(S), src_local].min(0)
    dist = dist.at[:, n_max].set(INF)
    return _propagate_dist(esrc, edst, dist, INF)


@functools.partial(jax.jit, static_argnames=("n_max",))
def resume_frontier_reach(esrc, edst, frontier, *, n_max: int):
    """Continue a Boolean all-sources fixpoint from a warm state.

    Used by incremental cache repair (DESIGN.md Sec. 3.5): after edge
    *insertions* the old converged frontier is a valid under-approximation,
    so re-running the fixpoint from it converges in O(new-path length)
    relaxations instead of O(diam).  ``frontier``: [S, n_max+1] bool with
    each row's own source bit already set."""
    frontier = frontier.at[:, n_max].set(False)
    return _propagate_bool(esrc, edst, frontier)


@functools.partial(jax.jit, static_argnames=("n_max",))
def resume_frontier_dist(esrc, edst, dist, *, n_max: int):
    """Tropical twin of :func:`resume_frontier_reach`: the old distances
    are realizable upper bounds after insertions, so relaxation from them
    converges to the new exact distances."""
    dist = dist.at[:, n_max].set(INF)
    return _propagate_dist(esrc, edst, dist, INF)


# ---------------------------------------------------------------------------
# per-query propagation (cheap phase against the cache; DESIGN.md Sec. 3)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_max",))
def single_source_reach(esrc, edst, src, *, n_max: int):
    """One-source Boolean fixpoint on one fragment: frontier [n_max+1] bool.
    ``src == n_max`` (pad) yields the all-false frontier.  vmap the leading
    axis of all three args for the batched multi-query path (each query
    propagates over its own fragment's edge list)."""
    frontier = jnp.zeros((1, n_max + 1), dtype=bool)
    frontier = frontier.at[0, src].set(src < n_max)
    frontier = frontier.at[0, n_max].set(False)
    return _propagate_bool(esrc, edst, frontier)[0]


@functools.partial(jax.jit, static_argnames=("n_max",))
def single_source_dist(esrc, edst, src, *, n_max: int):
    """One-source tropical fixpoint: dist [n_max+1] int32 (INF absent)."""
    dist = jnp.full((1, n_max + 1), INF, dtype=jnp.int32)
    dist = dist.at[0, src].min(jnp.where(src < n_max, 0, INF))
    dist = dist.at[0, n_max].set(INF)
    return _propagate_dist(esrc, edst, dist, INF)[0]


@functools.partial(jax.jit, static_argnames=("n_max",))
def single_source_regular(esrc, edst, labels, gids, q_labels, q_trans,
                          s_slot, q_start, s_gid, t_gid, *, n_max: int):
    """Per-query product-automaton forward fixpoint from (s, u_s) on s's
    fragment: f [n_max+1, Q] bool — f[v, q] = 1 iff a path from s occupying
    the start state reaches local slot v in state q (every step matching)."""
    Q = q_labels.shape[0]
    match = _match_matrix(labels, gids, q_labels, s_gid, t_gid)
    match = match.at[n_max, :].set(False)                     # [n+1, Q]
    f = jnp.zeros((n_max + 1, Q), dtype=bool)
    f = f.at[s_slot, q_start].set((s_slot < n_max) & match[s_slot, q_start])
    # int32 accumulator: an int8 dot wraps once >=128 predecessor states
    # are simultaneously active (wide alternations)
    tf = q_trans.astype(jnp.int32)

    def step(state):
        cur, _ = state
        # advance the automaton, then push along fragment edges
        adv = (cur.astype(jnp.int32) @ tf) > 0                # [n+1, Q]
        msgs = adv[esrc].astype(jnp.int8)                     # [E, Q]
        agg = jax.ops.segment_max(msgs, edst, num_segments=n_max + 1)
        new = cur | ((agg > 0) & match)
        return new, jnp.any(new != cur)

    f, _ = jax.lax.while_loop(lambda st: st[1], step, (f, jnp.any(f)))
    return f


@functools.partial(jax.jit, static_argnames=("n_max",))
def reverse_target_regular(esrc, edst, labels, gids, q_labels, q_trans,
                           t_slot, s_gid, t_gid, *, n_max: int):
    """Per-query product-automaton BACKWARD fixpoint to (t, u_t) on one
    fragment: r [n_max+1, Q] bool — r[v, q] = 1 iff from local slot v
    occupying state q a local path reaches t (or the stub of t) in the
    accepting state, with every step's target matching its state.

    vmapped over all fragments this yields the t-column of the dependency
    matrix without any all-sources work (DESIGN.md Sec. 3.2)."""
    Q = q_labels.shape[0]
    match = _match_matrix(labels, gids, q_labels, s_gid, t_gid)
    match = match.at[n_max, :].set(False)
    r = jnp.zeros((n_max + 1, Q), dtype=bool)
    r = r.at[t_slot, Q - 1].set((t_slot < n_max) & match[t_slot, Q - 1])
    tf = q_trans.astype(jnp.int32)          # int32: see single_source_regular

    def step(state):
        cur, _ = state
        ok = (cur & match).astype(jnp.int8)                   # [n+1, Q']
        msgs = ok[edst]                                       # [E, Q']
        agg = jax.ops.segment_max(msgs, esrc,
                                  num_segments=n_max + 1)     # [n+1, Q']
        pre = ((agg > 0).astype(jnp.int32) @ tf.T) > 0        # [n+1, Q]
        new = cur | pre
        return new, jnp.any(new != cur)

    r, _ = jax.lax.while_loop(lambda st: st[1], step, (r, jnp.any(r)))
    return r


# ---------------------------------------------------------------------------
# localEval: plain reachability (paper Fig. 3, procedure localEval)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_max", "B"))
def local_eval_reach(esrc, edst, src_local, src_row, tgt_local,
                     s_local, t_local, *, n_max: int, B: int):
    """One fragment's rvset, as a row block of the dependency matrix.

    Returns Rloc [B, B] bool: Rloc[row(v), col(w)] = 1 iff source v (owned
    in-node, or s) reaches virtual node w (or t) inside this fragment.  Rows
    owned by other fragments stay all-false, so assembly is elementwise OR —
    a single collective (the paper's "each site is visited only once").
    """
    src_local, src_row = _with_query_source(src_local, src_row, s_local,
                                            n_max, B)
    S = src_local.shape[0]
    frontier = jnp.zeros((S, n_max + 1), dtype=bool)
    frontier = frontier.at[jnp.arange(S), src_local].set(True)
    frontier = frontier.at[:, n_max].set(False)       # pad node never seen
    frontier = _propagate_bool(esrc, edst, frontier)

    # read out virtual-node columns (+ t column) for each source row
    cols = jnp.concatenate([tgt_local[: B - 2],
                            jnp.array([n_max], jnp.int32),      # s col unused
                            t_local[None].astype(jnp.int32)])
    out = jnp.take(frontier, cols, axis=1)            # [S, B]
    out = out & (cols[None, :] < n_max + 1) & (cols[None, :] != n_max)
    rloc = jnp.zeros((B, B), dtype=bool)
    rloc = rloc.at[src_row].max(out, mode="drop")
    return rloc


# ---------------------------------------------------------------------------
# localEval_d: bounded reachability (paper Sec. 4)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_max", "B"))
def local_eval_dist(esrc, edst, src_local, src_row, tgt_local,
                    s_local, t_local, cap, *, n_max: int, B: int):
    """Tropical rvset: Wloc[row(v), col(w)] = local dist(v, w) (INF absent)."""
    src_local, src_row = _with_query_source(src_local, src_row, s_local,
                                            n_max, B)
    S = src_local.shape[0]
    dist = jnp.full((S, n_max + 1), INF, dtype=jnp.int32)
    dist = dist.at[jnp.arange(S), src_local].min(0)
    dist = dist.at[:, n_max].set(INF)
    dist = _propagate_dist(esrc, edst, dist, cap)

    cols = jnp.concatenate([tgt_local[: B - 2],
                            jnp.array([n_max], jnp.int32),
                            t_local[None].astype(jnp.int32)])
    out = jnp.take(dist, cols, axis=1)
    out = jnp.where((cols[None, :] == n_max), INF, out)
    wloc = jnp.full((B, B), INF, dtype=jnp.int32)
    wloc = wloc.at[src_row].min(out, mode="drop")
    return wloc


# ---------------------------------------------------------------------------
# localEval_r: regular reachability (paper Fig. 7)
# ---------------------------------------------------------------------------

def _match_matrix(labels, gids, q_labels, s_gid, t_gid):
    """match[v, q]: node in local slot v can occupy automaton state q.

    q_labels sentinels: >=0 symbol, -1 only-s, -2 only-t, -3 wildcard.
    Pad slots (labels -9 / gids -1) match nothing.
    """
    lv = labels[:, None]
    gv = gids[:, None]
    lq = q_labels[None, :]
    return ((lq >= 0) & (lv == lq)) | \
           ((lq == -3) & (lv >= 0)) | \
           ((lq == -1) & (gv == s_gid)) | \
           ((lq == -2) & (gv == t_gid))


@functools.partial(jax.jit, static_argnames=("n_max", "B"))
def local_eval_regular(esrc, edst, src_local, src_row, tgt_local,
                       labels, gids, q_labels, q_trans,
                       s_local, t_local, s_gid, t_gid, *,
                       n_max: int, B: int):
    """Product-automaton rvset: Rloc [(B*Q), (B*Q)] bool.

    Row (v, q0): the source pair "in-node v occupying state q0"; column
    (w, q'): "path leaves this fragment arriving at virtual node w in state
    q'" (or arrives at t in q').  Equivalent to the paper's vectors of
    Boolean formulas v.rvec[u] over variables X_(w,u').
    """
    Q = q_labels.shape[0]
    src_local, src_row = _with_query_source(src_local, src_row, s_local,
                                            n_max, B)
    S = src_local.shape[0]
    match = _match_matrix(labels, gids, q_labels, s_gid, t_gid)  # [n+1, Q]
    match = match.at[n_max, :].set(False)

    # frontier[j, q0, v, q]: from source pair (src j, state q0) one can reach
    # local slot v occupying state q (all label constraints satisfied).
    src_match = match[src_local, :]                              # [S, Q]
    eye = jnp.eye(Q, dtype=bool)
    frontier = jnp.zeros((S, Q, n_max + 1, Q), dtype=bool)
    frontier = frontier.at[jnp.arange(S)[:, None, None],
                           jnp.arange(Q)[None, :, None],
                           src_local[:, None, None],
                           jnp.arange(Q)[None, None, :]].max(
        (src_match[:, :, None] & eye[None, :, :]))
    frontier = frontier.at[:, :, n_max, :].set(False)

    tf = q_trans.astype(jnp.int32)          # int32: int8 wraps at >=128
                                            # simultaneously-active states

    def step(state):
        f, _ = state
        # advance automaton: f2[j,q0,v,q'] = OR_q f[j,q0,v,q] & trans[q,q']
        f2 = (jnp.einsum("sqnp,pr->sqnr", f.astype(jnp.int32), tf) > 0)
        msgs = jnp.take(f2, esrc, axis=2)                        # [S,Q,E,Q]
        msgs = jnp.moveaxis(msgs, 2, 0).astype(jnp.int8)         # [E,S,Q,Q]
        agg = jax.ops.segment_max(msgs, edst, num_segments=n_max + 1)
        agg = jnp.moveaxis(agg > 0, 0, 2)                        # [S,Q,n+1,Q]
        new = f | (agg & match[None, None, :, :])
        return new, jnp.any(new != f)

    frontier, _ = jax.lax.while_loop(lambda st: st[1], step,
                                     (frontier, jnp.any(frontier)))

    cols = jnp.concatenate([tgt_local[: B - 2],
                            jnp.array([n_max], jnp.int32),
                            t_local[None].astype(jnp.int32)])
    out = jnp.take(frontier, cols, axis=2)                       # [S,Q,B,Q]
    out = out & (cols[None, None, :, None] != n_max)
    out = out.reshape(S, Q, B * Q)

    rows = src_row[:, None] * Q + jnp.arange(Q)[None, :]         # [S, Q]
    rows = jnp.where(src_row[:, None] >= B, B * Q, rows)         # drop pads
    rloc = jnp.zeros((B * Q, B * Q), dtype=bool)
    rloc = rloc.at[rows.reshape(-1)].max(out.reshape(S * Q, B * Q),
                                         mode="drop")
    return rloc


# ---------------------------------------------------------------------------
# evalDG: assembling at the coordinator (paper Fig. 4 / Secs. 4-5)
# ---------------------------------------------------------------------------

def evaldg_reach(D, src_rows, tgt_cols):
    """Single-source fixpoint on the dependency matrix D [B, B] bool.

    x := x OR x@D until fixpoint (<= diam(G_f) or-and vector-matrix
    products, each dispatched to the Pallas MXU kernel on TPU); answer:
    any reachable column in ``tgt_cols``.  src_rows / tgt_cols: masks [B].
    """
    from ..kernels.bool_matmul.ops import or_and_matmul
    # seed the carry from D so its device-varying type matches the body's
    x0 = src_rows | (D[0] & False)

    def step(state):
        x, _ = state
        nxt = x | or_and_matmul(x[None, :], D)[0]
        return nxt, jnp.any(nxt != x)

    x, _ = jax.lax.while_loop(lambda st: st[1], step, (x0, jnp.any(x0)))
    return jnp.any(x & tgt_cols)


def evaldg_dist(W, src_rows, tgt_cols):
    """Single-source tropical fixpoint (Bellman-Ford on G_d; the paper uses
    Dijkstra — Bellman-Ford is the parallel-matrix equivalent).  The
    vector-matrix relax rides the Pallas tropical kernel on TPU.
    Returns min distance onto ``tgt_cols`` (INF if unreachable)."""
    from ..kernels.tropical_matmul.ops import min_plus_matmul
    d0 = jnp.where(src_rows, 0, INF).astype(jnp.int32) + (W[0] & 0)

    def step(state):
        d, _ = state
        relax = min_plus_matmul(d[None, :], W)[0]
        nxt = jnp.minimum(d, relax)
        nxt = jnp.minimum(nxt, INF)
        return nxt, jnp.any(nxt != d)

    d, _ = jax.lax.while_loop(lambda st: st[1], step,
                              (d0, jnp.any(d0 < INF)))
    return jnp.min(jnp.where(tgt_cols, d, INF))
