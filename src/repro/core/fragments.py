"""Fragmentation F = (F, G_f) of a graph (paper Section 2.1), padded for SPMD.

Host-side preparation that turns ``(Graph, partition)`` into a uniform,
padded pytree of per-fragment arrays so that one ``shard_map``/``vmap``
program evaluates ``localEval`` on every fragment in parallel — the paper's
"each site computes its partial answer in parallel" with a *single* program.

Local node layout inside fragment ``F_i`` (paper Fig. 1 / Sec 2.1):

  * locals ``0 .. n_i-1``      — the real nodes ``V_i`` (partition class i);
  * locals ``n_i .. n_i+o_i-1`` — *virtual nodes* ``F_i.O``: one stub per
    distinct cross-edge target (labels copied from the target node so that
    regular queries can match on them);
  * local ``Nmax``              — a pad node; pad edges self-loop on it.

The *fragment graph* ``G_f``'s node set ``V_f`` is materialized as
``bnodes``: every node with an incoming cross edge (== every in-node ==
every virtual-node origin), plus two reserved dynamic slots for the query
endpoints: row/col ``B-2`` is ``s`` and col ``B-1`` is ``t`` (the paper adds
``s`` to iset and ``t`` to oset at query time; we reserve static slots so the
compiled program is query-independent).

Dynamic graphs (DESIGN.md Sec. 3.5): a fragmentation built with
``reserve_*`` headroom additionally carries *spare* capacity — extra edge
slots, virtual-stub slots, source slots, and boundary positions
``nb_active .. nb_cap-1`` — so ``apply_delta`` can absorb edge insertions
and deletions without changing any device array shape (jit-stable).  Spare
boundary slots are inert until activated: no source row maps to them, their
frontier rows stay empty, and their target columns point at the pad node,
so every existing kernel reads them as all-false / INF.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..graph.graph import Graph


@dataclasses.dataclass
class GraphDelta:
    """A batch of edge insertions and deletions against a fragmented graph.

    Node set and partition are fixed; only edges change (the paper's
    fragmentation is node-partitioned, so edge churn never moves a node
    between sites).  Deletions must name existing edges; one (u, v) entry
    removes one occurrence (multi-edges are deleted one at a time).
    """

    add_src: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))
    add_dst: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))
    del_src: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))
    del_dst: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))

    def __post_init__(self):
        for name in ("add_src", "add_dst", "del_src", "del_dst"):
            setattr(self, name, np.asarray(getattr(self, name),
                                           dtype=np.int64).reshape(-1))
        assert self.add_src.shape == self.add_dst.shape
        assert self.del_src.shape == self.del_dst.shape

    @property
    def n_add(self) -> int:
        return int(self.add_src.size)

    @property
    def n_del(self) -> int:
        return int(self.del_src.size)

    def is_empty(self) -> bool:
        return self.n_add == 0 and self.n_del == 0

    @classmethod
    def insert(cls, edges) -> "GraphDelta":
        e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        return cls(add_src=e[:, 0], add_dst=e[:, 1])

    @classmethod
    def delete(cls, edges) -> "GraphDelta":
        e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        return cls(del_src=e[:, 0], del_dst=e[:, 1])


@dataclasses.dataclass
class DeltaReport:
    """What ``Fragmentation.apply_delta`` changed (drives cache repair)."""

    dirty: np.ndarray            # [k] bool: fragments with local changes
    new_boundary: List[int]      # global ids activated into spare slots
    n_add_intra: int = 0
    n_add_cross: int = 0
    n_del: int = 0
    rebuilt: bool = False        # capacity exhausted -> rebuilt from scratch
    reason: str = ""


@dataclasses.dataclass
class Fragmentation:
    """Host metadata + stacked padded per-fragment arrays."""

    g: Graph
    part: np.ndarray          # [n] fragment id per node
    k: int                    # number of fragments (sites)
    bnodes: np.ndarray        # [B-2] global ids of boundary nodes (V_f)
    b_index: np.ndarray       # [n] position in bnodes or -1
    n_max: int                # max local slots (real + stubs) over fragments
    e_max: int                # max local edges over fragments
    s_max: int                # max sources per fragment (in-nodes + 1 for s)
    arrays: Dict[str, np.ndarray]   # stacked [k, ...] device-ready arrays
    frag_sizes: np.ndarray    # [k] |F_i| = n_i + e_i  (paper's |F_i|)
    # local index of every *global* node inside its owning fragment
    owner_local: np.ndarray   # [n]
    # amortized rvset cache (built lazily by core.cache.get_rvset_cache)
    rvset_cache: object = dataclasses.field(default=None, repr=False,
                                            compare=False)
    _slot_of: np.ndarray = dataclasses.field(default=None, repr=False,
                                             compare=False)
    # --- dynamic-graph bookkeeping (host-side; see apply_delta) ------------
    nb_cap: int = -1          # boundary slot capacity (-1: len(bnodes))
    n_edges: np.ndarray = dataclasses.field(default=None, repr=False,
                                            compare=False)   # [k] used slots
    src_fill: np.ndarray = dataclasses.field(default=None, repr=False,
                                             compare=False)  # [k] used rows
    stubs: List[dict] = dataclasses.field(default=None, repr=False,
                                          compare=False)  # gid -> stub slot
    reserve: Dict[str, int] = dataclasses.field(default=None, repr=False,
                                                compare=False)
    # bumped on every in-place mutation of the host arrays (apply_delta /
    # rebuild) — consumers that memoize device uploads key on it
    arrays_version: int = 0

    @property
    def B(self) -> int:       # boundary matrix side (capacity + query slots)
        return (self.nb_cap if self.nb_cap >= 0 else len(self.bnodes)) + 2

    @property
    def n_boundary(self) -> int:   # boundary matrix rows (|V_f| + spares)
        return self.B - 2

    @property
    def nb_active(self) -> int:    # |V_f| proper: activated boundary slots
        return len(self.bnodes)

    def boundary_owner(self) -> np.ndarray:
        """[n_boundary] int32: owning fragment of each boundary slot (spare
        slots map to fragment 0 — inert, since no frontier row or target
        column ever carries data for them)."""
        own = np.zeros(self.n_boundary, dtype=np.int32)
        own[: self.nb_active] = self.part[self.bnodes]
        return own

    def boundary_local(self) -> np.ndarray:
        """[n_boundary] int32: local slot of each boundary node inside its
        owning fragment (pad slot ``n_max`` for spare positions)."""
        loc = np.full(self.n_boundary, self.n_max, dtype=np.int32)
        loc[: self.nb_active] = self.owner_local[self.bnodes]
        return loc

    def slot_index(self) -> np.ndarray:
        """[n, k] int32: local slot of every global node inside every
        fragment — its owned slot in its home fragment, its virtual-stub
        slot in fragments that have a cross edge to it, ``n_max`` elsewhere.
        Query-independent; built once and memoized (the per-query phase of
        the cached engine is pure gathers against this index)."""
        if self._slot_of is None:
            slot_of = np.full((self.g.n, self.k), self.n_max, dtype=np.int32)
            gids = self.arrays["gids"]               # [k, n_max+1], pad -1
            for f in range(self.k):
                valid = np.nonzero(gids[f] >= 0)[0]
                slot_of[gids[f, valid], f] = valid
            self._slot_of = slot_of
        return self._slot_of

    @property
    def S_ROW(self) -> int:   # reserved boundary row/col for s
        return self.B - 2

    @property
    def T_COL(self) -> int:   # reserved boundary col for t
        return self.B - 1

    def fragment_of(self, v: int) -> int:
        return int(self.part[v])

    def traffic_bits_reach(self) -> int:
        """Upper bound the paper proves: O(|V_f|^2) bits of rvset payload."""
        return self.B * self.B

    def packed_traffic_bits(self, states: int = 1) -> int:
        """Bits the one collective actually ships once the Boolean payload
        is bitpacked into uint32 words (kernels.bitpack_ops): rows x
        ceil(cols/32) words.  ``states`` > 1 gives the product-automaton
        (B*|Q|)^2-shaped regular case."""
        from ..kernels.bitpack_ops.ops import packed_bits
        side = self.B * states
        return packed_bits(side, side)

    def traffic_bits(self, kind: str = "reach", states: int = 1,
                     batch: Optional[int] = None) -> int:
        """Wire size of the ONE collective (DESIGN.md Sec. 4).  All query
        classes route through here so ``QueryStats.payload_bits`` stays
        consistent across kinds.

        Single query (``batch=None``) — the seed engine's assembled matrix:

        * ``reach`` / ``rpq``: Boolean payload, bitpacked into uint32 words
          — ``side * ceil(side/32) * 32`` bits with ``side = B * states``;
        * ``dist`` / ``bounded``: tropical payload — int32 distances do not
          bitpack, so the wire carries the full ``side * side * 32`` bits.

        Fused sharded batch (``batch=N``, the ``dis_*_batch_sharded``
        engines): the collective carries only the rows actually
        contributed — the ``side = |V_f| * states`` query-independent
        D0/W0 rows plus one s-row and one t-column row per query, each
        ``side + 1`` wide (the extra column is the per-pair direct
        answer).  Boolean payloads bitpack to
        ``(side + 2N) * ceil((side+1)/32) * 32`` bits; the tropical wire
        ships raw int32 — ``(side + 2N) * (side + 1) * 32`` bits — never
        the ``B^2`` matrix per query.

        Both formulas are placement-independent: when several fragments
        share a device (k >> d, DESIGN.md Sec. 6) the owned rows are
        merged on-device *before* the collective, so the wire is
        bit-identical to the one-fragment-per-device layout and packing
        adds zero traffic.
        """
        if kind not in ("reach", "dist", "bounded", "rpq"):
            raise ValueError(f"unknown query kind {kind!r}; expected one of "
                             "('reach', 'dist', 'bounded', 'rpq')")
        if batch is None:
            if kind in ("reach", "rpq"):
                return self.packed_traffic_bits(states=states)
            side = self.B * states
            return side * side * 32
        side = self.n_boundary * states
        rows, cols = side + 2 * batch, side + 1
        if kind in ("reach", "rpq"):
            from ..kernels.bitpack_ops.ops import packed_bits
            return packed_bits(rows, cols)
        return rows * cols * 32

    def largest_fragment(self) -> int:
        return int(self.frag_sizes.max())

    # -- rollback snapshots (failed-delta recovery; DESIGN.md Sec. 7) ------

    def snapshot(self) -> dict:
        """Capture every piece of host state a delta (apply + cache
        repair) can touch, so a failed update can roll back to a
        consistent pre-delta point.  Arrays that :meth:`apply_delta`
        mutates *in place* are copied; fields that are only ever rebound
        wholesale (``g``, ``bnodes``, the whole-object rebinds of
        ``_rebuild_in_place``) are captured by reference.  The attached
        rvset cache is snapshotted too (its repairs rebind immutable jax
        arrays, so its snapshot is shallow)."""
        snap = {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}
        snap["arrays"] = {k: v.copy() for k, v in self.arrays.items()}
        snap["b_index"] = self.b_index.copy()
        snap["frag_sizes"] = self.frag_sizes.copy()
        for name in ("n_edges", "src_fill", "_slot_of"):
            v = getattr(self, name)
            if v is not None:
                snap[name] = v.copy()
        if self.stubs is not None:
            snap["stubs"] = [dict(s) for s in self.stubs]
        snap["_cache_state"] = (None if self.rvset_cache is None
                                else self.rvset_cache.snapshot())
        return snap

    def restore(self, snap: dict) -> None:
        """Roll back to a :meth:`snapshot`: ``arrays_version`` and the
        attached cache's ``version`` return to their pre-delta values and
        all host arrays to their pre-delta contents.  The memoized sharded
        device uploads are dropped — the version counter can be re-bumped
        to the same value after a rollback, so a stale memo must never
        survive one."""
        cache_state = snap["_cache_state"]
        for f in dataclasses.fields(self):
            setattr(self, f.name, snap[f.name])
        if self.rvset_cache is not None and cache_state is not None:
            self.rvset_cache.restore(cache_state)
        self.__dict__.pop("_sharded_device_inputs", None)

    # -- dynamic updates (DESIGN.md Sec. 3.5) ------------------------------

    def apply_delta(self, delta: GraphDelta) -> DeltaReport:
        """Apply a :class:`GraphDelta` to the fragmentation *in place*.

        Insertions land in pre-allocated padded slots (edges, virtual stubs,
        source rows, boundary positions) so no device array changes shape;
        deletions compact the owning fragment's edge list.  When any
        capacity is exhausted the whole fragmentation is rebuilt from the
        updated graph (``report.rebuilt``) with the same reserve headroom.

        Only host structures are touched here — cache repair is the job of
        :mod:`repro.core.incremental` (which calls this first).
        """
        g_new = self._updated_graph(delta)
        report = DeltaReport(dirty=np.zeros(self.k, dtype=bool),
                             new_boundary=[], n_del=delta.n_del)
        if delta.is_empty():
            return report
        try:
            self._apply_insertions(delta, report)
            self._apply_deletions(delta, report)
        except _CapacityExceeded as exc:
            self._rebuild_in_place(g_new)
            report.dirty[:] = True
            report.rebuilt = True
            report.reason = str(exc)
            return report
        self.g = g_new
        self.arrays_version += 1
        return report

    def _updated_graph(self, delta: GraphDelta) -> Graph:
        """The post-delta graph (validates deletions against existing
        edges); leaves ``self.g`` untouched.  O((m + n_del) log m) host
        work via one sort of the edge keys — the update path must stay
        cheap relative to the repair it triggers."""
        g = self.g
        keep = np.ones(g.m, dtype=bool)
        if delta.n_del:
            key = g.src * np.int64(g.n) + g.dst
            order = np.argsort(key, kind="stable")
            skey = key[order]
            taken: Dict[int, int] = {}      # dup deletes take distinct ids
            for u, v in zip(delta.del_src, delta.del_dst):
                kk = int(u) * g.n + int(v)
                lo = int(np.searchsorted(skey, kk, "left"))
                hi = int(np.searchsorted(skey, kk, "right"))
                j = lo + taken.get(kk, 0)
                if j >= hi:
                    raise ValueError(
                        f"delta deletes nonexistent edge {u}->{v}")
                taken[kk] = taken.get(kk, 0) + 1
                keep[order[j]] = False
        if delta.n_add:
            ends = np.concatenate([delta.add_src, delta.add_dst])
            if ends.min(initial=0) < 0 or ends.max(initial=-1) >= g.n:
                raise ValueError("delta inserts edge with out-of-range "
                                 f"node id (n={g.n})")
        src = np.concatenate([g.src[keep], delta.add_src])
        dst = np.concatenate([g.dst[keep], delta.add_dst])
        return Graph(g.n, src, dst, g.labels, g.label_names)

    def _apply_insertions(self, delta: GraphDelta, report: DeltaReport):
        esrc, edst = self.arrays["esrc"], self.arrays["edst"]
        for u, w in zip(delta.add_src, delta.add_dst):
            i = int(self.part[u])
            if self.part[w] == i:                      # intra-fragment edge
                dst_slot = int(self.owner_local[w])
                report.n_add_intra += 1
            else:                                      # cross edge -> stub
                self._ensure_boundary(int(w), report)
                dst_slot = self._ensure_stub(i, int(w))
                report.n_add_cross += 1
            slot = int(self.n_edges[i])
            if slot >= self.e_max:
                raise _CapacityExceeded(f"edge slots of fragment {i}")
            esrc[i, slot] = self.owner_local[u]
            edst[i, slot] = dst_slot
            self.n_edges[i] += 1
            self.frag_sizes[i] += 1
            report.dirty[i] = True

    def _apply_deletions(self, delta: GraphDelta, report: DeltaReport):
        esrc, edst = self.arrays["esrc"], self.arrays["edst"]
        for u, w in zip(delta.del_src, delta.del_dst):
            i = int(self.part[u])
            if self.part[w] == i:
                dst_slot = int(self.owner_local[w])
            else:
                dst_slot = self.stubs[i].get(int(w), -1)
            ne = int(self.n_edges[i])
            hits = np.nonzero((esrc[i, :ne] == self.owner_local[u])
                              & (edst[i, :ne] == dst_slot))[0]
            if dst_slot < 0 or hits.size == 0:
                raise _CapacityExceeded(   # stale bookkeeping: rebuild
                    f"deleted edge {u}->{w} not found in fragment {i}")
            j = int(hits[0])
            esrc[i, j], edst[i, j] = esrc[i, ne - 1], edst[i, ne - 1]
            esrc[i, ne - 1] = edst[i, ne - 1] = self.n_max     # pad self-loop
            self.n_edges[i] -= 1
            self.frag_sizes[i] -= 1
            report.dirty[i] = True
        # boundary membership / stubs are left as-is on deletion: a boundary
        # node with no remaining in-edges is inert (sound, costs one slot)
        # until the debt heuristic in core.incremental forces a rebuild.

    def _ensure_boundary(self, w: int, report: DeltaReport):
        """Activate node ``w`` as a boundary in-node in a spare slot."""
        if self.b_index[w] >= 0:
            return
        pos = self.nb_active
        if pos >= self.n_boundary:
            raise _CapacityExceeded("boundary slots")
        j = int(self.part[w])                 # owner gains a source row
        row = int(self.src_fill[j])
        if row >= self.s_max - 1:             # last row is reserved for s
            raise _CapacityExceeded(f"source rows of fragment {j}")
        self.arrays["src_local"][j, row] = self.owner_local[w]
        self.arrays["src_row"][j, row] = pos
        self.src_fill[j] += 1
        self.b_index[w] = pos
        self.bnodes = np.append(self.bnodes, w)
        report.dirty[j] = True
        report.new_boundary.append(w)

    def _ensure_stub(self, i: int, w: int) -> int:
        """Virtual-stub slot of global node ``w`` inside fragment ``i``."""
        slot = self.stubs[i].get(w)
        if slot is not None:
            return slot
        slot = int(self.arrays["n_local"][i])
        if slot >= self.n_max:
            raise _CapacityExceeded(f"local slots of fragment {i}")
        self.stubs[i][w] = slot
        self.arrays["gids"][i, slot] = w
        self.arrays["labels"][i, slot] = self.g.labels[w]
        self.arrays["n_local"][i] = slot + 1
        self.arrays["tgt_local"][i, self.b_index[w]] = slot
        if self._slot_of is not None:
            self._slot_of[w, i] = slot
        return slot

    def rebuild(self) -> None:
        """Re-fragment the current graph from scratch (compacts stale
        boundary slots and stubs left behind by deletions, and restores
        the full reserve headroom).  Drops the attached cache."""
        self._rebuild_in_place(self.g)

    def _rebuild_in_place(self, g_new: Graph):
        """Re-fragment the updated graph with the same reserves and adopt
        the result, keeping this object's identity (callers hold refs)."""
        version = self.arrays_version
        fresh = fragment_graph(g_new, self.part, self.k,
                               **(self.reserve or {}))
        for field in dataclasses.fields(self):
            setattr(self, field.name, getattr(fresh, field.name))
        self.rvset_cache = None
        self.arrays_version = version + 1


class _CapacityExceeded(Exception):
    """A delta outgrew the pre-allocated padded slots: rebuild instead."""


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def fragment_graph(g: Graph, part: np.ndarray, k: int,
                   pad_multiple: int = 8, reserve_boundary: int = 0,
                   reserve_edges: int = 0, reserve_stubs: int = 0,
                   reserve_sources: Optional[int] = None) -> Fragmentation:
    """Build the padded fragmentation (host, numpy).

    ``reserve_*`` pre-allocate headroom for :meth:`Fragmentation.apply_delta`
    so dynamic updates keep every device array shape static:
    ``reserve_boundary`` spare boundary positions (new in-nodes),
    ``reserve_edges`` extra edge slots per fragment, ``reserve_stubs`` extra
    virtual-node slots per fragment, and ``reserve_sources`` extra source
    rows per fragment (defaults to ``reserve_boundary`` — the worst case is
    every new in-node landing in one fragment).
    """
    part = np.asarray(part, dtype=np.int32)
    assert part.shape == (g.n,)
    assert part.min(initial=0) >= 0 and part.max(initial=0) < k
    if reserve_sources is None:
        reserve_sources = reserve_boundary

    cross_mask = part[g.src] != part[g.dst]
    bnodes = np.unique(g.dst[cross_mask])          # in-nodes == V_f core
    b_index = np.full(g.n, -1, dtype=np.int64)
    b_index[bnodes] = np.arange(len(bnodes))
    nb_cap = len(bnodes) + reserve_boundary
    B = nb_cap + 2

    # --- per-fragment local structures -------------------------------------
    glists = [np.where(part == i)[0] for i in range(k)]
    g2l = np.full(g.n, -1, dtype=np.int64)
    for gl in glists:
        g2l[gl] = np.arange(len(gl))

    frag_src = [[] for _ in range(k)]
    frag_dst = [[] for _ in range(k)]
    stub_maps: list[dict] = [dict() for _ in range(k)]   # global id -> stub local

    src_part = part[g.src]
    internal = ~cross_mask
    # internal edges
    for i in range(k):
        sel = internal & (src_part == i)
        frag_src[i] = list(g2l[g.src[sel]])
        frag_dst[i] = list(g2l[g.dst[sel]])
    # cross edges -> stubs
    cs, cd = g.src[cross_mask], g.dst[cross_mask]
    for u, w in zip(cs, cd):
        i = int(part[u])
        sm = stub_maps[i]
        if int(w) not in sm:
            sm[int(w)] = len(glists[i]) + len(sm)
        frag_src[i].append(int(g2l[u]))
        frag_dst[i].append(sm[int(w)])

    n_locals = [len(glists[i]) + len(stub_maps[i]) for i in range(k)]
    n_max = _round_up((max(n_locals) if k else 1) + reserve_stubs,
                      pad_multiple)
    e_max = _round_up(max((len(frag_src[i]) for i in range(k)), default=1)
                      + reserve_edges, pad_multiple)
    e_max = max(e_max, 1)

    in_counts = [int(np.sum(part[bnodes] == i)) for i in range(k)] or [0]
    s_maxr = max(in_counts) + 1 + reserve_sources  # +1 reserved slot for s

    esrc = np.full((k, e_max), n_max, dtype=np.int32)
    edst = np.full((k, e_max), n_max, dtype=np.int32)
    gids = np.full((k, n_max + 1), -1, dtype=np.int32)
    labels = np.full((k, n_max + 1), -9, dtype=np.int32)
    src_local = np.full((k, s_maxr), n_max, dtype=np.int32)
    src_row = np.full((k, s_maxr), B, dtype=np.int32)      # B == dropped
    tgt_local = np.full((k, B), n_max, dtype=np.int32)

    for i in range(k):
        ne = len(frag_src[i])
        esrc[i, :ne] = frag_src[i]
        edst[i, :ne] = frag_dst[i]
        nl = len(glists[i])
        gids[i, :nl] = glists[i]
        labels[i, :nl] = g.labels[glists[i]]
        for w, loc in stub_maps[i].items():
            gids[i, loc] = w
            labels[i, loc] = g.labels[w]
        # sources: in-nodes owned by this fragment
        mine = bnodes[part[bnodes] == i]
        src_local[i, : len(mine)] = g2l[mine]
        src_row[i, : len(mine)] = b_index[mine]
        # targets: stubs for boundary nodes of other fragments
        for w, loc in stub_maps[i].items():
            tgt_local[i, b_index[w]] = loc

    owner_local = g2l
    frag_sizes = np.array(
        [len(glists[i]) + len(frag_src[i]) for i in range(k)], dtype=np.int64)

    arrays = dict(esrc=esrc, edst=edst, gids=gids, labels=labels,
                  src_local=src_local, src_row=src_row, tgt_local=tgt_local,
                  n_local=np.array(n_locals, dtype=np.int32))
    reserve = dict(pad_multiple=pad_multiple,
                   reserve_boundary=reserve_boundary,
                   reserve_edges=reserve_edges, reserve_stubs=reserve_stubs,
                   reserve_sources=reserve_sources)
    return Fragmentation(g=g, part=part, k=k, bnodes=bnodes, b_index=b_index,
                         n_max=n_max, e_max=e_max, s_max=s_maxr,
                         arrays=arrays, frag_sizes=frag_sizes,
                         owner_local=owner_local, nb_cap=nb_cap,
                         n_edges=np.array([len(frag_src[i])
                                           for i in range(k)], np.int64),
                         src_fill=np.array(in_counts[:k] or [0], np.int64),
                         stubs=stub_maps, reserve=reserve)


# ---------------------------------------------------------------------------
# fragment -> device placement (k >> d scale-out; DESIGN.md Sec. 6)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Placement:
    """Fragment-to-device assignment for the sharded backend.

    The paper's model has one *site* per fragment; real meshes are smaller
    than real fragmentations, so the shard_map engines pack several
    fragments onto each device (``d <= k``).  Each device evaluates its
    owned fragments' localEval stages independently (a vmap over the
    owned-fragments axis), OR/min-merges their boundary rows locally, and
    still ships exactly ONE collective per fused batch — the wire size is
    unchanged, and the response-time bound becomes the largest *per-device*
    workload ``max_d sum_{i on d} |F_i|`` instead of the largest fragment.

    ``device_of[i]`` is the device owning fragment ``i``.  Devices hold at
    most :attr:`fpd` fragments; short devices are padded with inert
    fragments (pad-only edge lists, no owned boundary rows) whose
    propagations converge in zero iterations.

    Construct with :meth:`balanced` (greedy workload balancing — the
    default the session picks) or :meth:`round_robin` (the baseline), or
    pass an explicit ``device_of`` for a custom policy.  Instances are
    frozen and hashable; :meth:`cache_key` keys compiled-program and
    device-upload memos.
    """

    k: int                    # fragments
    d: int                    # devices
    device_of: tuple          # [k] owning device per fragment

    def __post_init__(self):
        object.__setattr__(self, "device_of",
                           tuple(int(x) for x in self.device_of))
        if self.d < 1:
            raise ValueError(f"placement needs >= 1 device, got d={self.d}")
        if self.d > self.k:
            raise ValueError(
                f"placement maps {self.k} fragments onto {self.d} devices: "
                "d > k is invalid — shard_map packs whole fragments onto "
                "devices and cannot split one fragment across several; "
                "use a mesh with at most k devices")
        if len(self.device_of) != self.k:
            raise ValueError(f"device_of has {len(self.device_of)} entries "
                             f"for {self.k} fragments")
        bad = [x for x in self.device_of if not (0 <= x < self.d)]
        if bad:
            raise ValueError(f"device_of entries out of range [0, {self.d}): "
                             f"{bad[:4]}")

    # -- constructors -------------------------------------------------------

    @classmethod
    def round_robin(cls, k: int, d: int) -> "Placement":
        """Baseline policy: fragment ``i`` lives on device ``i % d``."""
        return cls(k=k, d=d, device_of=tuple(i % d for i in range(k)))

    @staticmethod
    def fragment_weights(fr: Fragmentation) -> np.ndarray:
        """Per-fragment workload estimate used by :meth:`balanced`.

        The paper bounds response time by O(|F_i| * |V_f|) — each owned
        in-node is one source of the all-sources fixpoint over the
        fragment — so the weight is ``|F_i| * (1 + b_i)`` with ``b_i`` the
        number of boundary rows fragment ``i`` owns (boundary size drives
        both the fixpoint batch and the fragment's share of the wire)."""
        b_owned = np.bincount(fr.part[fr.bnodes],
                              minlength=fr.k).astype(np.int64)
        return fr.frag_sizes.astype(np.int64) * (1 + b_owned)

    @classmethod
    def balanced(cls, fr: Fragmentation, d: int) -> "Placement":
        """Greedy boundary-size balancing (LPT list scheduling).

        Fragments are placed in decreasing :meth:`fragment_weights` order,
        each onto the least-loaded device that still has a free slot
        (devices are capped at ``ceil(k/d)`` fragments so the padded
        owned-fragments axis — and with it compiled shapes and device
        memory — never exceeds the round-robin layout's).  Guarantees the
        standard list-scheduling bound
        ``max_load <= total/d + max_weight`` and is deterministic."""
        k = fr.k
        if d > k:       # same validation as __post_init__, but earlier and
            return cls(k=k, d=d, device_of=())   # with its clear message
        w = cls.fragment_weights(fr)
        cap = -(-k // d)                         # ceil(k/d)
        loads = np.zeros(d, dtype=np.int64)
        counts = np.zeros(d, dtype=np.int64)
        device_of = np.zeros(k, dtype=np.int64)
        for i in np.argsort(-w, kind="stable"):
            free = counts < cap
            cand = np.where(free, loads, np.iinfo(np.int64).max)
            dev = int(np.argmin(cand))           # ties -> lowest device id
            device_of[i] = dev
            loads[dev] += w[i]
            counts[dev] += 1
        return cls(k=k, d=d, device_of=tuple(device_of))

    # -- layout -------------------------------------------------------------

    @property
    def fpd(self) -> int:
        """Owned-fragments axis length per device (max over devices)."""
        return int(max(np.bincount(np.asarray(self.device_of, np.int64),
                                   minlength=self.d).max(initial=0), 1))

    def perm(self) -> np.ndarray:
        """[d * fpd] int64 device-major packing order: entry ``dev*fpd + j``
        is the fragment id in slot ``j`` of device ``dev``, or ``-1`` for
        an inert pad slot.  This is the host-side permutation that packs
        the stacked ``[k, ...]`` fragment arrays into the ``[d*fpd, ...]``
        layout shard_map splits across the mesh."""
        fpd = self.fpd
        out = np.full(self.d * fpd, -1, dtype=np.int64)
        fill = np.zeros(self.d, dtype=np.int64)
        for i, dev in enumerate(self.device_of):
            out[dev * fpd + fill[dev]] = i
            fill[dev] += 1
        return out

    def loads(self, weights: np.ndarray) -> np.ndarray:
        """[d] summed ``weights`` per device (``weights``: [k])."""
        return np.bincount(np.asarray(self.device_of, np.int64),
                           weights=np.asarray(weights, np.float64),
                           minlength=self.d).astype(np.int64)

    def max_load(self, fr: Fragmentation) -> int:
        """Largest per-device workload — what the response-time bound
        scales with once fragments are packed (DESIGN.md Sec. 6)."""
        return int(self.loads(self.fragment_weights(fr)).max(initial=0))

    def cache_key(self) -> tuple:
        """Hashable identity for program-cache / upload-memo keys."""
        return (self.k, self.d, self.device_of)


def query_slots(fr: Fragmentation, s: int, t: int) -> Dict[str, np.ndarray]:
    """Per-query dynamic inputs: where s and t live.

    Returns stacked [k]-arrays: ``s_local``/``t_local`` give the local index
    of s / t inside the owning fragment (pad ``n_max`` elsewhere).  These are
    traced values — changing (s, t) does NOT recompile the engine.
    """
    k, n_max = fr.k, fr.n_max
    s_local = np.full(k, n_max, dtype=np.int32)
    t_local = np.full(k, n_max, dtype=np.int32)
    s_local[fr.part[s]] = fr.owner_local[s]
    t_local[fr.part[t]] = fr.owner_local[t]
    return dict(s_local=s_local, t_local=t_local,
                s_gid=np.int32(s), t_gid=np.int32(t))
