"""Fragmentation F = (F, G_f) of a graph (paper Section 2.1), padded for SPMD.

Host-side preparation that turns ``(Graph, partition)`` into a uniform,
padded pytree of per-fragment arrays so that one ``shard_map``/``vmap``
program evaluates ``localEval`` on every fragment in parallel — the paper's
"each site computes its partial answer in parallel" with a *single* program.

Local node layout inside fragment ``F_i`` (paper Fig. 1 / Sec 2.1):

  * locals ``0 .. n_i-1``      — the real nodes ``V_i`` (partition class i);
  * locals ``n_i .. n_i+o_i-1`` — *virtual nodes* ``F_i.O``: one stub per
    distinct cross-edge target (labels copied from the target node so that
    regular queries can match on them);
  * local ``Nmax``              — a pad node; pad edges self-loop on it.

The *fragment graph* ``G_f``'s node set ``V_f`` is materialized as
``bnodes``: every node with an incoming cross edge (== every in-node ==
every virtual-node origin), plus two reserved dynamic slots for the query
endpoints: row/col ``B-2`` is ``s`` and col ``B-1`` is ``t`` (the paper adds
``s`` to iset and ``t`` to oset at query time; we reserve static slots so the
compiled program is query-independent).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from ..graph.graph import Graph


@dataclasses.dataclass
class Fragmentation:
    """Host metadata + stacked padded per-fragment arrays."""

    g: Graph
    part: np.ndarray          # [n] fragment id per node
    k: int                    # number of fragments (sites)
    bnodes: np.ndarray        # [B-2] global ids of boundary nodes (V_f)
    b_index: np.ndarray       # [n] position in bnodes or -1
    n_max: int                # max local slots (real + stubs) over fragments
    e_max: int                # max local edges over fragments
    s_max: int                # max sources per fragment (in-nodes + 1 for s)
    arrays: Dict[str, np.ndarray]   # stacked [k, ...] device-ready arrays
    frag_sizes: np.ndarray    # [k] |F_i| = n_i + e_i  (paper's |F_i|)
    # local index of every *global* node inside its owning fragment
    owner_local: np.ndarray   # [n]
    # amortized rvset cache (built lazily by core.cache.get_rvset_cache)
    rvset_cache: object = dataclasses.field(default=None, repr=False,
                                            compare=False)
    _slot_of: np.ndarray = dataclasses.field(default=None, repr=False,
                                             compare=False)

    @property
    def B(self) -> int:       # boundary matrix side (|V_f| + 2 query slots)
        return len(self.bnodes) + 2

    @property
    def n_boundary(self) -> int:   # |V_f| proper (without the query slots)
        return len(self.bnodes)

    def slot_index(self) -> np.ndarray:
        """[n, k] int32: local slot of every global node inside every
        fragment — its owned slot in its home fragment, its virtual-stub
        slot in fragments that have a cross edge to it, ``n_max`` elsewhere.
        Query-independent; built once and memoized (the per-query phase of
        the cached engine is pure gathers against this index)."""
        if self._slot_of is None:
            slot_of = np.full((self.g.n, self.k), self.n_max, dtype=np.int32)
            gids = self.arrays["gids"]               # [k, n_max+1], pad -1
            for f in range(self.k):
                valid = np.nonzero(gids[f] >= 0)[0]
                slot_of[gids[f, valid], f] = valid
            self._slot_of = slot_of
        return self._slot_of

    @property
    def S_ROW(self) -> int:   # reserved boundary row/col for s
        return self.B - 2

    @property
    def T_COL(self) -> int:   # reserved boundary col for t
        return self.B - 1

    def fragment_of(self, v: int) -> int:
        return int(self.part[v])

    def traffic_bits_reach(self) -> int:
        """Upper bound the paper proves: O(|V_f|^2) bits of rvset payload."""
        return self.B * self.B

    def packed_traffic_bits(self, states: int = 1) -> int:
        """Bits the one collective actually ships once the Boolean payload
        is bitpacked into uint32 words (kernels.bitpack_ops): rows x
        ceil(cols/32) words.  ``states`` > 1 gives the product-automaton
        (B*|Q|)^2-shaped regular case."""
        from ..kernels.bitpack_ops.ops import packed_bits
        side = self.B * states
        return packed_bits(side, side)

    def largest_fragment(self) -> int:
        return int(self.frag_sizes.max())


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def fragment_graph(g: Graph, part: np.ndarray, k: int,
                   pad_multiple: int = 8) -> Fragmentation:
    """Build the padded fragmentation (host, numpy)."""
    part = np.asarray(part, dtype=np.int32)
    assert part.shape == (g.n,)
    assert part.min(initial=0) >= 0 and part.max(initial=0) < k

    cross_mask = part[g.src] != part[g.dst]
    bnodes = np.unique(g.dst[cross_mask])          # in-nodes == V_f core
    b_index = np.full(g.n, -1, dtype=np.int64)
    b_index[bnodes] = np.arange(len(bnodes))
    B = len(bnodes) + 2

    # --- per-fragment local structures -------------------------------------
    glists = [np.where(part == i)[0] for i in range(k)]
    g2l = np.full(g.n, -1, dtype=np.int64)
    for gl in glists:
        g2l[gl] = np.arange(len(gl))

    frag_src = [[] for _ in range(k)]
    frag_dst = [[] for _ in range(k)]
    stub_maps: list[dict] = [dict() for _ in range(k)]   # global id -> stub local

    src_part = part[g.src]
    internal = ~cross_mask
    # internal edges
    for i in range(k):
        sel = internal & (src_part == i)
        frag_src[i] = list(g2l[g.src[sel]])
        frag_dst[i] = list(g2l[g.dst[sel]])
    # cross edges -> stubs
    cs, cd = g.src[cross_mask], g.dst[cross_mask]
    for u, w in zip(cs, cd):
        i = int(part[u])
        sm = stub_maps[i]
        if int(w) not in sm:
            sm[int(w)] = len(glists[i]) + len(sm)
        frag_src[i].append(int(g2l[u]))
        frag_dst[i].append(sm[int(w)])

    n_locals = [len(glists[i]) + len(stub_maps[i]) for i in range(k)]
    n_max = _round_up(max(n_locals) if k else 1, pad_multiple)
    e_max = _round_up(max((len(frag_src[i]) for i in range(k)), default=1),
                      pad_multiple)
    e_max = max(e_max, 1)

    in_counts = [int(np.sum(part[bnodes] == i)) for i in range(k)] or [0]
    s_maxr = max(in_counts) + 1            # +1 reserved source slot for s

    esrc = np.full((k, e_max), n_max, dtype=np.int32)
    edst = np.full((k, e_max), n_max, dtype=np.int32)
    gids = np.full((k, n_max + 1), -1, dtype=np.int32)
    labels = np.full((k, n_max + 1), -9, dtype=np.int32)
    src_local = np.full((k, s_maxr), n_max, dtype=np.int32)
    src_row = np.full((k, s_maxr), B, dtype=np.int32)      # B == dropped
    tgt_local = np.full((k, B), n_max, dtype=np.int32)

    for i in range(k):
        ne = len(frag_src[i])
        esrc[i, :ne] = frag_src[i]
        edst[i, :ne] = frag_dst[i]
        nl = len(glists[i])
        gids[i, :nl] = glists[i]
        labels[i, :nl] = g.labels[glists[i]]
        for w, loc in stub_maps[i].items():
            gids[i, loc] = w
            labels[i, loc] = g.labels[w]
        # sources: in-nodes owned by this fragment
        mine = bnodes[part[bnodes] == i]
        src_local[i, : len(mine)] = g2l[mine]
        src_row[i, : len(mine)] = b_index[mine]
        # targets: stubs for boundary nodes of other fragments
        for w, loc in stub_maps[i].items():
            tgt_local[i, b_index[w]] = loc

    owner_local = g2l
    frag_sizes = np.array(
        [len(glists[i]) + len(frag_src[i]) for i in range(k)], dtype=np.int64)

    arrays = dict(esrc=esrc, edst=edst, gids=gids, labels=labels,
                  src_local=src_local, src_row=src_row, tgt_local=tgt_local,
                  n_local=np.array(n_locals, dtype=np.int32))
    return Fragmentation(g=g, part=part, k=k, bnodes=bnodes, b_index=b_index,
                         n_max=n_max, e_max=e_max, s_max=s_maxr,
                         arrays=arrays, frag_sizes=frag_sizes,
                         owner_local=owner_local)


def query_slots(fr: Fragmentation, s: int, t: int) -> Dict[str, np.ndarray]:
    """Per-query dynamic inputs: where s and t live.

    Returns stacked [k]-arrays: ``s_local``/``t_local`` give the local index
    of s / t inside the owning fragment (pad ``n_max`` elsewhere).  These are
    traced values — changing (s, t) does NOT recompile the engine.
    """
    k, n_max = fr.k, fr.n_max
    s_local = np.full(k, n_max, dtype=np.int32)
    t_local = np.full(k, n_max, dtype=np.int32)
    s_local[fr.part[s]] = fr.owner_local[s]
    t_local[fr.part[t]] = fr.owner_local[t]
    return dict(s_local=s_local, t_local=t_local,
                s_gid=np.int32(s), t_gid=np.int32(t))
