"""Incremental rvset-cache maintenance for dynamic graphs (DESIGN.md Sec. 3.5).

The paper's guarantees hold for a *static* fragmentation; the serving engine
amortizes work across queries precisely because real workloads re-query one
graph — and real graphs change between queries.  This module keeps the cached
structures of :mod:`repro.core.cache` valid under edge updates without
recomputing them from scratch:

* **insertions** are monotone, so the cached state is reusable twice over:
  the affected fragment's all-sources fixpoint is *resumed* from the cached
  frontiers (``engine.resume_frontier_*`` converges in O(new-path-length)
  relaxations instead of O(diam)), and the changed rows of the boundary
  matrix ``D0`` are pushed through the cached closure with a rank-style
  semiring update — a closure over the r x r changed-row block instead of
  the full |V_f| x |V_f| matrix (``_rank_update_bool`` / ``_tropical``,
  riding the same ``or_and_matmul`` / ``min_plus_matmul`` dispatchers);
* **cross-edge insertions** grow ``V_f`` into the pre-allocated spare
  boundary slots of :func:`repro.core.fragments.fragment_graph`
  (``reserve_boundary``), so every device array keeps its shape and nothing
  retraces;
* **deletions** are not monotone, so the dirty fragments' frontiers are
  recomputed cold and the closure rebuilt from the (mostly cached) ``D0``;
  a debt counter decides when enough deletions have accumulated that a full
  structural rebuild (which also compacts stale boundary slots and stubs)
  is cheaper than continuing to repair.

Correctness of the rank-style update: let ``R`` be the changed rows and
``T = D0'[R] (x) C`` (one possibly-new hop out of R, then old paths).  Any
path in the updated dependency graph decomposes at its uses of R-row edges
into  ``u --C--> r_1 --T--> r_2 --T--> ... --T--> v``,  so with
``M = T[:, R]`` and ``M*`` its closure,

    C' = C  |  C[:, R] (x) M* (x) T          (boolean; min-plus analogous)

— exact for monotone updates because old entries stay valid lower bounds.
The changed-row count is padded to ``ROW_PAD`` buckets so repeated repairs
reuse compiled programs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import bes, engine
from .cache import _gather_boundary_matrix, prepare_rvset_cache
from .engine import INF
from .fragments import Fragmentation, GraphDelta

ROW_PAD = 64                 # changed-row padding bucket (jit stability)
RECOMPUTE_DIRTY_FRAC = 0.5   # most fragments dirty -> recompute beats repair
DEBT_PER_RECOMPUTE = 0.5     # deletion-recompute cost, in full-rebuild units
REBUILD_DEBT = 4.0           # accumulated debt that triggers a full rebuild


@dataclasses.dataclass
class UpdateStats:
    """What one :func:`apply_delta` call did to the fragmentation + cache."""

    mode: str                # noop | structural | repair | recompute | rebuild
    n_add_intra: int = 0
    n_add_cross: int = 0
    n_del: int = 0
    dirty_fragments: int = 0
    new_boundary: int = 0
    changed_rows: int = 0
    reason: str = ""


def _stats_base(report) -> dict:
    return dict(n_add_intra=report.n_add_intra,
                n_add_cross=report.n_add_cross, n_del=report.n_del,
                dirty_fragments=int(report.dirty.sum()),
                new_boundary=len(report.new_boundary))


# fragment arrays a cross-edge insertion can mutate beyond the edge lists:
# _ensure_boundary touches src_local/src_row, _ensure_stub touches
# gids/labels/tgt_local/n_local (fragments.Fragmentation.apply_delta)
_CROSS_TOUCHED = ("src_local", "src_row", "gids", "labels", "tgt_local",
                  "n_local")


def touched_arrays(report) -> set:
    """``fr.arrays`` keys the applied delta mutated, from its
    :class:`~repro.core.fragments.DeltaReport` — what
    :meth:`RvsetCache.refresh_device_arrays` needs to re-upload.
    Intra-fragment edges and deletions only rewrite the edge lists; cross
    insertions additionally grow stubs/sources (see ``_CROSS_TOUCHED``)."""
    names = {"esrc", "edst"}
    if report.n_add_cross:
        names.update(_CROSS_TOUCHED)
    return names


def rebuild_cache(fr: Fragmentation, old_version: int, report,
                  with_dist: bool, use_pallas="auto",
                  reason: str = "") -> UpdateStats:
    """Drop + rebuild the cache from the current fragmentation state.
    Snapshot ids stay monotone across rebuilds (QueryServer stamps answers
    with ``cache.version``).  Shared by the host and sharded update paths."""
    fr.rvset_cache = None
    fresh = prepare_rvset_cache(fr, with_dist=with_dist,
                                use_pallas=use_pallas)
    fresh.version = old_version + 1
    return UpdateStats(mode="rebuild", reason=reason, **_stats_base(report))


def apply_delta(fr: Fragmentation, delta: GraphDelta,
                use_pallas="auto", chaos=None) -> UpdateStats:
    """Apply ``delta`` to ``fr`` and incrementally repair its rvset cache.

    The attached cache (if any) answers identically to one rebuilt from
    scratch afterwards — pinned property-style by tests/test_incremental.py.
    An empty delta is a strict no-op (cached arrays keep their identity).

    ``chaos`` (a :class:`repro.serve.faults.FaultInjector`) is consulted at
    the ``delta.repair`` site *after* the host arrays have mutated, so an
    injected failure leaves the fragmentation genuinely mid-update — the
    caller (``QuerySession.apply``) is responsible for rolling back via
    :meth:`Fragmentation.snapshot` / ``restore``.
    """
    if delta.is_empty():
        return UpdateStats(mode="noop")
    cache = fr.rvset_cache
    with_dist = cache is not None and cache.bl_dist is not None
    report = fr.apply_delta(delta)
    if chaos is not None:
        chaos.maybe_fail("delta.repair")
    base = _stats_base(report)
    if cache is None:
        return UpdateStats(mode="structural", **base)
    if report.rebuilt:
        return rebuild_cache(fr, cache.version, report, with_dist,
                             use_pallas, reason=report.reason)

    dirty_frac = float(report.dirty.mean())
    if report.n_del:
        cache.repair_debt += DEBT_PER_RECOMPUTE + 0.5 * dirty_frac
        if cache.repair_debt >= REBUILD_DEBT:
            fr.rebuild()
            return rebuild_cache(fr, cache.version, report, with_dist,
                                 use_pallas, reason="repair debt")
        _recompute(cache, report.dirty, warm=False, use_pallas=use_pallas)
        cache.refresh_device_arrays(touched_arrays(report))
        return UpdateStats(mode="recompute", **base)
    if dirty_frac > RECOMPUTE_DIRTY_FRAC:
        # insert-only but wide: the changed-row block is most of the matrix,
        # so a (warm-started) recompute is cheaper than the rank update
        _recompute(cache, report.dirty, warm=True, use_pallas=use_pallas)
        cache.refresh_device_arrays(touched_arrays(report))
        return UpdateStats(mode="recompute", **base)
    changed = _repair_insert(cache, report.dirty, use_pallas=use_pallas)
    cache.refresh_device_arrays(touched_arrays(report))
    return UpdateStats(mode="repair", changed_rows=changed, **base)


# ---------------------------------------------------------------------------
# frontier maintenance (per-fragment, warm- or cold-started)
# ---------------------------------------------------------------------------

def _frontier_init(fr: Fragmentation, f: int, warm_rows, dist: bool):
    """[S, n_max+1] initial state for fragment ``f``'s all-sources fixpoint:
    the cached boundary rows when warm (insert-only deltas — the old
    fixpoint is a valid starting bound), plain seeds when cold."""
    src_local = fr.arrays["src_local"][f]
    src_row = fr.arrays["src_row"][f]
    valid = src_row < fr.B - 2
    rows = np.nonzero(valid)[0]
    shape = (fr.s_max, fr.n_max + 1)
    if dist:
        init = np.full(shape, int(INF), dtype=np.int32)
        if warm_rows is not None:
            init[rows] = warm_rows[src_row[valid]]
        init[rows, src_local[valid]] = 0
    else:
        init = np.zeros(shape, dtype=bool)
        if warm_rows is not None:
            init[rows] = warm_rows[src_row[valid]]
        init[rows, src_local[valid]] = True
    return jnp.asarray(init), rows, src_row[valid]


def _update_frontiers(cache, dirty: np.ndarray, warm: bool):
    """Re-run the all-sources fixpoint of every dirty fragment and scatter
    the refreshed rows back into the cached [nb, n_max+1] matrices."""
    fr = cache.fr
    bl, bl_d = cache.bl_frontier, cache.bl_dist
    bl_host = np.asarray(bl)
    bl_d_host = np.asarray(bl_d) if bl_d is not None else None
    for f in np.nonzero(dirty)[0]:
        esrc = jnp.array(fr.arrays["esrc"][f])
        edst = jnp.array(fr.arrays["edst"][f])
        init, rows, bpos = _frontier_init(
            fr, f, bl_host if warm else None, dist=False)
        front = engine.resume_frontier_reach(esrc, edst, init,
                                             n_max=fr.n_max)
        bl = bl.at[jnp.asarray(bpos)].set(front[jnp.asarray(rows)])
        if bl_d is not None:
            init_d, rows, bpos = _frontier_init(
                fr, f, bl_d_host if warm else None, dist=True)
            front_d = engine.resume_frontier_dist(esrc, edst, init_d,
                                                  n_max=fr.n_max)
            bl_d = bl_d.at[jnp.asarray(bpos)].set(front_d[jnp.asarray(rows)])
    cache.bl_frontier = bl
    if bl_d is not None:
        cache.bl_dist = bl_d


# ---------------------------------------------------------------------------
# closure maintenance: rank-style update (inserts) / rebuild (deletes)
# ---------------------------------------------------------------------------

def changed_row_ids(fr: Fragmentation, dirty: np.ndarray) -> np.ndarray:
    """Active boundary positions whose D0 row may have changed: exactly the
    in-nodes owned by dirty fragments (stubs — and hence row reads — of a
    fragment only change when its own edge list does)."""
    owner = fr.boundary_owner()
    mask = dirty[owner]
    mask[fr.nb_active:] = False            # spare slots own no rows
    return np.nonzero(mask)[0]


def pad_row_ids(row_ids: np.ndarray, pad: int = ROW_PAD,
                cap: int = None) -> np.ndarray:
    """Pad the changed-row set to a bucket size by repeating the first id —
    duplicate rows are semiring no-ops (identical constraints OR/min twice)
    and keep the repair kernels' shapes in a small set of buckets.  ``cap``
    (the matrix side) bounds the bucket so a small boundary never pays for
    more rows than the full matrix has."""
    r = len(row_ids)
    rp = ((r + pad - 1) // pad) * pad
    if cap is not None:
        rp = min(rp, max(cap, r))
    return np.concatenate([row_ids, np.full(rp - r, row_ids[0], np.int64)])


def gather_rows(fr: Fragmentation, bl, row_ids: np.ndarray):
    """D0 rows ``row_ids`` read out of frontier matrix ``bl`` (the gather
    of cache._gather_boundary_matrix, restricted to the changed rows; the
    pad column carries the semiring zero, so spare targets read inert)."""
    nb = fr.n_boundary
    owner = fr.boundary_owner()
    cols = fr.arrays["tgt_local"][owner[row_ids]][:, :nb]
    rows = bl[jnp.asarray(row_ids)]
    return jnp.take_along_axis(rows, jnp.asarray(cols), axis=1)


@jax.jit
def _rank_update_bool(C, rows_new, idx):
    """C' = C | C[:, R] (x) closure(T[:, R]) (x) T with T = rows_new (x) C;
    exact for monotone row updates (see module docstring).  One jitted
    program per changed-row bucket size."""
    from ..kernels.bool_matmul.ops import or_and_matmul
    T = or_and_matmul(rows_new, C)                     # [r, nb]
    Mc = bes.bool_closure(T[:, idx])
    left = or_and_matmul(C[:, idx], Mc)                # [nb, r]
    return C | or_and_matmul(left, T)


@jax.jit
def _rank_update_tropical(Cd, rows_new, idx):
    from ..kernels.tropical_matmul.ops import min_plus_matmul
    T = jnp.minimum(min_plus_matmul(rows_new, Cd), INF)
    Mc = bes.tropical_closure(T[:, idx])
    left = jnp.minimum(min_plus_matmul(Cd[:, idx], Mc), INF)
    via = jnp.minimum(min_plus_matmul(left, T), INF)
    return jnp.minimum(Cd, via)


def _repair_insert(cache, dirty: np.ndarray, use_pallas="auto") -> int:
    """Insert-only repair: warm frontier resume + rank-style closure update.

    The candidate rows (every in-node of a dirty fragment) are diffed
    against the pre-update frontiers and only rows whose D0 entries
    *actually changed* go through the closure update — in a dense fragment
    most insertions change few or no boundary rows, so the common case is
    a cheap frontier resume and a no-op (or tiny) rank update.  Returns the
    number of changed D0 rows pushed through the closure.  (The jitted rank
    updates always use the backend dispatchers — the ``use_pallas`` escape
    hatch only steers the recompute/rebuild paths.)"""
    fr = cache.fr
    bl_old, bl_d_old = cache.bl_frontier, cache.bl_dist
    _update_frontiers(cache, dirty, warm=True)
    candidates = changed_row_ids(fr, dirty)
    if fr.n_boundary == 0 or candidates.size == 0:
        return 0
    # diff candidate D0 rows old vs new (new stub columns read all-false /
    # INF out of the old frontiers, so freshly activated rows always diff)
    rows_new = gather_rows(fr, cache.bl_frontier, candidates)
    rows_old = gather_rows(fr, bl_old, candidates)
    changed = np.any(np.asarray(rows_new != rows_old), axis=1)
    rows_d_new = rows_d_old = None
    if cache.bl_dist is not None:
        rows_d_new = gather_rows(fr, cache.bl_dist, candidates)
        rows_d_old = gather_rows(fr, bl_d_old, candidates)
        changed |= np.any(np.asarray(rows_d_new != rows_d_old), axis=1)
    if not changed.any():
        return 0
    sel = np.nonzero(changed)[0]
    padded_sel = pad_row_ids(sel, cap=fr.n_boundary)
    padded = candidates[padded_sel]
    cache.closure = _rank_update_bool(cache.closure, rows_new[padded_sel],
                                      jnp.asarray(padded))
    if cache.bl_dist is not None:
        cache.dist_closure = _rank_update_tropical(
            cache.dist_closure, rows_d_new[padded_sel], jnp.asarray(padded))
    return int(sel.size)


def _recompute(cache, dirty: np.ndarray, warm: bool, use_pallas="auto"):
    """Per-fragment recompute: refresh dirty fragments' frontiers (cold
    when deletions are present — the old state over-approximates), then
    rebuild D0 by gather and re-close it.  Clean fragments' frontier rows —
    the expensive part — are reused as-is."""
    fr = cache.fr
    _update_frontiers(cache, dirty, warm=warm)
    D0 = _gather_boundary_matrix(fr, cache.bl_frontier, fill=False)
    cache.closure = bes.bool_closure(D0, use_pallas=use_pallas)
    if cache.bl_dist is not None:
        W0 = _gather_boundary_matrix(fr, cache.bl_dist, fill=INF)
        cache.dist_closure = bes.tropical_closure(W0, use_pallas=use_pallas)
