"""MRdRPQ: the paper's MapReduce formulation (Section 6, Fig. 10).

Map    = localEval_r on each fragment (procedure mapRPQ);
Shuffle= every mapper emits <1, rvset_i> to ONE reducer;
Reduce = evalDG_r on the union (procedure reduceRPQ).

We reproduce the *dataflow* (including the single-reducer bottleneck the
paper inherits from Hadoop) so the benchmark can quantify it against the
replicated-closure engine.  The ECC (elapsed communication cost, after
Afrati & Ullman) is max over process paths of shipped input sizes:
ECC = O(|F_m| + |R|^2 |V_f|^2).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import engine
from .automaton import QueryAutomaton
from .fragments import Fragmentation, query_slots


@dataclasses.dataclass
class MRResult:
    answer: bool
    ecc_bits: int           # elapsed communication cost
    mapper_input_bits: int  # max |F_i| shipped to a mapper
    reducer_input_bits: int # sum of rvset payloads into the single reducer


def mr_drpq(fr: Fragmentation, s: int, t: int, qa: QueryAutomaton) -> MRResult:
    if s == t:
        return MRResult(bool(qa.nullable), 0, 0, 0)
    Q = qa.n_states
    arrs = {k: jnp.array(v) for k, v in fr.arrays.items()}
    qs = query_slots(fr, s, t)
    q_labels, q_trans = jnp.asarray(qa.state_labels), jnp.asarray(qa.trans)

    # ---- map phase: one mapper per fragment (procedure mapRPQ) ----------
    mapper = jax.jit(jax.vmap(
        lambda es, ed, sl, sr, tl, lab, gid, sloc, tloc:
        engine.local_eval_regular(es, ed, sl, sr, tl, lab, gid,
                                  q_labels, q_trans, sloc, tloc,
                                  jnp.int32(s), jnp.int32(t),
                                  n_max=fr.n_max, B=fr.B)))
    rvsets = mapper(arrs["esrc"], arrs["edst"], arrs["src_local"],
                    arrs["src_row"], arrs["tgt_local"], arrs["labels"],
                    arrs["gids"],
                    jnp.asarray(qs["s_local"]), jnp.asarray(qs["t_local"]))

    # ---- shuffle + reduce: single reducer (procedure reduceRPQ) ---------
    reducer_dev = jax.devices()[0]
    rvsets = jax.device_put(rvsets, reducer_dev)
    D = jnp.any(rvsets, axis=0)

    src_rows = np.zeros(fr.B * Q, dtype=bool)
    src_rows[fr.S_ROW * Q + qa.start] = True
    tgt_cols = np.zeros(fr.B * Q, dtype=bool)
    tgt_cols[fr.T_COL * Q + qa.final] = True
    bt = int(fr.b_index[t])
    if bt >= 0:
        tgt_cols[bt * Q + qa.final] = True
    ans = engine.evaldg_reach(D, jnp.asarray(src_rows), jnp.asarray(tgt_cols))

    mapper_bits = int(fr.frag_sizes.max()) * 32
    reducer_bits = fr.k * (fr.B * Q) ** 2      # every mapper ships its block
    return MRResult(bool(ans), mapper_bits + reducer_bits,
                    mapper_bits, reducer_bits)
