"""Typed query IR + planner for mixed-kind fused batches (DESIGN.md Sec. 5).

The paper's three query classes (reachability, bounded reachability /
distance, regular path) share one evaluation skeleton — localEval partials
combined through the boundary dependency structure — and therefore one
serving engine.  This module is the *language* half of that engine:

* **IR**: :class:`Reach`, :class:`Dist`, :class:`Rpq` — small frozen
  dataclasses describing one query each.  They carry no fragmentation or
  backend state, so a workload is just a list of values that can be built,
  inspected, logged, or replayed independently of execution.
* **Planner**: :func:`plan_queries` groups a heterogeneous batch by
  *execution signature* — ``(kind,)`` for reach/dist, ``(kind,
  automaton-key)`` for RPQs — into :class:`ExecutionGroup`\\ s.  Every group
  is served by ONE compiled program invocation (`core.cache` batched
  kernels), and group sizes are padded up to power-of-two buckets
  (:func:`bucket_size`) so bursty, ragged batches reuse a small set of
  compiled shapes instead of retracing.

Distances with and without a bound share a group: the cached tropical
kernel computes exact distances and the bound is applied per-query at
answer extraction, so ``Dist(s, t)`` and ``Dist(s, t, bound=l)`` fuse.

Execution lives in :mod:`repro.core.session`; this module stays importable
without touching a device.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..errors import Status
from .automaton import QueryAutomaton
from .engine import QueryStats


# ---------------------------------------------------------------------------
# query IR
# ---------------------------------------------------------------------------

def _check_endpoints(s, t):
    if not (isinstance(s, (int, np.integer)) and isinstance(t, (int, np.integer))):
        raise TypeError(f"query endpoints must be ints, got ({s!r}, {t!r})")
    if s < 0 or t < 0:
        raise ValueError(f"query endpoints must be >= 0, got ({s}, {t})")


@dataclasses.dataclass(frozen=True)
class Reach:
    """q_r(s, t): is there any path from s to t?  (paper Fig. 3)

    Run via ``session.run([Reach(s, t), ...])``; the result's ``answer``
    is a bool.  Frozen and hashable so batches dedup with ``set()``.
    """

    s: int
    t: int
    # uncached (seed-engine) execution only: also return the assembled
    # dependency matrix, like the legacy ``dis_reach(..., return_matrix=True)``
    return_matrix: bool = False
    kind = "reach"

    def __post_init__(self):
        _check_endpoints(self.s, self.t)


@dataclasses.dataclass(frozen=True)
class Dist:
    """q_br(s, t, l) / dist(s, t): bounded reachability when ``bound`` is
    given, exact shortest distance otherwise.  (paper Sec. 4)

    With ``bound=l`` the result's ``answer`` is ``dist(s, t) <= l``; with
    ``bound=None`` the result's ``distance`` is the exact hop count
    (``-1`` if unreachable).  Both forms share one fused tropical
    execution per batch group.
    """

    s: int
    t: int
    bound: Optional[int] = None
    kind = "dist"

    def __post_init__(self):
        _check_endpoints(self.s, self.t)


@dataclasses.dataclass(frozen=True, eq=False)
class Rpq:
    """q_rr(s, t, R): regular path query — exactly one of ``regex`` (label
    names resolved against the session's graph) or ``automaton`` (a
    prebuilt :class:`QueryAutomaton`) must be given.  (paper Sec. 5)

    The result's ``answer`` is True iff some s→t path spells a word the
    automaton accepts.  Queries sharing an automaton (or an equal regex)
    fuse into one product-graph execution per batch group.
    """

    s: int
    t: int
    regex: Optional[str] = None
    automaton: Optional[QueryAutomaton] = None
    return_matrix: bool = False
    kind = "rpq"

    def __post_init__(self):
        _check_endpoints(self.s, self.t)
        if (self.regex is None) == (self.automaton is None):
            raise ValueError(
                "Rpq needs exactly one of regex= or automaton=, got "
                f"regex={self.regex!r}, automaton={self.automaton!r}")

    # hand-rolled value semantics: the generated ones would compare the
    # automaton's numpy arrays elementwise (ambiguous truth value) and
    # inherit its unhashability — dedup via set(queries) must work
    def _key(self) -> tuple:
        return (self.s, self.t, self.regex,
                None if self.automaton is None else self.automaton.cache_key(),
                self.return_matrix)

    def __eq__(self, other):
        return isinstance(other, Rpq) and self._key() == other._key()

    def __hash__(self):
        return hash(self._key())


Query = Union[Reach, Dist, Rpq]


@dataclasses.dataclass
class QueryResult:
    """One answered query (field layout matches the legacy core.api one,
    plus the rvset-cache snapshot id the answer was computed against)."""

    answer: bool
    distance: Optional[int]
    stats: QueryStats
    dependency_matrix: Optional[np.ndarray] = None
    # version of the rvset cache consulted (None: uncached execution)
    cache_version: Optional[int] = None
    # True when the sharded engine failed for this query's group and the
    # answer was served by the vmap fallback instead (still exact; see
    # DESIGN.md Sec. 7)
    degraded: bool = False
    # lifecycle state; the session only ever returns answered results, so
    # this is DONE everywhere a result exists — serving futures reuse the
    # same enum for their richer terminal states (DESIGN.md Sec. 8)
    status: Status = Status.DONE


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

BUCKET_MIN = 8      # smallest fused-batch shape (tiny groups pad up to this)


def bucket_size(n: int) -> int:
    """Pad a group of ``n`` queries to the next power-of-two bucket
    (>= BUCKET_MIN), so ragged batch sizes map onto a logarithmic number of
    compiled programs instead of one per size."""
    if n <= BUCKET_MIN:
        return BUCKET_MIN
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass
class ExecutionGroup:
    """All queries of one batch sharing an execution signature: they are
    answered by ONE invocation of the group's compiled program."""

    kind: str                                  # "reach" | "dist" | "rpq"
    key: Tuple                                 # full signature (hashable)
    indices: List[int] = dataclasses.field(default_factory=list)
    queries: List[Query] = dataclasses.field(default_factory=list)
    automaton: Optional[QueryAutomaton] = None  # resolved, rpq groups only

    @property
    def n(self) -> int:
        return len(self.queries)

    @property
    def padded_size(self) -> int:
        return bucket_size(self.n)

    def pairs(self) -> np.ndarray:
        """[padded_size, 2] int64 (s, t) rows; padding repeats row 0, whose
        answer is computed once more and discarded (semiring no-op)."""
        p = np.array([(q.s, q.t) for q in self.queries], dtype=np.int64)
        pad = self.padded_size - len(p)
        if pad:
            p = np.concatenate([p, np.repeat(p[:1], pad, axis=0)])
        return p


@dataclasses.dataclass
class QueryPlan:
    """Grouping of one submitted batch; ``groups`` preserve first-seen
    order, ``indices`` inside each group preserve submission order."""

    groups: List[ExecutionGroup]
    n_queries: int

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    def explain(self) -> str:
        lines = [f"plan: {self.n_queries} queries -> {self.n_groups} fused "
                 "executions"]
        for g in self.groups:
            sig = g.kind if g.automaton is None else \
                f"{g.kind}[|Q|={g.automaton.n_states}]"
            lines.append(f"  {sig}: {g.n} queries (padded to "
                         f"{g.padded_size})")
        return "\n".join(lines)


def plan_queries(queries: Sequence[Query],
                 resolve_automaton: Callable[[Rpq], QueryAutomaton],
                 ) -> QueryPlan:
    """Group a heterogeneous batch by (kind, automaton) execution signature.

    ``resolve_automaton`` turns an :class:`Rpq` into its
    :class:`QueryAutomaton` (compiling the regex against the session's
    graph labels); two RPQs land in the same group iff their automata have
    equal :meth:`QueryAutomaton.cache_key`, which is also the key the
    product-closure cache uses — one group == one closure == one program.
    """
    groups: dict = {}
    for i, q in enumerate(queries):
        if isinstance(q, Reach):
            key: Tuple = ("reach",)
            qa = None
        elif isinstance(q, Dist):
            key = ("dist",)
            qa = None
        elif isinstance(q, Rpq):
            qa = resolve_automaton(q)
            key = ("rpq", qa.cache_key())
        else:
            raise TypeError(
                f"queries[{i}] is {type(q).__name__}; expected Reach, Dist "
                "or Rpq (see repro.core.plan)")
        group = groups.get(key)
        if group is None:
            group = groups[key] = ExecutionGroup(kind=key[0], key=key,
                                                 automaton=qa)
        group.indices.append(i)
        group.queries.append(q)
    return QueryPlan(groups=list(groups.values()), n_queries=len(queries))
