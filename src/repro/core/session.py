"""QuerySession: one handle over a (dynamic) fragmentation for all three
query classes (DESIGN.md Sec. 5).

``repro.connect(fr)`` opens a session that owns the amortized caches
(rvset / tropical / per-automaton product closures, physically attached to
the Fragmentation so every view of it shares one copy), the backend choice
(single-host ``vmap`` vs ``shard_map``, which packs the ``k`` fragments
onto a mesh of ``d <= k`` devices per a
:class:`~repro.core.fragments.Placement`), snapshot version stamping, and
delta application.  ``session.run([...])`` takes a
heterogeneous batch of :mod:`repro.core.plan` IR values, groups it by
(kind, automaton) through the planner, and serves every group with ONE
compiled batched execution — reach and dist through the PR-2 kernels, RPQs
through the batched product-closure path — returning
:class:`~repro.core.plan.QueryResult`\\ s in submission order.

The seed free functions (``dis_reach``, ``dis_dist``, ``dis_rpq``) are
thin shims over per-fragmentation default sessions (see ``core.api``);
everything inside ``src/repro`` talks to the session directly.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import cache as _cache
from . import engine, incremental
from ..errors import DeltaApplyFailed, Status
from .automaton import QueryAutomaton, build_query_automaton
from .engine import INF, QueryStats
from .fragments import Fragmentation, GraphDelta, Placement, query_slots
from .plan import (Dist, ExecutionGroup, Query, QueryPlan, QueryResult,
                   Reach, Rpq, plan_queries)

BACKENDS = ("auto", "vmap", "shard_map")
CACHE_MODES = ("amortized", "none")


@dataclasses.dataclass
class SessionStats:
    """Work accounting across the session's lifetime."""

    queries: int = 0         # queries answered
    batches: int = 0         # run() calls
    executions: int = 0      # compiled-program invocations issued
    updates: int = 0         # deltas applied
    # robustness accounting (DESIGN.md Sec. 7)
    degraded_groups: int = 0  # sharded groups served by the vmap fallback
    rollbacks: int = 0        # failed deltas rolled back to their snapshot


def connect(fr: Fragmentation, backend: str = "auto",
            cache: str = "amortized", mesh=None,
            placement: Optional[Placement] = None,
            chaos=None) -> "QuerySession":
    """Open a :class:`QuerySession` over ``fr`` — the front door of the
    library (also exported as ``repro.connect``).

    ``backend``:

    * ``"vmap"`` runs every fragment's localEval as one SPMD program on
      the host device;
    * ``"shard_map"`` distributes the fragments over the devices of
      ``mesh`` (built lazily when omitted) according to ``placement``
      and keeps the one-collective guarantee per fused batch for all
      three query classes.  Meshes *smaller* than ``fr.k`` are valid —
      each device packs several fragments (``k >> d`` scale-out); meshes
      larger than ``fr.k`` are refused (a fragment is never split);
    * ``"auto"`` picks shard_map whenever more than one device is
      available and ``d <= fr.k`` (judged against ``mesh`` when one is
      passed), and vmap otherwise.

    ``placement`` maps fragment -> device (see
    :class:`~repro.core.fragments.Placement`); when omitted the session
    uses greedy workload balancing (``Placement.balanced``) over the mesh
    size.  ``cache``: ``"amortized"`` serves batches from the
    rvset/product caches (built lazily, shared with every other session
    on the same fragmentation); ``"none"`` evaluates each query with the
    seed one-shot engine and never builds cache state.

    ``chaos``: an optional :class:`repro.serve.faults.FaultInjector`
    consulted at every engine / upload / delta-repair site — the handle
    tests and benchmarks use to exercise the failure paths of
    DESIGN.md Sec. 7.  ``None`` (the default) adds zero overhead.
    """
    return QuerySession(fr, backend=backend, cache=cache, mesh=mesh,
                        placement=placement, chaos=chaos)


class QuerySession:
    """Unified query interface over one fragmentation (see :func:`connect`)."""

    def __init__(self, fr: Fragmentation, backend: str = "auto",
                 cache: str = "amortized", mesh=None,
                 placement: Optional[Placement] = None, chaos=None):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected one of "
                             f"{BACKENDS}")
        if cache not in CACHE_MODES:
            raise ValueError(f"unknown cache mode {cache!r}; expected one "
                             f"of {CACHE_MODES}")
        self.fr = fr
        self.cache_mode = cache
        self._mesh = mesh
        if placement is not None and placement.k != fr.k:
            raise ValueError(f"placement maps {placement.k} fragments but "
                             f"the fragmentation has {fr.k}")
        if placement is not None and mesh is not None \
                and mesh.devices.size != placement.d:
            raise ValueError(f"mesh has {mesh.devices.size} devices but "
                             f"the placement expects {placement.d}")
        # d: the device budget the sharded backend would run on.  An
        # explicit placement or mesh pins it; otherwise every process
        # device up to fr.k is used (fragments pack when devices < k).
        # shard_map fits iff d <= fr.k — a fragment is never split across
        # devices, so a mesh LARGER than fr.k is refused.
        if placement is not None:
            d = placement.d
            have = f"a {d}-device placement"
        elif mesh is not None:
            d = int(mesh.devices.size)
            have = f"a {d}-device mesh"
        else:
            d = min(len(jax.devices()), fr.k)
            have = f"{len(jax.devices())} devices"
        fits = 1 <= d <= fr.k
        if backend == "auto":
            backend = "shard_map" if fr.k > 1 and d > 1 and fits else "vmap"
        elif backend == "shard_map" and not fits:
            raise ValueError(
                f"backend='shard_map' packs fragments onto at most one "
                f"device each ({fr.k} fragments), cannot use {have}; pass "
                f"a mesh/placement with <= {fr.k} devices, or "
                "backend='auto' to fall back to vmap")
        self.backend = backend
        if backend == "shard_map" and placement is None:
            placement = Placement.balanced(fr, d)
        self.placement = placement
        self.chaos = chaos
        self.stats = SessionStats()
        self.last_plan: Optional[QueryPlan] = None
        self._regex_cache: Dict[str, QueryAutomaton] = {}
        # serializes group execution and delta application so several
        # server threads can share one session over the same caches; an
        # RLock because run() resolves automatons (also locked) inline
        self._lock = threading.RLock()

    # -- cache lifecycle ---------------------------------------------------

    def warm(self, with_dist: bool = False) -> "QuerySession":
        """Eagerly build the amortized caches (no-op for cache='none')."""
        with self._lock:
            if self.cache_mode == "amortized":
                _cache.prepare_rvset_cache(self.fr, with_dist=with_dist)
        return self

    @property
    def cache_version(self) -> Optional[int]:
        """Snapshot id of the attached rvset cache (None before first
        build or for uncached sessions); bumped by every delta repair."""
        c = self.fr.rvset_cache
        return None if c is None else c.version

    # -- dynamic graphs ----------------------------------------------------

    def apply(self, delta: GraphDelta) -> incremental.UpdateStats:
        """Apply a :class:`GraphDelta` and repair the session's caches in
        place (DESIGN.md Sec. 3.5).  On the shard_map backend the repair
        collective ships only the changed bitpacked rows; otherwise (and
        for the cases the sharded path does not cover) the host repair
        runs.  Queries run after this see the new snapshot
        (``cache_version`` is bumped).

        The host cache is repaired even though sharded *answers* recompute
        on-device: it stays the ``cache_version`` snapshot source and is
        shared with vmap sessions/shims on this fragmentation, which would
        otherwise read stale state (DESIGN.md Sec. 5, known trade-off).

        A delta that fails mid-apply (bad input, engine failure, injected
        chaos) is **rolled back**: the fragmentation and its caches return
        to the pre-delta snapshot (``arrays_version`` / ``cache_version``
        unchanged, subsequent queries answer against the pre-delta graph)
        and a typed :class:`~repro.errors.DeltaApplyFailed` wrapping the
        cause is raised (DESIGN.md Sec. 7)."""
        with self._lock:
            self.stats.updates += 1
            snap = self.fr.snapshot()
            try:
                if (self.backend == "shard_map"
                        and self.fr.rvset_cache is not None):
                    from . import distributed
                    return distributed.apply_delta_sharded(
                        self.fr, delta, mesh=self._mesh,
                        placement=self.placement, chaos=self.chaos)
                return incremental.apply_delta(self.fr, delta,
                                               chaos=self.chaos)
            except Exception as exc:
                self.fr.restore(snap)
                self.stats.rollbacks += 1
                raise DeltaApplyFailed(exc) from exc

    def repair_on(self, fr: Fragmentation,
                  delta: GraphDelta) -> incremental.UpdateStats:
        """Repair ``fr``'s caches for ``delta`` — the MVCC building block
        (:mod:`repro.core.versions`).  Unlike :meth:`apply` this neither
        takes the session lock nor snapshots: ``fr`` is a private
        copy-on-write clone that no reader can see, so the repair runs
        concurrently with queries against the head version, and a failed
        repair is handled by *dropping* the clone (the head was never
        touched) rather than restoring a snapshot."""
        self.stats.updates += 1
        if self.backend == "shard_map" and fr.rvset_cache is not None:
            from . import distributed
            return distributed.apply_delta_sharded(
                fr, delta, mesh=self._mesh, placement=self.placement,
                chaos=self.chaos)
        return incremental.apply_delta(fr, delta, chaos=self.chaos)

    # -- query execution ---------------------------------------------------

    def run(self, queries: Union[Query, Sequence[Query]],
            version=None) -> List[QueryResult]:
        """Answer a heterogeneous batch; results in submission order.

        The batch is grouped by (kind, automaton) and each group is served
        by one compiled batched execution (``cache='amortized'``) or by
        per-query seed evaluations (``cache='none'``).  Every result is
        stamped with the cache snapshot it was computed against.

        ``version``: an optional pinned MVCC :class:`~repro.core.versions.
        Version` — the batch then runs against that snapshot's
        fragmentation and cache instead of ``self.fr``, and results are
        stamped with *its* ``cache_version``.  This is how the async
        engine serves reads while the next version repairs concurrently.

        Thread-safe: the whole batch runs under the session lock, so a
        concurrent :meth:`apply` can never move the snapshot between a
        group's execution and its ``cache_version`` stamp.  (MVCC repairs
        hold the lock only for the copy-on-write clone, never for the
        repair itself — see :meth:`repair_on` — so versioned batches wait
        at most one memcpy, never a repair.)
        """
        if isinstance(queries, (Reach, Dist, Rpq)):
            queries = [queries]
        queries = list(queries)
        fr = self.fr if version is None else version.fr
        with self._lock:
            plan = plan_queries(queries, self._resolve_automaton)
            self.last_plan = plan
            results: List[Optional[QueryResult]] = [None] * len(queries)
            for group in plan.groups:
                if self.cache_mode == "amortized":
                    self._run_group_cached(fr, group, results)
                else:
                    self._run_group_uncached(fr, group, results)
            # uncached execution never consults the cache: stamp None even
            # if a cache happens to exist on the shared fragmentation
            if self.cache_mode != "amortized":
                stamp = None
            else:
                c = fr.rvset_cache
                stamp = None if c is None else c.version
        for r in results:
            r.cache_version = stamp
            r.status = Status.DONE
        self.stats.queries += len(queries)
        self.stats.batches += 1
        return results  # type: ignore[return-value]

    # convenience single-query sugar (examples / interactive use)
    def reach(self, s: int, t: int) -> bool:
        return self.run(Reach(int(s), int(t)))[0].answer

    def dist(self, s: int, t: int,
             bound: Optional[int] = None) -> QueryResult:
        return self.run(Dist(int(s), int(t), bound=bound))[0]

    def rpq(self, s: int, t: int, regex: Optional[str] = None,
            automaton: Optional[QueryAutomaton] = None) -> bool:
        return self.run(Rpq(int(s), int(t), regex=regex,
                            automaton=automaton))[0].answer

    # -- internals ---------------------------------------------------------

    def _resolve_automaton(self, q: Rpq) -> QueryAutomaton:
        if q.automaton is not None:
            return q.automaton
        with self._lock:
            qa = self._regex_cache.get(q.regex)
            if qa is None:
                g = self.fr.g
                label_of = (g.label_of if g.label_names is not None
                            else (lambda name: int(name)))
                qa = build_query_automaton(q.regex, label_of)
                self._regex_cache[q.regex] = qa
            return qa

    def _run_group_cached(self, fr: Fragmentation, group: ExecutionGroup,
                          results) -> None:
        """One compiled batched execution for the whole group (padded to
        the group's bucket size; pad answers are discarded).  On the
        shard_map backend every kind routes through its one-collective
        sharded batch engine, so the paper's guarantees survive fusion for
        all three query classes (DESIGN.md Sec. 3.3)."""
        pairs = group.pairs()
        stats = self._group_stats(fr, group)
        ans, degraded = self._execute_group(fr, group.kind, pairs,
                                            group.automaton)
        if group.kind == "reach":
            for i, q, a, st in zip(group.indices, group.queries, ans, stats):
                results[i] = self._reach_result(q, a, st)
        elif group.kind == "dist":
            # exact distances once; each query's bound applies at answer
            # extraction (this is what lets bounded + exact queries fuse)
            for i, q, di, st in zip(group.indices, group.queries, ans, stats):
                results[i] = self._dist_result(q, int(di), st)
        else:                                   # rpq
            for i, q, a, st in zip(group.indices, group.queries, ans, stats):
                results[i] = self._rpq_result(q, group.automaton, a, st)
        if degraded:
            for i in group.indices:
                results[i].degraded = True
        self.stats.executions += 1

    def _execute_group(self, fr: Fragmentation, kind: str, pairs, qa):
        """One batched engine execution; returns ``(answers, degraded)``.

        On the shard_map backend an engine/upload failure **degrades**
        instead of failing the group: the same batch re-runs on the host
        vmap path, which answers from the host rvset cache — kept repaired
        on every delta exactly so it can serve as the fallback source.
        Answers stay exact; callers flag them ``degraded=True``
        (DESIGN.md Sec. 7)."""
        if self.backend == "shard_map":
            from . import distributed
            try:
                if kind == "reach":
                    return distributed.dis_reach_batch_sharded(
                        fr, pairs, mesh=self._mesh,
                        placement=self.placement, chaos=self.chaos), False
                if kind == "dist":
                    return distributed.dis_dist_batch_sharded(
                        fr, pairs, mesh=self._mesh,
                        placement=self.placement, chaos=self.chaos), False
                return distributed.dis_rpq_batch_sharded(
                    fr, pairs, qa, mesh=self._mesh,
                    placement=self.placement, chaos=self.chaos), False
            except Exception:
                self.stats.degraded_groups += 1
                return self._execute_group_vmap(fr, kind, pairs, qa), True
        return self._execute_group_vmap(fr, kind, pairs, qa), False

    def _execute_group_vmap(self, fr: Fragmentation, kind: str, pairs, qa):
        if self.chaos is not None:
            self.chaos.maybe_fail("engine.vmap", pairs=pairs)
        if kind == "reach":
            return _cache.dis_reach_batch(fr, pairs)
        if kind == "dist":
            return _cache.dis_dist_batch(fr, pairs)
        return _cache.dis_rpq_batch(fr, pairs, qa)

    def _run_group_uncached(self, fr: Fragmentation, group: ExecutionGroup,
                            results) -> None:
        """Seed one-shot engine, one evaluation per query (cache='none')."""
        for i, q in zip(group.indices, group.queries):
            if group.kind == "reach":
                results[i] = exec_reach(fr, q.s, q.t,
                                        return_matrix=q.return_matrix)
            elif group.kind == "dist":
                results[i] = exec_dist(fr, q.s, q.t, bound=q.bound)
            else:
                results[i] = exec_rpq(fr, q.s, q.t, group.automaton,
                                      return_matrix=q.return_matrix)
            self.stats.executions += 1

    def _group_stats(self, fr: Fragmentation,
                     group: ExecutionGroup) -> List[QueryStats]:
        """Per-query stats whose SUM over the group is exact: a fused group
        ships ONE collective of ``traffic_bits(kind, states, batch=padded)``
        bits total (the padded batch is what actually rides the wire), so
        the bits are amortized across the group's queries with an integer
        fair split and the single collective round is stamped on the first
        query — summing :class:`QueryStats` over any group then reports
        the group's real wire cost instead of overstating it N-fold."""
        states = 1 if group.automaton is None else group.automaton.n_states
        total = fr.traffic_bits(group.kind, states=states,
                                batch=group.padded_size)
        n = group.n
        return [QueryStats(total * (i + 1) // n - total * i // n,
                           1 if i == 0 else 0, fr.B, states)
                for i in range(n)]

    def _reach_result(self, q: Reach, ans, stats: QueryStats) -> QueryResult:
        if q.s == q.t:
            return QueryResult(True, 0, stats)
        return QueryResult(bool(ans), None, stats)

    def _dist_result(self, q: Dist, d: int, stats: QueryStats) -> QueryResult:
        if q.s == q.t:
            ok = q.bound is None or 0 <= q.bound
            return QueryResult(ok, 0, stats)
        dist: Optional[int] = None if d < 0 else d
        reachable = dist is not None
        answer = (reachable if q.bound is None
                  else (reachable and dist <= q.bound))
        # match the seed path: a failed bounded query reports no distance
        if q.bound is not None and not answer:
            dist = None
        return QueryResult(answer, dist, stats)

    def _rpq_result(self, q: Rpq, qa: QueryAutomaton, ans,
                    stats: QueryStats) -> QueryResult:
        if q.s == q.t:
            return QueryResult(bool(qa.nullable), 0, stats)
        return QueryResult(bool(ans), None, stats)


# ---------------------------------------------------------------------------
# per-fragmentation default sessions (what the core.api shims delegate to)
# ---------------------------------------------------------------------------

def default_session(fr: Fragmentation,
                    cache: str = "amortized") -> QuerySession:
    """Memoized vmap-backend session attached to ``fr`` (one per cache
    mode).  Cache state lives on the fragmentation itself, so default
    sessions and explicitly connected ones always share it."""
    key = "_default_session_" + cache
    sess = fr.__dict__.get(key)
    if sess is None:
        sess = QuerySession(fr, backend="vmap", cache=cache)
        fr.__dict__[key] = sess
    return sess


# ---------------------------------------------------------------------------
# seed one-shot engine (paper Figs. 3-7): full localEval + evalDG per query
# ---------------------------------------------------------------------------
#
# Answer extraction (coordinator side):
#   * source row  = reserved row B-2 (s), in automaton state u_s for RPQs;
#   * target cols = reserved col B-1 (t arrivals internal to t's fragment)
#     plus the alias col b_index[t] when t itself is a boundary in-node
#     (arrivals via a cross edge landing exactly on t).

def _as_jnp(fr: Fragmentation):
    # jnp.array (copy=True), not asarray: the host buffers are mutated in
    # place by apply_delta, and on CPU asarray may alias them (PR 7).
    return {k: jnp.array(v) for k, v in fr.arrays.items()}


def _tgt_cols(fr: Fragmentation, t: int) -> jnp.ndarray:
    B = fr.B
    cols = np.zeros(B, dtype=bool)
    cols[fr.T_COL] = True
    bt = fr.b_index[t]
    if bt >= 0:
        cols[bt] = True
    return jnp.asarray(cols)


def _src_rows(fr: Fragmentation) -> jnp.ndarray:
    rows = np.zeros(fr.B, dtype=bool)
    rows[fr.S_ROW] = True
    return jnp.asarray(rows)


def exec_reach(fr: Fragmentation, s: int, t: int,
               return_matrix: bool = False) -> QueryResult:
    """disReach (paper Fig. 3): vmapped localEval + one assemble + evalDG."""
    if s == t:
        return QueryResult(True, 0, QueryStats(0, 0, fr.B, 1))
    arrs = _as_jnp(fr)
    qs = query_slots(fr, s, t)
    local = jax.vmap(
        lambda es, ed, sl, sr, tl, sloc, tloc: engine.local_eval_reach(
            es, ed, sl, sr, tl, sloc, tloc, n_max=fr.n_max, B=fr.B))
    rlocs = local(arrs["esrc"], arrs["edst"], arrs["src_local"],
                  arrs["src_row"], arrs["tgt_local"],
                  jnp.asarray(qs["s_local"]), jnp.asarray(qs["t_local"]))
    D = jnp.any(rlocs, axis=0)                 # assemble (the one collective)
    ans = engine.evaldg_reach(D, _src_rows(fr), _tgt_cols(fr, t))
    stats = QueryStats(payload_bits=fr.traffic_bits("reach"),
                       collective_rounds=1, boundary=fr.B, states=1)
    return QueryResult(bool(ans), None, stats,
                       np.asarray(D) if return_matrix else None)


def exec_dist(fr: Fragmentation, s: int, t: int,
              bound: Optional[int] = None) -> QueryResult:
    """disDist (paper Sec. 4): bounded reachability q_br(s, t, l); with
    bound=None returns exact dist(s, t) (INF -> unreachable -> None)."""
    if s == t:
        ok = bound is None or 0 <= bound
        return QueryResult(ok, 0, QueryStats(0, 0, fr.B, 1))
    cap = jnp.int32(bound) if bound is not None else INF
    arrs = _as_jnp(fr)
    qs = query_slots(fr, s, t)
    local = jax.vmap(
        lambda es, ed, sl, sr, tl, sloc, tloc: engine.local_eval_dist(
            es, ed, sl, sr, tl, sloc, tloc, cap, n_max=fr.n_max, B=fr.B))
    wlocs = local(arrs["esrc"], arrs["edst"], arrs["src_local"],
                  arrs["src_row"], arrs["tgt_local"],
                  jnp.asarray(qs["s_local"]), jnp.asarray(qs["t_local"]))
    W = jnp.min(wlocs, axis=0)
    d = engine.evaldg_dist(W, _src_rows(fr), _tgt_cols(fr, t))
    d = int(d)
    reachable = d < int(INF)
    answer = reachable if bound is None else (reachable and d <= bound)
    stats = QueryStats(payload_bits=fr.traffic_bits("dist"),
                       collective_rounds=1, boundary=fr.B, states=1)
    # a failed bounded query reports no distance: with the propagation
    # capped at the bound, d is not the true distance past it (local
    # segments longer than the cap were pruned), so don't surface it
    return QueryResult(answer, d if (reachable and answer) else None, stats)


def exec_rpq(fr: Fragmentation, s: int, t: int, qa: QueryAutomaton,
             return_matrix: bool = False) -> QueryResult:
    """disRPQ (paper Sec. 5): product-automaton localEval_r + evalDG_r."""
    if s == t:
        return QueryResult(bool(qa.nullable), 0,
                           QueryStats(0, 0, fr.B, qa.n_states))
    Q = qa.n_states
    arrs = _as_jnp(fr)
    qs = query_slots(fr, s, t)
    q_labels = jnp.asarray(qa.state_labels)
    q_trans = jnp.asarray(qa.trans)
    local = jax.vmap(
        lambda es, ed, sl, sr, tl, lab, gid, sloc, tloc:
        engine.local_eval_regular(es, ed, sl, sr, tl, lab, gid,
                                  q_labels, q_trans, sloc, tloc,
                                  jnp.int32(s), jnp.int32(t),
                                  n_max=fr.n_max, B=fr.B))
    rlocs = local(arrs["esrc"], arrs["edst"], arrs["src_local"],
                  arrs["src_row"], arrs["tgt_local"], arrs["labels"],
                  arrs["gids"],
                  jnp.asarray(qs["s_local"]), jnp.asarray(qs["t_local"]))
    D = jnp.any(rlocs, axis=0)                  # [(B*Q), (B*Q)]

    src_rows = np.zeros(fr.B * Q, dtype=bool)
    src_rows[fr.S_ROW * Q + qa.start] = True
    tgt_cols = np.zeros(fr.B * Q, dtype=bool)
    tgt_cols[fr.T_COL * Q + qa.final] = True
    bt = fr.b_index[t]
    if bt >= 0:
        tgt_cols[bt * Q + qa.final] = True
    ans = engine.evaldg_reach(D, jnp.asarray(src_rows), jnp.asarray(tgt_cols))
    stats = QueryStats(payload_bits=fr.traffic_bits("rpq", states=Q),
                       collective_rounds=1, boundary=fr.B, states=Q)
    return QueryResult(bool(ans), None, stats,
                       np.asarray(D) if return_matrix else None)
