"""MVCC snapshot store: immutable ``(Fragmentation, RvsetCache)`` versions
with copy-on-write deltas and concurrent repair (DESIGN.md Sec. 9).

The serving engine's write problem: ``session.apply`` mutates the head
fragmentation *in place*, so every delta is a structural barrier — no query
may overlap the repair.  This module removes the barrier by making deltas
produce **new versions** instead of mutating the current one:

* a :class:`Version` is an immutable published snapshot — nothing mutates
  its ``fr``/cache after publication, so any number of readers can run
  against it lock-free once pinned;
* :func:`cow_clone` builds the next version from the head by copying ONLY
  what ``apply_delta`` can touch (the padded-headroom design keeps every
  array shape static, so the copy is a handful of small host arrays —
  edge lists always, the stub/boundary family only for cross-edge deltas)
  while sharing everything else by reference, including the cache's
  device buffers (``refresh_device_arrays(touched=...)`` re-uploads only
  the mutated arrays and binds a *new* dict, so the shared buffers of
  older versions are never observed to change);
* :meth:`VersionedCacheStore.commit_delta` runs the repair on the private
  clone — holding the session lock only for the clone memcpy, never for
  the repair — and publishes the result as the new head.  Readers that
  pinned an older version keep it alive until they release it; a failed
  repair is simply **dropped** (the head was never touched), which retires
  PR-7's snapshot/restore rollback on this path.

Consistency model: readers always pin the *head* (latest fully-repaired
version) — monotonic reads; a delta becomes visible exactly when its
repair publishes.  ``UpdateFuture.result()`` is the commit point.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from ..errors import DeltaApplyFailed
from . import incremental
from .cache import RvsetCache
from .fragments import Fragmentation, GraphDelta

# fr.arrays keys apply_delta may mutate, by delta shape.  Deletions and
# intra-fragment insertions only rewrite edge slots; cross-fragment
# insertions can additionally activate boundary slots and virtual stubs.
_COW_ALWAYS = ("esrc", "edst")
_COW_CROSS = ("src_local", "src_row", "gids", "labels", "tgt_local",
              "n_local")


def touched_array_names(fr: Fragmentation, delta: GraphDelta) -> set:
    """Prospective upper bound on the ``fr.arrays`` keys applying ``delta``
    to ``fr`` can mutate — what :func:`cow_clone` must copy (the exact
    post-hoc set is :func:`incremental.touched_arrays`, but the clone has
    to copy *before* the delta runs)."""
    names = set(_COW_ALWAYS)
    if delta.n_add and bool(np.any(fr.part[delta.add_src]
                                   != fr.part[delta.add_dst])):
        names.update(_COW_CROSS)
    return names


def _clone_cache(clone_fr: Fragmentation,
                 base: Optional[RvsetCache]) -> Optional[RvsetCache]:
    """Cache for the clone, sharing the base's immutable device state.

    Repairs rebind ``bl_frontier``/``closure``/... functionally and
    ``refresh_device_arrays`` binds a new ``arrays`` dict, so sharing by
    reference is safe; the two dicts are copied because repairs mutate
    them in place (``arrays[k] = ...`` via the new-dict rebind is safe,
    but ``rpq_closures`` is cleared/LRU'd in place by ``product_closure``
    and the refresh)."""
    if base is None:
        return None
    return RvsetCache(
        fr=clone_fr, arrays=dict(base.arrays),
        bl_frontier=base.bl_frontier, closure=base.closure,
        part_b=base.part_b, bl_dist=base.bl_dist,
        dist_closure=base.dist_closure,
        rpq_closures=dict(base.rpq_closures),
        version=base.version, repair_debt=base.repair_debt)


def cow_clone(fr: Fragmentation, delta: GraphDelta) -> Fragmentation:
    """Copy-on-write clone of ``fr`` that ``delta`` can be applied to
    without the base ever observing a change.

    Copied: the delta-touched ``arrays`` (see :func:`touched_array_names`)
    and every host bookkeeping array ``apply_delta`` mutates in place
    (``b_index``, ``frag_sizes``, ``n_edges``, ``src_fill``, ``stubs``,
    ``_slot_of``).  Shared by reference: the graph, partition, untouched
    arrays, and fields that are only ever *rebound* (``bnodes`` grows via
    ``np.append`` — a fresh array — and ``g`` is replaced wholesale).

    ``dataclasses.replace`` (not ``copy.copy``) so the clone's ``__dict__``
    carries dataclass fields only — memoized default sessions and sharded
    device uploads stay with the base and rebuild lazily against the
    clone."""
    touched = touched_array_names(fr, delta)
    arrays = {k: (v.copy() if k in touched else v)
              for k, v in fr.arrays.items()}
    clone = dataclasses.replace(
        fr, arrays=arrays,
        b_index=fr.b_index.copy(),
        frag_sizes=fr.frag_sizes.copy(),
        rvset_cache=None,
        _slot_of=None if fr._slot_of is None else fr._slot_of.copy(),
        n_edges=None if fr.n_edges is None else fr.n_edges.copy(),
        src_fill=None if fr.src_fill is None else fr.src_fill.copy(),
        stubs=None if fr.stubs is None else [dict(s) for s in fr.stubs])
    clone.rvset_cache = _clone_cache(clone, fr.rvset_cache)
    return clone


@dataclasses.dataclass
class Version:
    """One published immutable snapshot.  ``pins`` counts in-flight readers
    (query chunks running against this version); the store never reclaims
    a pinned version."""

    vid: int
    fr: Fragmentation
    pins: int = 0
    retired: bool = False     # dropped/superseded; reclaimed when unpinned

    @property
    def cache_version(self) -> Optional[int]:
        """Snapshot id results computed against this version carry."""
        c = self.fr.rvset_cache
        return None if c is None else c.version


class VersionedCacheStore:
    """Keeps the last few versions live over one :class:`QuerySession`.

    * :meth:`acquire_head` / :meth:`release` pin a reader to the head
      snapshot for the duration of one batch;
    * :meth:`commit_delta` clones the head copy-on-write, repairs the
      clone concurrently with readers (session lock held only during the
      clone), and publishes it as the new head — or drops it on failure;
    * :meth:`drop` retires a version explicitly (operator rollback);
    * capacity eviction reclaims the oldest **unpinned, non-head**
      versions beyond ``capacity`` — pinned versions persist until their
      readers drain, so the store can transiently exceed capacity.

    Commits are serialized by ``_repair_lock`` (deltas are ordered);
    bookkeeping is protected by ``_lock``.  Lock order is always
    ``_repair_lock -> session._lock (briefly) -> _lock``, and readers take
    only ``session._lock``, so the store adds no deadlock edge.
    """

    def __init__(self, session, capacity: int = 4):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.session = session
        self.capacity = capacity
        self._lock = threading.Lock()
        self._repair_lock = threading.Lock()
        self._versions: "OrderedDict[int, Version]" = OrderedDict()
        self._versions[0] = Version(0, session.fr)
        self._head_vid = 0
        self._next_vid = 1
        self.committed = 0       # deltas published as new versions
        self.dropped = 0         # versions dropped (failed repair + drop())
        self.evicted = 0         # unpinned versions reclaimed by capacity

    # -- readers ------------------------------------------------------------

    def head(self) -> Version:
        with self._lock:
            return self._versions[self._head_vid]

    def acquire_head(self) -> Version:
        """Pin the head snapshot for one reader; pair with :meth:`release`."""
        with self._lock:
            ver = self._versions[self._head_vid]
            ver.pins += 1
            return ver

    def release(self, ver: Version) -> None:
        with self._lock:
            ver.pins -= 1
            assert ver.pins >= 0, f"over-released version {ver.vid}"
            self._reclaim()

    def live(self):
        """The currently live (non-retired) versions, oldest first."""
        with self._lock:
            return [v for v in self._versions.values() if not v.retired]

    # -- writers ------------------------------------------------------------

    def commit_delta(self, delta: GraphDelta
                     ) -> Tuple[Version, incremental.UpdateStats]:
        """Apply ``delta`` as a new version and publish it as head.

        The head is pinned while its clone is cut and repaired; the
        session lock is held only for the clone (a few small-array
        memcpys), so concurrent readers wait at most that long and
        **never** for the repair itself.  A failed repair raises
        :class:`~repro.errors.DeltaApplyFailed` and leaves the head
        untouched — the clone is simply dropped, no restore needed."""
        with self._repair_lock:
            base = self.acquire_head()
            try:
                if delta.is_empty():
                    return base, incremental.UpdateStats(mode="noop")
                with self.session._lock:
                    work_fr = cow_clone(base.fr, delta)
                try:
                    stats = self.session.repair_on(work_fr, delta)
                except Exception as exc:
                    with self._lock:
                        self.dropped += 1
                    self.session.stats.rollbacks += 1
                    raise DeltaApplyFailed(exc) from exc
                with self._lock:
                    ver = Version(self._next_vid, work_fr)
                    self._next_vid += 1
                    self._versions[ver.vid] = ver
                    self._head_vid = ver.vid
                    self.committed += 1
                    self._reclaim()
                return ver, stats
            finally:
                self.release(base)

    def drop(self, vid: int) -> None:
        """Retire version ``vid`` (rollback-as-drop).  Pinned readers keep
        their snapshot until they release it; if the head is dropped, the
        newest remaining live version becomes head.  The last live version
        cannot be dropped — something must serve reads."""
        with self._lock:
            ver = self._versions.get(vid)
            if ver is None or ver.retired:
                raise KeyError(f"no live version {vid}")
            live = [v for v in self._versions.values() if not v.retired]
            if len(live) == 1:
                raise ValueError(
                    f"cannot drop version {vid}: it is the last live "
                    "version (reads must have a head to pin)")
            ver.retired = True
            self.dropped += 1
            if vid == self._head_vid:
                for v in reversed(self._versions.values()):
                    if not v.retired:
                        self._head_vid = v.vid
                        break
            self._reclaim()

    def _reclaim(self) -> None:
        """(lock held) Delete retired versions whose readers drained, then
        evict the oldest unpinned non-head versions beyond capacity."""
        for vid in [v.vid for v in self._versions.values()
                    if v.retired and v.pins == 0]:
            del self._versions[vid]
        while len(self._versions) > self.capacity:
            victim = next((v for v in self._versions.values()
                           if v.vid != self._head_vid and v.pins == 0), None)
            if victim is None:
                break       # everything pinned: over capacity until drained
            del self._versions[victim.vid]
            self.evicted += 1

    # -- observability ------------------------------------------------------

    def gauges(self) -> dict:
        """Live MVCC gauges for :meth:`QueryServer.telemetry`."""
        with self._lock:
            return dict(
                live_versions=len(self._versions),
                head_vid=self._head_vid,
                pinned_readers={v.vid: v.pins
                                for v in self._versions.values() if v.pins},
                versions_committed=self.committed,
                versions_dropped=self.dropped,
                versions_evicted=self.evicted)
