from .pipeline import GraphEpochStream, MaskedItemStream, TokenStream

__all__ = ["GraphEpochStream", "MaskedItemStream", "TokenStream"]
