"""Deterministic, step-indexed data pipelines (replayable after restart).

Every loader is a pure function of (seed, step) so checkpoint-restart
recovery replays the identical stream — the property the fault-tolerance
tests assert.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStream:
    """Synthetic LM token batches (Zipf-ish unigram + ngram structure so the
    loss is learnable, not pure noise)."""
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        base = rng.zipf(1.5, size=(self.batch, self.seq_len + 1))
        toks = np.minimum(base - 1, self.vocab - 1).astype(np.int32)
        # inject copy structure: second half repeats first half shifted
        half = (self.seq_len + 1) // 2
        toks[:, half:2 * half] = toks[:, :half]
        return dict(tokens=jnp.asarray(toks[:, :-1]),
                    targets=jnp.asarray(toks[:, 1:]))


@dataclasses.dataclass(frozen=True)
class MaskedItemStream:
    """BERT4Rec Cloze batches."""
    n_items: int
    batch: int
    seq_len: int
    mask_token: int = 1
    mask_rate: float = 0.15
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        items = rng.integers(2, self.n_items, (self.batch, self.seq_len)
                             ).astype(np.int32)
        mask = rng.random((self.batch, self.seq_len)) < self.mask_rate
        mask[:, 0] |= ~mask.any(axis=1)          # ensure >=1 mask per row
        masked = np.where(mask, self.mask_token, items).astype(np.int32)
        return dict(items=jnp.asarray(masked), targets=jnp.asarray(items),
                    mask=jnp.asarray(mask))


@dataclasses.dataclass(frozen=True)
class GraphEpochStream:
    """Minibatch GNN training: step-indexed seed-node batches + fanout
    sampling (host side), padded to static shapes."""
    n_nodes: int
    batch_nodes: int
    seed: int = 0

    def seeds_at(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        return rng.choice(self.n_nodes, size=self.batch_nodes, replace=False)
