"""Typed errors for the serving stack (DESIGN.md Sec. 7).

Every failure the server can surface to a client is a subclass of
:class:`ServingError`, so callers catch one base class and branch on type
instead of string-matching messages.  The ``permanent`` attribute is the
retry contract: the drain loop retries transient failures with capped
exponential backoff but gives up immediately on permanent ones (a poison
query fails the same way every time — backing off just wastes its
batchmates' latency budgets).
"""
from __future__ import annotations


class ServingError(Exception):
    """Base class of every typed serving failure."""

    #: retrying the same operation cannot succeed when True
    permanent = False


class QueryTooExpensive(ServingError):
    """Admission control rejected a RED-lane query at ``submit`` time.

    Carries the cost estimate and the limit it exceeded so clients can
    split the query, raise their limit, or route it elsewhere.
    """

    permanent = True

    def __init__(self, kind: str, estimate: float, limit: float):
        self.kind = kind
        self.estimate = float(estimate)
        self.limit = float(limit)
        super().__init__(
            f"{kind} query cost estimate {self.estimate:.0f} exceeds the "
            f"red-lane admission limit {self.limit:.0f} semiring ops")


class DeadlineExceeded(ServingError):
    """The request's latency budget expired before it was served; the
    server fails it fast instead of computing an answer nobody is
    waiting for."""

    permanent = True

    def __init__(self, message: str = "request deadline exceeded"):
        super().__init__(message)


class DeadLetterError(ServingError):
    """A request kept failing after retries and batch bisection and was
    quarantined into the server's ``dead_letters`` list.  ``cause`` is the
    last underlying failure."""

    permanent = True

    def __init__(self, attempts: int, cause: BaseException):
        self.attempts = int(attempts)
        self.cause = cause
        super().__init__(f"request dead-lettered after {self.attempts} "
                         f"attempts: {cause!r}")


class DeltaApplyFailed(ServingError):
    """A :class:`~repro.core.fragments.GraphDelta` failed mid-apply and the
    fragmentation + caches were rolled back to the pre-delta snapshot
    (``arrays_version`` and ``cache_version`` unchanged; queries keep
    answering against the pre-delta graph)."""

    def __init__(self, cause: BaseException):
        self.cause = cause
        self.rolled_back = True
        self.permanent = getattr(cause, "permanent", False)
        super().__init__("graph delta failed and was rolled back "
                         f"(pre-delta cache intact): {cause!r}")


class InjectedFault(ServingError):
    """Raised by :class:`repro.serve.faults.FaultInjector` at an injection
    site.  ``permanent=True`` models a poison input that fails on every
    attempt; the default models a transient fault retries can outlive."""

    def __init__(self, site: str, detail: str = "", permanent: bool = False):
        self.site = site
        self.permanent = bool(permanent)
        msg = f"injected fault at {site!r}"
        super().__init__(msg + (f": {detail}" if detail else ""))
