"""Typed errors and lifecycle states for the serving stack (DESIGN.md
Sec. 7–8).

Every failure the server can surface to a client is a subclass of
:class:`ServingError`, so callers catch one base class and branch on type
instead of string-matching messages.  The ``permanent`` attribute is the
retry contract: the scheduler retries transient failures with capped
exponential backoff but gives up immediately on permanent ones (a poison
query fails the same way every time — backing off just wastes its
batchmates' latency budgets).

:class:`Status` is the one lifecycle enum shared by the whole stack:
query/update futures (:mod:`repro.serve.engine`), session results
(:class:`repro.core.plan.QueryResult`), and the error taxonomy here
(each terminal failure class carries the ``status`` it resolves a future
to).  It subclasses :class:`str`, so ``Status.DONE == "done"`` holds and
pre-enum callers that compared against string literals keep working.
"""
from __future__ import annotations

import enum


class Status(str, enum.Enum):
    """Lifecycle of a submitted request (query or graph update).

    ``PENDING`` -> queued, not yet picked up by the scheduler;
    ``RUNNING`` -> popped into an executing batch;
    terminal states: ``DONE`` (query answered), ``DEAD_LETTER`` (query
    quarantined after retries + bisection), ``DEADLINE`` (latency budget
    expired before service), ``APPLIED`` (delta landed), ``FAILED``
    (delta rolled back).
    """

    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    DEAD_LETTER = "dead_letter"
    DEADLINE = "deadline"
    APPLIED = "applied"
    FAILED = "failed"

    @property
    def terminal(self) -> bool:
        """True once a future carrying this status will never change."""
        return self not in (Status.PENDING, Status.RUNNING)

    def __str__(self) -> str:  # repr-friendly: "done", not "Status.DONE"
        return self.value


class ServingError(Exception):
    """Base class of every typed serving failure."""

    #: retrying the same operation cannot succeed when True
    permanent = False

    #: terminal :class:`Status` a future resolves to when this error is
    #: its outcome (``FAILED`` unless a subclass is more specific)
    status = Status.FAILED


class QueryTooExpensive(ServingError):
    """Admission control rejected a RED-lane query at ``submit`` time.

    Carries the cost estimate and the limit it exceeded so clients can
    split the query, raise their limit, or route it elsewhere.
    """

    permanent = True

    def __init__(self, kind: str, estimate: float, limit: float):
        self.kind = kind
        self.estimate = float(estimate)
        self.limit = float(limit)
        super().__init__(
            f"{kind} query cost estimate {self.estimate:.0f} exceeds the "
            f"red-lane admission limit {self.limit:.0f} semiring ops")


class DeadlineExceeded(ServingError):
    """The request's latency budget expired before it was served; the
    server fails it fast instead of computing an answer nobody is
    waiting for."""

    permanent = True
    status = Status.DEADLINE

    def __init__(self, message: str = "request deadline exceeded"):
        super().__init__(message)


class DeadLetterError(ServingError):
    """A request kept failing after retries and batch bisection and was
    quarantined into the server's ``dead_letters`` list.  ``cause`` is the
    last underlying failure."""

    permanent = True
    status = Status.DEAD_LETTER

    def __init__(self, attempts: int, cause: BaseException):
        self.attempts = int(attempts)
        self.cause = cause
        super().__init__(f"request dead-lettered after {self.attempts} "
                         f"attempts: {cause!r}")


class DeltaApplyFailed(ServingError):
    """A :class:`~repro.core.fragments.GraphDelta` failed mid-apply and the
    fragmentation + caches were rolled back to the pre-delta snapshot
    (``arrays_version`` and ``cache_version`` unchanged; queries keep
    answering against the pre-delta graph)."""

    status = Status.FAILED

    def __init__(self, cause: BaseException):
        self.cause = cause
        self.rolled_back = True
        self.permanent = getattr(cause, "permanent", False)
        super().__init__("graph delta failed and was rolled back "
                         f"(pre-delta cache intact): {cause!r}")


class InjectedFault(ServingError):
    """Raised by :class:`repro.serve.faults.FaultInjector` at an injection
    site.  ``permanent=True`` models a poison input that fails on every
    attempt; the default models a transient fault retries can outlive."""

    def __init__(self, site: str, detail: str = "", permanent: bool = False):
        self.site = site
        self.permanent = bool(permanent)
        msg = f"injected fault at {site!r}"
        super().__init__(msg + (f": {detail}" if detail else ""))
