from .graph import Graph, bfs_distances, bfs_reachable, csr_from_coo, reverse
from .generate import erdos_renyi, labeled_chain_graph, preferential_attachment
from .partition import (bfs_partition, block_partition, cut_stats,
                        hash_partition, random_partition)

__all__ = [
    "Graph", "bfs_distances", "bfs_reachable", "csr_from_coo", "reverse",
    "erdos_renyi", "labeled_chain_graph", "preferential_attachment",
    "bfs_partition", "block_partition", "cut_stats", "hash_partition",
    "random_partition",
]
