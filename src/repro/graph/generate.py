"""Synthetic graph generators (paper Section 7, 'Synthetic data').

The paper's generator is controlled by |V|, |E| and |L|; scalability
experiments follow the densification law [20].  We provide Erdos-Renyi-style
uniform graphs, preferential-attachment (power-law) graphs, and layered DAGs
with planted paths so that queries have controllable answers.
"""
from __future__ import annotations

import numpy as np

from .graph import Graph


def erdos_renyi(n: int, m: int, n_labels: int = 8, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m, dtype=np.int64)
    dst = rng.integers(0, n, size=m, dtype=np.int64)
    labels = rng.integers(0, n_labels, size=n).astype(np.int32)
    return Graph(n, src, dst, labels)


def preferential_attachment(n: int, m_per: int = 4, n_labels: int = 8,
                            seed: int = 0) -> Graph:
    """Power-law-ish digraph: each new node links to m_per earlier nodes,
    preferring high in-degree (densification-style growth)."""
    rng = np.random.default_rng(seed)
    srcs, dsts = [], []
    weights = np.ones(n, dtype=np.float64)
    for v in range(1, n):
        k = min(m_per, v)
        p = weights[:v] / weights[:v].sum()
        targets = rng.choice(v, size=k, replace=False, p=p)
        for t in targets:
            srcs.append(v)
            dsts.append(int(t))
            weights[t] += 1.0
    labels = rng.integers(0, n_labels, size=n).astype(np.int32)
    return Graph(n, np.array(srcs, dtype=np.int64),
                 np.array(dsts, dtype=np.int64), labels)


def labeled_chain_graph(n_chain: int, n_noise_nodes: int, n_noise_edges: int,
                        chain_label: int, n_labels: int = 8,
                        seed: int = 0) -> Graph:
    """A planted labeled chain 0 -> 1 -> ... -> n_chain-1 (all interior nodes
    carrying `chain_label`) embedded in random noise: gives regular
    reachability queries a guaranteed witness path."""
    rng = np.random.default_rng(seed)
    n = n_chain + n_noise_nodes
    src = list(range(n_chain - 1))
    dst = list(range(1, n_chain))
    src += list(rng.integers(0, n, size=n_noise_edges))
    dst += list(rng.integers(0, n, size=n_noise_edges))
    labels = rng.integers(0, n_labels, size=n).astype(np.int32)
    labels[1:n_chain - 1] = chain_label
    return Graph(n, np.array(src, dtype=np.int64),
                 np.array(dst, dtype=np.int64), labels)
