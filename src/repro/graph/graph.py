"""Host-side graph substrate.

Graphs here are *data-pipeline* objects: plain numpy arrays that the
fragmentation layer (`repro.core.fragments`) turns into padded, device-ready
pytrees.  Node-labeled directed graphs, per the paper (Section 2.1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Graph:
    """A node-labeled directed graph G = (V, E, L) in COO form."""

    n: int
    src: np.ndarray  # [E] int64 edge sources
    dst: np.ndarray  # [E] int64 edge targets
    labels: np.ndarray  # [n] int32 node labels (ids into label_names)
    label_names: Optional[Sequence[str]] = None

    def __post_init__(self):
        self.src = np.asarray(self.src, dtype=np.int64)
        self.dst = np.asarray(self.dst, dtype=np.int64)
        self.labels = np.asarray(self.labels, dtype=np.int32)
        assert self.src.shape == self.dst.shape
        assert self.labels.shape == (self.n,)
        if self.n:
            assert self.src.max(initial=-1) < self.n
            assert self.dst.max(initial=-1) < self.n

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    def size(self) -> int:
        """|G| = |V| + |E| (the paper's fragment-size measure)."""
        return self.n + self.m

    def label_of(self, name: str) -> int:
        assert self.label_names is not None
        return list(self.label_names).index(name)


def csr_from_coo(n: int, src: np.ndarray, dst: np.ndarray):
    """Build CSR (indptr, indices) sorted by source node."""
    order = np.argsort(src, kind="stable")
    s, d = src[order], dst[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, s + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, d


def out_degrees(g: Graph) -> np.ndarray:
    deg = np.zeros(g.n, dtype=np.int64)
    np.add.at(deg, g.src, 1)
    return deg


def reverse(g: Graph) -> Graph:
    return Graph(g.n, g.dst.copy(), g.src.copy(), g.labels.copy(), g.label_names)


def bfs_reachable(g: Graph, s: int) -> np.ndarray:
    """Host BFS oracle: boolean reachability from s (includes s)."""
    indptr, indices = csr_from_coo(g.n, g.src, g.dst)
    seen = np.zeros(g.n, dtype=bool)
    seen[s] = True
    frontier = [s]
    while frontier:
        nxt = []
        for u in frontier:
            for v in indices[indptr[u] : indptr[u + 1]]:
                if not seen[v]:
                    seen[v] = True
                    nxt.append(int(v))
        frontier = nxt
    return seen


def bfs_distances(g: Graph, s: int) -> np.ndarray:
    """Host BFS oracle: unit-weight distances from s (INF = -1)."""
    indptr, indices = csr_from_coo(g.n, g.src, g.dst)
    dist = np.full(g.n, -1, dtype=np.int64)
    dist[s] = 0
    frontier = [s]
    d = 0
    while frontier:
        nxt = []
        d += 1
        for u in frontier:
            for v in indices[indptr[u] : indptr[u + 1]]:
                if dist[v] < 0:
                    dist[v] = d
                    nxt.append(int(v))
        frontier = nxt
    return dist
