"""Graph partitioners.

The paper imposes *no constraints* on fragmentation (Section 2.1) and its
experiments use random partitioning; partition quality only affects |V_f|.
We provide random / hash / greedy-BFS-block partitioners.  The greedy one is
an edge-cut heuristic: partitioning to minimize sum |F_i.I||F_i.O| is
intractable (paper Section 6, [10]), so a cheap locality heuristic is the
practical choice.
"""
from __future__ import annotations

import numpy as np

from .graph import Graph, csr_from_coo


def random_partition(g: Graph, k: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, k, size=g.n).astype(np.int32)


def hash_partition(g: Graph, k: int) -> np.ndarray:
    return (np.arange(g.n, dtype=np.int64) * 2654435761 % 2**32 % k).astype(np.int32)


def block_partition(g: Graph, k: int) -> np.ndarray:
    """Contiguous index blocks (good for generators that grow locally)."""
    return np.minimum(np.arange(g.n) * k // max(g.n, 1), k - 1).astype(np.int32)


def bfs_partition(g: Graph, k: int, seed: int = 0) -> np.ndarray:
    """Greedy BFS blocks: grow fragments along edges to shrink the cut."""
    rng = np.random.default_rng(seed)
    indptr, indices = csr_from_coo(g.n, g.src, g.dst)
    part = np.full(g.n, -1, dtype=np.int32)
    target = (g.n + k - 1) // k
    cur = 0
    count = 0
    order = rng.permutation(g.n)
    queue: list[int] = []
    oi = 0
    while cur < k:
        if not queue:
            while oi < g.n and part[order[oi]] >= 0:
                oi += 1
            if oi >= g.n:
                break
            queue.append(int(order[oi]))
        u = queue.pop(0)
        if part[u] >= 0:
            continue
        part[u] = cur
        count += 1
        if count >= target:
            cur, count, queue = cur + 1, 0, []
            continue
        for v in indices[indptr[u] : indptr[u + 1]]:
            if part[v] < 0:
                queue.append(int(v))
    part[part < 0] = k - 1
    return part


def cut_stats(g: Graph, part: np.ndarray) -> dict:
    cross = part[g.src] != part[g.dst]
    v_f = np.unique(np.concatenate([g.dst[cross], []])).size
    return {
        "cross_edges": int(cross.sum()),
        "in_nodes": int(np.unique(g.dst[cross]).size),
        "v_f": int(v_f),
    }
