from .ops import bitpack_bool_matmul, pack_cols, pack_rows, unpack_rows
from .ref import bitpack_matmul_ref, pack_rows_ref

__all__ = ["bitpack_bool_matmul", "pack_cols", "pack_rows", "unpack_rows",
           "bitpack_matmul_ref", "pack_rows_ref"]
