from .ops import (bitpack_bool_matmul, pack_cols, pack_payload, pack_rows,
                  packed_bits, unpack_payload, unpack_rows)
from .ref import bitpack_matmul_ref, pack_rows_ref

__all__ = ["bitpack_bool_matmul", "pack_cols", "pack_rows", "unpack_rows",
           "pack_payload", "unpack_payload", "packed_bits",
           "bitpack_matmul_ref", "pack_rows_ref"]
