"""Bit-packed or-and matmul Pallas kernel (TPU target) — beyond-paper opt.

The paper counts rvset traffic in *bits* (Theorem 1: |V_f| equations of
|V_f| bits).  Packing 32 boundary nodes per uint32 lane makes the engine
match that accounting exactly: the all-gathered boundary matrix and the
closure working set shrink 32x, and the or-and contraction becomes

    C[i, j] = OR_w ( Apacked[i, w] AND Bpacked[w, j] ) != 0

— pure VPU bitwise ops, 32 contraction steps per loaded word.  The closure
becomes memory-bound-optimal at the cost of leaving the MXU idle; see
EXPERIMENTS.md §Perf for the crossover vs ``bool_matmul``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def pack_rows(a: jax.Array) -> jax.Array:
    """[M, K] bool -> [M, ceil(K/32)] uint32 (bit b of word w = a[:, 32w+b])."""
    M, K = a.shape
    W = (K + 31) // 32
    pad = W * 32 - K
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)))
    bits = a.reshape(M, W, 32).astype(jnp.uint32)
    weights = jnp.left_shift(jnp.uint32(1), jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(bits * weights[None, None, :], axis=-1, dtype=jnp.uint32)


def pack_cols(b: jax.Array) -> jax.Array:
    """[K, N] bool -> [ceil(K/32), N] uint32 (bit b of word w = b[32w+b, :])."""
    return pack_rows(b.T).T


def unpack_rows(ap: jax.Array, K: int) -> jax.Array:
    """Inverse of pack_rows."""
    M, W = ap.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (ap[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(M, W * 32)[:, :K].astype(bool)


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int, cw: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]                       # [bm, bw] uint32
    b = b_ref[...]                       # [bw, bn] uint32
    bm, bw = a.shape
    bn = b.shape[1]

    def chunk(c, acc):
        a_c = jax.lax.dynamic_slice(a, (0, c * cw), (bm, cw))
        b_c = jax.lax.dynamic_slice(b, (c * cw, 0), (cw, bn))
        hit = (a_c[:, :, None] & b_c[None, :, :]) != 0    # [bm, cw, bn]
        return acc | jnp.any(hit, axis=1)

    acc_ref[...] = jax.lax.fori_loop(0, bw // cw, chunk, acc_ref[...])

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finalize():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bw", "cw", "interpret"))
def bitpack_matmul_pallas(ap: jax.Array, bp: jax.Array, *, bm: int = 128,
                          bn: int = 128, bw: int = 8, cw: int = 8,
                          interpret: bool = False) -> jax.Array:
    """ap [M, W] uint32 (row-packed), bp [W, N] uint32 (col-packed) ->
    or-and product [M, N] bool."""
    M, W = ap.shape
    W2, N = bp.shape
    assert W == W2 and M % bm == 0 and N % bn == 0 and W % bw == 0
    assert bw % cw == 0
    k_steps = W // bw
    grid = (M // bm, N // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, cw=cw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bw, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.bool_),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.bool_)],
        interpret=interpret,
    )(ap, bp)
