"""Jit'd wrapper: packs Boolean operands, pads, runs the packed kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .bitpack_ops import (bitpack_matmul_pallas, pack_cols, pack_rows,
                          unpack_rows)


@functools.partial(jax.jit, static_argnames=("block",))
def bitpack_bool_matmul(a: jax.Array, b: jax.Array,
                        block: int = 128) -> jax.Array:
    """Boolean or-and matmul via 32x bit-packing.  a [M,K], b [K,N] bool."""
    M, K = a.shape
    N = b.shape[1]
    ap = pack_rows(a.astype(bool))                     # [M, W]
    bp = pack_cols(b.astype(bool))                     # [W, N]
    W = ap.shape[1]
    bw = 8
    pm, pn, pw = (-M) % block, (-N) % block, (-W) % bw
    ap = jnp.pad(ap, ((0, pm), (0, pw)))
    bp = jnp.pad(bp, ((0, pw), (0, pn)))
    out = bitpack_matmul_pallas(ap, bp, bm=block, bn=block, bw=bw,
                                interpret=jax.default_backend() != "tpu")
    return out[:M, :N]


def pack_payload(m: jax.Array) -> jax.Array:
    """Pack a Boolean payload matrix [R, C] into uint32 words [R, ceil(C/32)]
    for the one collective in ``core.distributed`` (8x fewer bits and bytes
    on the wire than the seed's uint8-per-entry shipping)."""
    return pack_rows(m.astype(bool))


def unpack_payload(p: jax.Array, n_cols: int) -> jax.Array:
    """Inverse of :func:`pack_payload` on the replicated side."""
    return unpack_rows(p, n_cols)


def packed_bits(rows: int, cols: int) -> int:
    """Bits actually shipped for a [rows, cols] Boolean payload once packed:
    rows x ceil(cols/32) uint32 words."""
    return rows * ((cols + 31) // 32) * 32


__all__ = ["bitpack_bool_matmul", "pack_rows", "pack_cols", "unpack_rows",
           "pack_payload", "unpack_payload", "packed_bits"]
