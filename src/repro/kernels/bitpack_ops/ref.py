"""Pure-jnp oracle for the bit-packed or-and matmul (and packing)."""
import jax.numpy as jnp


def bitpack_matmul_ref(a_bool, b_bool):
    """Unpacked oracle: plain or-and product of the Boolean operands."""
    return (a_bool.astype(jnp.float32) @ b_bool.astype(jnp.float32)) > 0


def pack_rows_ref(a):
    import numpy as np
    a = np.asarray(a)
    M, K = a.shape
    W = (K + 31) // 32
    out = np.zeros((M, W), dtype=np.uint32)
    for k in range(K):
        out[:, k // 32] |= a[:, k].astype(np.uint32) << np.uint32(k % 32)
    return out
