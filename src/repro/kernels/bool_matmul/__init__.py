from .ops import bool_matmul
from .ref import bool_matmul_ref

__all__ = ["bool_matmul", "bool_matmul_ref"]
