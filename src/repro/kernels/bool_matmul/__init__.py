from .ops import bool_matmul, or_and_matmul
from .ref import bool_matmul_ref

__all__ = ["bool_matmul", "or_and_matmul", "bool_matmul_ref"]
