"""Or-and semiring matmul Pallas kernel (TPU target).

C[i, j] = OR_k ( A[i, k] AND B[k, j] )

This is the frontier-expansion / closure-squaring hot spot of the paper's
evalDG (DESIGN.md Sec. 2.1).  TPU mapping: 0/1 operands are upcast to f32
inside the kernel so each (bm, bk) x (bk, bn) block rides the MXU; the
accumulator stays f32 in a VMEM scratch across the K grid axis and is
thresholded (> 0) on the last K step.  Default blocks of 128 are
MXU-aligned; three f32 128x128 buffers = 192 KiB, far under VMEM.

Validated on CPU with interpret=True against ref.py (tests/test_kernels.py);
compiled path is exercised by the dry-run on the TPU target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(a, b,
                                preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finalize():
        o_ref[...] = acc_ref[...] > 0.0


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def bool_matmul_pallas(a: jax.Array, b: jax.Array, *, bm: int = 128,
                       bn: int = 128, bk: int = 128,
                       interpret: bool = False) -> jax.Array:
    """a [M, K] bool, b [K, N] bool -> [M, N] bool.  M, N, K must be
    multiples of the block sizes (ops.py pads)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape)
    k_steps = K // bk
    grid = (M // bm, N // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.bool_),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
