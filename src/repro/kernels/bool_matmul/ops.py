"""Jit'd public wrapper: pads to block multiples, picks interpret mode on CPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .bool_matmul import bool_matmul_pallas


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block",))
def bool_matmul(a: jax.Array, b: jax.Array, block: int = 128) -> jax.Array:
    """Or-and matmul with automatic padding; interpret=True off-TPU."""
    M, N = a.shape[0], b.shape[1]
    bm = bn = bk = block
    a = _pad_to(a.astype(bool), bm, bk)
    b = _pad_to(b.astype(bool), bk, bn)
    out = bool_matmul_pallas(a, b, bm=bm, bn=bn, bk=bk,
                             interpret=not _on_tpu())
    return out[:M, :N]


def or_and_matmul(a: jax.Array, b: jax.Array, block: int = 128) -> jax.Array:
    """Backend-dispatched or-and contraction C = OR_k (a & b).

    The rvset cache / evalDG hot path routes through here: on TPU the MXU
    Pallas kernel runs compiled; elsewhere the same semiring is one XLA f32
    matmul + threshold (interpret-mode Pallas would be orders of magnitude
    slower on CPU, so it is reserved for the kernel unit tests).
    """
    if _on_tpu():
        return bool_matmul(a, b, block=block)
    return (a.astype(jnp.float32) @ b.astype(jnp.float32)) > 0
