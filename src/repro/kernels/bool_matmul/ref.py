"""Pure-jnp oracle for the or-and semiring matmul."""
import jax.numpy as jnp


def bool_matmul_ref(a, b):
    """a [M, K] bool, b [K, N] bool -> OR_k(a & b) [M, N] bool."""
    return (a.astype(jnp.float32) @ b.astype(jnp.float32)) > 0
