from .ops import min_plus_chunked, min_plus_matmul, tropical_matmul
from .ref import tropical_matmul_ref

__all__ = ["tropical_matmul", "min_plus_matmul", "min_plus_chunked",
           "tropical_matmul_ref"]
