from .ops import tropical_matmul
from .ref import tropical_matmul_ref

__all__ = ["tropical_matmul", "tropical_matmul_ref"]
