"""Jit'd public wrapper: INF-pads to block multiples; interpret off-TPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .tropical_matmul import INF, tropical_matmul_pallas


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)), constant_values=int(INF))
    return x


@functools.partial(jax.jit, static_argnames=("block",))
def tropical_matmul(a: jax.Array, b: jax.Array, block: int = 128) -> jax.Array:
    M, N = a.shape[0], b.shape[1]
    a = _pad_to(a.astype(jnp.int32), block, block)
    b = _pad_to(b.astype(jnp.int32), block, block)
    out = tropical_matmul_pallas(a, b, bm=block, bn=block, bk=block,
                                 interpret=jax.default_backend() != "tpu")
    return out[:M, :N]


def min_plus_chunked(a: jax.Array, b: jax.Array,
                     row_chunk: int = 16) -> jax.Array:
    """Pure-jnp row-chunked (min, +) contraction: chunking caps the
    [C, K, N] broadcast intermediate so the closure of a few-thousand-node
    boundary stays well under a GiB.  The single shared fallback for every
    non-TPU min-plus path (bes closures, evalDG_d, batched dist)."""
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    M, K = a.shape
    if M == 0 or K == 0:        # empty contraction: min over nothing == INF
        return jnp.full((M, b.shape[1]), INF, jnp.int32)

    def one_chunk(rows):
        return jnp.min(rows[:, :, None] + b[None, :, :], axis=1)

    if M <= row_chunk:
        return jnp.minimum(one_chunk(a), INF)
    pad = (-M) % row_chunk
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)), constant_values=int(INF))
    chunks = a.reshape(-1, row_chunk, K)
    out = jax.lax.map(one_chunk, chunks)
    return jnp.minimum(out.reshape(-1, b.shape[1])[:M], INF)


def min_plus_matmul(a: jax.Array, b: jax.Array, block: int = 128,
                    row_chunk: int = 16) -> jax.Array:
    """Backend-dispatched (min, +) contraction C = min_k (a + b):
    the Pallas tropical kernel on TPU, :func:`min_plus_chunked` elsewhere."""
    if jax.default_backend() == "tpu":
        return tropical_matmul(a, b, block=block)
    return min_plus_chunked(a, b, row_chunk=row_chunk)
