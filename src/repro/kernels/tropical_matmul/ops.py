"""Jit'd public wrapper: INF-pads to block multiples; interpret off-TPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .tropical_matmul import INF, tropical_matmul_pallas


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)), constant_values=int(INF))
    return x


@functools.partial(jax.jit, static_argnames=("block",))
def tropical_matmul(a: jax.Array, b: jax.Array, block: int = 128) -> jax.Array:
    M, N = a.shape[0], b.shape[1]
    a = _pad_to(a.astype(jnp.int32), block, block)
    b = _pad_to(b.astype(jnp.int32), block, block)
    out = tropical_matmul_pallas(a, b, bm=block, bn=block, bk=block,
                                 interpret=jax.default_backend() != "tpu")
    return out[:M, :N]
