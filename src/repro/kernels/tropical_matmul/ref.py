"""Pure-jnp oracle for the (min, +) matmul."""
import jax.numpy as jnp

INF = jnp.int32(1 << 29)


def tropical_matmul_ref(a, b):
    """a [M, K], b [K, N] int32 -> min_k(a + b) [M, N], INF-saturated."""
    out = jnp.min(a[:, :, None] + b[None, :, :], axis=1)
    return jnp.minimum(out, INF).astype(jnp.int32)
