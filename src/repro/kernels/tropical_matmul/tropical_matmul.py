"""Tropical (min, +) semiring matmul Pallas kernel (TPU target).

C[i, j] = min_k ( A[i, k] + B[k, j] )      (int32, INF-saturating)

The disDist closure hot spot (paper Sec. 4; DESIGN.md Sec. 2.1).  There is
no MXU path for (min, +), so the kernel is VPU-shaped: for each (bm, bk) x
(bk, bn) block pair it sweeps the contraction axis in chunks of ``ck``,
materializing a [bm, ck, bn] broadcast-add in VMEM and folding it into the
accumulator with a running elementwise min.  ck=8 keeps the intermediate at
128*8*128*4B = 512 KiB worst-case; the accumulator persists across the K
grid axis in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INF = 1 << 29    # python int: safe to close over inside the kernel body


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int, ck: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, INF)

    a = a_ref[...]                      # [bm, bk] int32
    b = b_ref[...]                      # [bk, bn] int32
    bm, bk = a.shape
    bn = b.shape[1]

    def chunk(c, acc):
        a_c = jax.lax.dynamic_slice(a, (0, c * ck), (bm, ck))
        b_c = jax.lax.dynamic_slice(b, (c * ck, 0), (ck, bn))
        vals = a_c[:, :, None] + b_c[None, :, :]      # [bm, ck, bn]
        return jnp.minimum(acc, jnp.min(vals, axis=1))

    acc = jax.lax.fori_loop(0, bk // ck, chunk, acc_ref[...])
    acc_ref[...] = jnp.minimum(acc, INF)              # saturate

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finalize():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "ck", "interpret"))
def tropical_matmul_pallas(a: jax.Array, b: jax.Array, *, bm: int = 128,
                           bn: int = 128, bk: int = 128, ck: int = 8,
                           interpret: bool = False) -> jax.Array:
    """a [M, K] int32, b [K, N] int32 -> min-plus product [M, N] int32."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2 and K % bk == 0 and M % bm == 0 and N % bn == 0
    assert bk % ck == 0
    k_steps = K // bk
    grid = (M // bm, N // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, ck=ck),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a, b)
