"""Sharding-constraint helper usable inside model code.

``hint(x, spec...)`` applies lax.with_sharding_constraint when tracing
under a mesh context whose axis names cover the spec, and is a no-op
otherwise (smoke tests and single-device runs trace the same code with no
mesh).  The constraint is best-effort by design: models must stay valid
without any mesh.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def hint(x, *spec_parts):
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec_parts))
    except Exception:   # no mesh context / unknown axis names -> no-op
        return x
