import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production mesh, record memory/cost/collective analysis.

The two lines above MUST run before any other import (jax locks the device
count at first init); 512 placeholder host devices back both the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape train_4k --multi-pod both --out results/dryrun.json
"""
import argparse    # noqa: E402
import json        # noqa: E402
import time        # noqa: E402
import traceback   # noqa: E402

import jax                                   # noqa: E402
from jax.sharding import NamedSharding       # noqa: E402

from repro.configs import ARCHS, get_arch    # noqa: E402
from repro.launch.hlo_stats import (collective_bytes,     # noqa: E402
                                    collective_schedule)
from repro.launch.mesh import make_production_mesh        # noqa: E402


def _compile(prog, mesh):
    shardings = jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                             prog.arg_specs,
                             is_leaf=lambda x: isinstance(
                                 x, jax.sharding.PartitionSpec))
    with mesh:
        lowered = jax.jit(prog.step_fn, in_shardings=shardings).lower(
            *prog.abstract_args)
        compiled = lowered.compile()
    return compiled


def _costs(compiled, scale: float = 1.0) -> dict:
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return dict(flops=float(cost.get("flops", 0.0)) * scale,
                bytes=float(cost.get("bytes accessed", 0.0)) * scale,
                coll=float(coll.get("total", 0)) * scale,
                coll_count=int(coll.get("count", 0)),
                breakdown={k: v * scale for k, v in coll.items()
                           if k not in ("total", "count")})


def probe_costs(arch, arch_id, shape_id, multi_pod,
                optimized: bool = False) -> dict:
    """Loop-free cost probes (XLA counts loop bodies once, so the full
    compile undercounts).  LM: 2- and 4-layer unrolled probes, linear
    extrapolation in n_layers, x grad-accum for train.  recsys serve_bulk:
    one chunk x n_chunks.  Everything else is loop-free already."""
    fam = getattr(arch, "family", "")
    mesh = make_production_mesh(multi_pod=multi_pod)
    if fam == "lm":
        # L=2 / L=4 probes (L=1 degenerates under XLA's optimizer);
        # slope clamped non-negative for robustness.
        p2 = arch.build(shape_id, multipod=multi_pod, probe_layers=2,
                        optimized=optimized)
        p4 = arch.build(shape_id, multipod=multi_pod, probe_layers=4,
                        optimized=optimized)
        c2 = _costs(_compile(p2, mesh))
        c4 = _costs(_compile(p4, mesh))
        L = arch.base_cfg.n_layers
        scale = p2.cost_scale
        out = {}
        for k in ("flops", "bytes", "coll"):
            slope = max((c4[k] - c2[k]) / 2.0, 0.0)
            out[k] = scale * (c2[k] + slope * (L - 2))
        out["method"] = f"lm-2pt-extrapolation(L={L}, scale={scale})"
        return out
    if fam == "recsys" and shape_id == "serve_bulk":
        p = arch.build(shape_id, multipod=multi_pod, probe=True,
                       optimized=optimized)
        c = _costs(_compile(p, mesh), scale=p.cost_scale)
        return dict(flops=c["flops"], bytes=c["bytes"], coll=c["coll"],
                    method=f"chunk-probe(x{p.cost_scale})")
    return {}


def run_cell(arch_id: str, shape_id: str, multi_pod: bool,
             verbose: bool = True, probes: bool = True,
             optimized: bool = False) -> dict:
    """Lower + compile one cell; return the dry-run record."""
    arch = get_arch(arch_id)
    skip = arch.skip_reason(shape_id)
    rec = dict(arch=arch_id, shape=shape_id,
               mesh="2x16x16" if multi_pod else "16x16",
               variant="optimized" if optimized else "baseline")
    if skip:
        rec.update(status="skipped", reason=skip)
        return rec

    t0 = time.time()
    try:
        prog = arch.build(shape_id, multipod=multi_pod, reduced=False,
                          optimized=optimized)
    except TypeError:
        prog = arch.build(shape_id, multipod=multi_pod, reduced=False)
    mesh = make_production_mesh(multi_pod=multi_pod)
    compiled = _compile(prog, mesh)
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    n_dev = mesh.devices.size

    per_dev_bytes = getattr(mem, "temp_size_in_bytes", 0) + \
        getattr(mem, "argument_size_in_bytes", 0) + \
        getattr(mem, "output_size_in_bytes", 0)
    rec.update(
        status="ok",
        kind=prog.kind,
        seconds=round(time.time() - t0, 1),
        n_devices=int(n_dev),
        hlo_flops=float(cost.get("flops", 0.0)),
        hlo_bytes=float(cost.get("bytes accessed", 0.0)),
        arg_bytes_per_dev=int(getattr(mem, "argument_size_in_bytes", 0)),
        temp_bytes_per_dev=int(getattr(mem, "temp_size_in_bytes", 0)),
        out_bytes_per_dev=int(getattr(mem, "output_size_in_bytes", 0)),
        peak_bytes_per_dev=int(per_dev_bytes),
        collective_bytes=int(coll.get("total", 0)),
        collective_count=int(coll.get("count", 0)),
        collective_breakdown={k: int(v) for k, v in coll.items()
                              if k not in ("total", "count")},
        collective_schedule=collective_schedule(hlo),
        model_flops=float(prog.model_flops),
        model_bytes=float(prog.model_bytes),
    )
    if probes:
        pc = probe_costs(arch, arch_id, shape_id, multi_pod,
                         optimized=optimized)
        if pc:
            rec["probe_flops"] = pc["flops"]
            rec["probe_bytes"] = pc["bytes"]
            rec["probe_collective_bytes"] = pc["coll"]
            rec["probe_method"] = pc["method"]
        else:   # loop-free program: the direct costs are already exact
            rec["probe_flops"] = rec["hlo_flops"]
            rec["probe_bytes"] = rec["hlo_bytes"]
            rec["probe_collective_bytes"] = float(rec["collective_bytes"])
            rec["probe_method"] = "loop-free-direct"
    if verbose:
        print(f"[{arch_id} x {shape_id} x {rec['mesh']}] OK "
              f"({rec['seconds']}s)")
        print(f"  memory/device: args={rec['arg_bytes_per_dev']/2**30:.2f}GiB "
              f"temp={rec['temp_bytes_per_dev']/2**30:.2f}GiB "
              f"out={rec['out_bytes_per_dev']/2**30:.2f}GiB")
        print(f"  HLO flops={rec['hlo_flops']:.3e} "
              f"bytes={rec['hlo_bytes']:.3e} "
              f"collective={rec['collective_bytes']/2**20:.1f}MiB "
              f"({rec['collective_count']} ops)")
        print(f"  schedule: {rec['collective_schedule'][:4]}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape id (default: all)")
    ap.add_argument("--multi-pod", choices=("no", "yes", "both"),
                    default="both")
    ap.add_argument("--optimized", action="store_true",
                    help="build with the beyond-paper optimizations on")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    arch_ids = [args.arch] if args.arch else list(ARCHS)
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    records = []
    failures = 0

    def flush():
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)

    for aid in arch_ids:
        shape_ids = [args.shape] if args.shape else \
            get_arch(aid).shape_ids()
        for sid in shape_ids:
            for mp in pods:
                try:
                    # cost probes only on the single-pod mesh — the
                    # roofline table is single-pod (assignment §ROOFLINE)
                    records.append(run_cell(aid, sid, mp, probes=not mp,
                                            optimized=args.optimized))
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    traceback.print_exc()
                    records.append(dict(arch=aid, shape=sid,
                                        mesh="2x16x16" if mp else "16x16",
                                        status="error", error=str(e)[:500]))
                flush()
    ok = sum(1 for r in records if r["status"] == "ok")
    sk = sum(1 for r in records if r["status"] == "skipped")
    print(f"\n== dry-run: {ok} ok / {sk} skipped / {failures} failed "
          f"-> {args.out}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
