"""Parse collective traffic out of lowered/compiled HLO text.

cost_analysis() has no collective-bytes entry, so the roofline's third term
is summed from the operand sizes of every collective op in the module
(assignment: §ROOFLINE ANALYSIS)."""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# matches e.g.  %all-reduce.5 = f32[128,1024]{1,0} all-reduce(...)
_HLO_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _elem_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output sizes of every collective in an *optimized HLO* module.
    Returns {op_kind: bytes, ..., 'total': bytes, 'count': n}."""
    out: Dict[str, int] = {}
    count = 0
    for m in _HLO_RE.finditer(hlo_text):
        tuple_body, dtype, dims, kind = m.groups()
        if tuple_body is not None:
            size = sum(_elem_bytes(d, s)
                       for d, s in _TUPLE_ELEM_RE.findall(tuple_body))
        else:
            size = _elem_bytes(dtype, dims)
        out[kind] = out.get(kind, 0) + size
        count += 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["count"] = count
    return out


def collective_schedule(hlo_text: str, limit: int = 12):
    """First few collectives with shapes — the 'collective schedule' the
    dry-run records in EXPERIMENTS.md."""
    items = []
    for m in _HLO_RE.finditer(hlo_text):
        tuple_body, dtype, dims, kind = m.groups()
        shape = tuple_body if tuple_body is not None else f"{dtype}[{dims}]"
        items.append(f"{kind}({shape})")
        if len(items) >= limit:
            break
    return items
