"""Parse collective traffic out of lowered/compiled HLO text.

cost_analysis() has no collective-bytes entry, so the roofline's third term
is summed from the result sizes of every collective op in the module
(assignment: §ROOFLINE ANALYSIS).

The actual parsing lives in :mod:`repro.analysis.hlo_check` — the single
structured HLO/StableHLO parser in the repo (DESIGN.md Sec. 10.1).  This
module keeps the launch layer's aggregate view on top of it.  Unlike the
old regex scan, the structured parser counts an async ``-start``/``-done``
pair as ONE collective and raises on element types it does not know
instead of silently guessing 4 bytes.
"""
from __future__ import annotations

from typing import Dict, List

from ..analysis.hlo_check import parse_program


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result sizes of every collective in a lowered/compiled module.
    Returns {op_kind: bytes, ..., 'total': bytes, 'count': n}."""
    model = parse_program(hlo_text)
    out: Dict[str, int] = {}
    for op in model.collectives:
        out[op.kind] = out.get(op.kind, 0) + op.payload_bits // 8
    out["total"] = sum(out.values())
    out["count"] = len(model.collectives)
    return out


def collective_schedule(hlo_text: str, limit: int = 12) -> List[str]:
    """First few collectives with shapes — the 'collective schedule' the
    dry-run records in EXPERIMENTS.md."""
    items = []
    for op in parse_program(hlo_text).collectives[:limit]:
        shapes = ", ".join(str(t) for t in op.results)
        shape = shapes if len(op.results) == 1 else f"({shapes})"
        items.append(f"{op.kind}({shape})")
    return items
