"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (required by the dry-run contract: only dryrun.py
sets the 512-placeholder-device XLA flag)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (= 256 chips, one v5e pod) or 2x16x16 (= 512 chips, two pods).

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
    The "pod" axis carries only data-parallel traffic (gradient
    all-reduce over DCN); "model" carries TP/EP collectives on ICI.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(k: int):
    """Small helper mesh for single-host multi-device runs (tests)."""
    return jax.make_mesh((k,), ("data",))
