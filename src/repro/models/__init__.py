from . import bert4rec, transformer
from . import gnn

__all__ = ["bert4rec", "transformer", "gnn"]
