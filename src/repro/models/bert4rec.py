"""BERT4Rec (arXiv:1904.06690): bidirectional transformer over item
sequences with a masked-item (Cloze) objective, plus the three serving
paths of the assigned shape set (online p99, offline bulk, retrieval
against ~1M candidates).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..recsys.embedding import embedding_lookup

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    name: str = "bert4rec"
    n_items: int = 1_000_000     # embedding-table rows (incl. PAD=0, MASK=1)
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    d_ff_mult: int = 4
    dtype: Any = jnp.float32
    # beyond-paper serving optimization: two-stage top-k over the
    # model-sharded item axis (local top-k per shard, then a tiny global
    # top-k) — avoids all-gathering [chunk, n_items] logits per chunk.
    topk_ways: int = 0

    MASK: int = 1
    PAD: int = 0

    def n_params(self) -> int:
        d = self.embed_dim
        per_block = 4 * d * d + 2 * d * (d * self.d_ff_mult) + 4 * d
        return self.n_items * d + self.seq_len * d + \
            self.n_blocks * per_block + 2 * d


def init_params(cfg: Bert4RecConfig, key) -> Params:
    d = cfg.embed_dim
    ks = jax.random.split(key, 3 + cfg.n_blocks)

    def blk(k):
        kk = jax.random.split(k, 6)
        s = 1.0 / jnp.sqrt(d)
        return dict(
            ln1=jnp.ones((d,), cfg.dtype), ln2=jnp.ones((d,), cfg.dtype),
            wqkv=(jax.random.normal(kk[0], (d, 3 * d)) * s).astype(cfg.dtype),
            wo=(jax.random.normal(kk[1], (d, d)) * s).astype(cfg.dtype),
            w1=(jax.random.normal(kk[2], (d, cfg.d_ff_mult * d)) * s).astype(cfg.dtype),
            w2=(jax.random.normal(kk[3], (cfg.d_ff_mult * d, d)) *
                (1.0 / jnp.sqrt(cfg.d_ff_mult * d))).astype(cfg.dtype),
        )

    blocks = jax.vmap(blk)(jax.random.split(ks[2], cfg.n_blocks))
    return dict(
        item_embed=(jax.random.normal(ks[0], (cfg.n_items, d)) * 0.02
                    ).astype(cfg.dtype),
        pos_embed=(jax.random.normal(ks[1], (cfg.seq_len, d)) * 0.02
                   ).astype(cfg.dtype),
        ln_f=jnp.ones((d,), cfg.dtype),
        blocks=blocks,
    )


def _ln(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def encode(cfg: Bert4RecConfig, params: Params, items) -> jax.Array:
    """items [B, S] int32 -> hidden states [B, S, d].  Bidirectional
    attention with PAD masking (encoder-only: no causal mask, no decode)."""
    B, S = items.shape
    d, h = cfg.embed_dim, cfg.n_heads
    dh = d // h
    x = embedding_lookup(params["item_embed"], items)
    x = x + params["pos_embed"][None, :S, :]
    pad = items == cfg.PAD                                  # [B, S]

    def blk(x, p):
        hx = _ln(x, p["ln1"])
        qkv = hx @ p["wqkv"]
        q, k, v = [z.reshape(B, S, h, dh)
                   for z in jnp.split(qkv, 3, axis=-1)]
        scores = jnp.einsum("bshd,bthd->bhst", q, k) / jnp.sqrt(
            jnp.array(dh, jnp.float32)).astype(x.dtype)
        scores = scores.astype(jnp.float32)
        live = ~pad[:, None, None, :]
        smax = jnp.max(jnp.where(live, scores, -1e30), axis=-1,
                       keepdims=True)
        smax = jnp.maximum(smax, -1e30)
        # clamp the exp *input* (not output): exp of the untaken branch
        # would compute inf and poison the vjp with inf * 0 = nan
        ex = jnp.exp(jnp.where(live, scores - smax, -1e4))
        probs = (ex / jnp.maximum(jnp.sum(ex, axis=-1, keepdims=True),
                                  1e-9)).astype(x.dtype)
        att = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(B, S, d)
        x = x + att @ p["wo"]
        hx = _ln(x, p["ln2"])
        x = x + jax.nn.gelu(hx @ p["w1"]) @ p["w2"]
        return x, None

    # unrolled (2 blocks): keeps XLA cost_analysis exact for the dry-run
    x, _ = jax.lax.scan(blk, x, params["blocks"], unroll=cfg.n_blocks)
    return _ln(x, params["ln_f"])


def masked_item_loss(cfg: Bert4RecConfig, params: Params, items, targets,
                     mask) -> jax.Array:
    """Cloze objective: items with MASK tokens, targets the original ids,
    mask [B, S] bool marking positions to predict."""
    hidden = encode(cfg, params, items)                      # [B, S, d]
    logits = (hidden @ params["item_embed"].T).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def sampled_masked_loss(cfg: Bert4RecConfig, params: Params, items,
                        mask_positions, targets, negatives) -> jax.Array:
    """Production-scale Cloze loss: gather the masked positions, score
    against (shared) sampled negatives + the gold item instead of the full
    1M-row softmax (sampled softmax a la Covington/Yi et al.).

    items [B, S]; mask_positions [B, M] (indices into S); targets [B, M];
    negatives [n_neg] shared item ids.
    """
    hidden = encode(cfg, params, items)                       # [B, S, d]
    h = jnp.take_along_axis(hidden, mask_positions[..., None], axis=1)
    neg_vecs = embedding_lookup(params["item_embed"], negatives)   # [n, d]
    pos_vecs = embedding_lookup(params["item_embed"], targets)     # [B, M, d]
    neg_logits = jnp.einsum("bmd,nd->bmn", h, neg_vecs).astype(jnp.float32)
    pos_logit = jnp.sum(h * pos_vecs, axis=-1).astype(jnp.float32)
    logits = jnp.concatenate([pos_logit[..., None], neg_logits], axis=-1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    return jnp.mean(logz - pos_logit)


def _topk_scores(cfg: Bert4RecConfig, scores, k: int):
    """Exact top-k; with cfg.topk_ways, two-stage over the sharded item
    axis: per-shard top-k runs locally, only [rows, ways*k] crosses the
    network instead of [rows, n_items]."""
    if not cfg.topk_ways:
        return jax.lax.top_k(scores, k)
    from ..launch.constraints import hint
    rows, V = scores.shape
    W = cfg.topk_ways
    assert V % W == 0
    # GSPMD's sort partitioner all-gathers the operand regardless of
    # layout hints (measured: §Perf); shard_map makes the per-shard top_k
    # *local by construction*.  Falls back to plain top_k with no mesh.
    s3 = scores.reshape(rows, W, V // W).transpose(1, 0, 2)   # [W, rows, .]

    def _local(block):           # [W/shards, rows, V/W] per device
        return jax.lax.top_k(block, k)

    try:
        from jax.sharding import PartitionSpec as _P
        # ways on "model", rows stay on "data": replicating either axis
        # forces a full-logits all-gather (measured, §Perf)
        spec = _P("model", "data", None)
        s3c = hint(s3, "model", "data", None)
        v_loc, i_loc = jax.shard_map(
            _local, in_specs=spec, out_specs=(spec, spec))(s3c)
    except Exception:            # no mesh context (single-device paths)
        v_loc, i_loc = jax.lax.top_k(s3, k)
    i_loc = i_loc + (jnp.arange(W) * (V // W))[:, None, None]
    v_all = v_loc.transpose(1, 0, 2).reshape(rows, W * k)
    i_all = i_loc.transpose(1, 0, 2).reshape(rows, W * k)
    v, j = jax.lax.top_k(v_all, k)                        # tiny global pass
    return v, jnp.take_along_axis(i_all, j, axis=1)


def score_topk(cfg: Bert4RecConfig, params: Params, items, k: int = 100,
               chunk: int = 4096):
    """Offline bulk scoring: top-k items per row, batch processed in chunks
    so the [chunk, n_items] logits block — not [B, n_items] — is the peak
    intermediate.  items [B, S] with B % chunk == 0."""
    B, S = items.shape
    if B <= chunk:
        return _topk_scores(cfg, score_next(cfg, params, items), k)
    chunks = items.reshape(B // chunk, chunk, S)

    def one(ch):
        return _topk_scores(cfg, score_next(cfg, params, ch), k)

    vals, idx = jax.lax.map(one, chunks)
    return vals.reshape(B, k), idx.reshape(B, k)


def score_next(cfg: Bert4RecConfig, params: Params, items) -> jax.Array:
    """Serving: append MASK, score all items.  items [B, S] -> [B, n_items].
    Used by serve_p99 (B=512) and serve_bulk (B=262144)."""
    hidden = encode(cfg, params, items)
    last = hidden[:, -1, :]                                   # MASK position
    return last @ params["item_embed"].T


def score_candidates(cfg: Bert4RecConfig, params: Params, items,
                     candidates) -> jax.Array:
    """Retrieval: one query against a candidate set (batched dot, no loop).
    items [1, S]; candidates [n_cand] -> scores [n_cand]."""
    hidden = encode(cfg, params, items)
    q = hidden[:, -1, :]                                      # [1, d]
    cand_vecs = embedding_lookup(params["item_embed"], candidates)
    return (cand_vecs @ q[0]).astype(jnp.float32)
