from .common import GraphData, pad_graph, segment_mp, edge_softmax
from . import common, e3, egnn, equivariant, gat, sampler

__all__ = ["GraphData", "pad_graph", "segment_mp", "edge_softmax",
           "common", "e3", "egnn", "equivariant", "gat", "sampler"]
