"""GNN substrate: padded COO graphs + segment-op message passing.

JAX sparse is BCOO-only, so message passing is built on edge-index
gather -> ``jax.ops.segment_sum``/``segment_max`` scatter (this IS the
system's SpMM layer; the same segment machinery backs the paper engine's
frontier propagation and the recsys EmbeddingBag).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class GraphData:
    """Static-shape padded (batched) graph.

    Padding convention: pad edges point at node slot n_node-1 with
    edge_mask False; pad nodes have node_mask False.
    """
    senders: Any      # [E] int32
    receivers: Any    # [E] int32
    node_mask: Any    # [N] bool
    edge_mask: Any    # [E] bool
    graph_ids: Any    # [N] int32 (disjoint-union batching; 0 if single)
    n_graphs: int = 1


def segment_mp(messages, receivers, n_nodes: int, reduce: str = "sum"):
    """Aggregate edge messages onto receiver nodes."""
    if reduce == "sum":
        return jax.ops.segment_sum(messages, receivers, num_segments=n_nodes)
    if reduce == "max":
        return jax.ops.segment_max(messages, receivers, num_segments=n_nodes)
    if reduce == "mean":
        s = jax.ops.segment_sum(messages, receivers, num_segments=n_nodes)
        c = jax.ops.segment_sum(jnp.ones(messages.shape[0], jnp.float32),
                                receivers, num_segments=n_nodes)
        return s / jnp.maximum(c, 1.0)[:, None]
    raise ValueError(reduce)


def edge_softmax(scores, receivers, edge_mask, n_nodes: int):
    """Numerically-stable softmax over incoming edges of each node.
    scores [E, H] -> alpha [E, H]."""
    scores = jnp.where(edge_mask[:, None], scores, -jnp.inf)
    smax = jax.ops.segment_max(scores, receivers, num_segments=n_nodes)
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
    ex = jnp.exp(scores - smax[receivers]) * edge_mask[:, None]
    denom = jax.ops.segment_sum(ex, receivers, num_segments=n_nodes)
    return ex / jnp.maximum(denom[receivers], 1e-9)


def mlp_init(key, sizes, dtype=jnp.float32):
    ks = jax.random.split(key, len(sizes) - 1)
    return [dict(w=(jax.random.normal(k, (a, b)) / np.sqrt(a)).astype(dtype),
                 b=jnp.zeros((b,), dtype))
            for k, (a, b) in zip(ks, zip(sizes[:-1], sizes[1:]))]


def mlp_apply(layers, x, act=jax.nn.silu, final_act=False):
    for i, lyr in enumerate(layers):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


def graph_readout(node_vals, graph_ids, n_graphs: int, node_mask,
                  reduce: str = "sum"):
    """Pool node values per graph (molecule batching)."""
    vals = node_vals * node_mask[:, None]
    if reduce == "sum":
        return jax.ops.segment_sum(vals, graph_ids, num_segments=n_graphs)
    if reduce == "mean":
        s = jax.ops.segment_sum(vals, graph_ids, num_segments=n_graphs)
        c = jax.ops.segment_sum(node_mask.astype(jnp.float32), graph_ids,
                                num_segments=n_graphs)
        return s / jnp.maximum(c, 1.0)[:, None]
    raise ValueError(reduce)


def pad_graph(senders, receivers, n_nodes: int, e_max: int, n_max: int,
              graph_ids: Optional[np.ndarray] = None, n_graphs: int = 1):
    """Host-side padding to static shapes."""
    E = len(senders)
    assert E <= e_max and n_nodes <= n_max
    s = np.full(e_max, n_max - 1, np.int32)
    r = np.full(e_max, n_max - 1, np.int32)
    s[:E], r[:E] = senders, receivers
    node_mask = np.zeros(n_max, bool)
    node_mask[:n_nodes] = True
    edge_mask = np.zeros(e_max, bool)
    edge_mask[:E] = True
    gi = np.zeros(n_max, np.int32)
    if graph_ids is not None:
        gi[:n_nodes] = graph_ids
    return GraphData(jnp.asarray(s), jnp.asarray(r), jnp.asarray(node_mask),
                     jnp.asarray(edge_mask), jnp.asarray(gi), n_graphs)
