"""Minimal E(3)-equivariance library: real spherical harmonics (l <= 3),
Clebsch-Gordan coupling in the real basis, Bessel radial basis.

Self-contained (no e3nn): complex-basis CG from the Racah closed form,
transformed to the real SH basis with the standard unitary change of basis
(the (-1j)**l phase makes the real-basis coefficients real).  Correctness
is *property-tested*: contracting Y_l1(u) x Y_l2(u) through CG(l1,l2,l3)
must be collinear with Y_l3(u) for every direction u, and the full models
built on top are tested for rotation equivariance (tests/test_gnn.py).
"""
from __future__ import annotations

import functools
from math import factorial, sqrt

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# complex-basis (su2) Clebsch-Gordan, Racah closed form
# ---------------------------------------------------------------------------

def _su2_cg_coeff(j1, m1, j2, m2, j3, m3) -> float:
    if m3 != m1 + m2:
        return 0.0
    if not (abs(j1 - j2) <= j3 <= j1 + j2):
        return 0.0
    if abs(m1) > j1 or abs(m2) > j2 or abs(m3) > j3:
        return 0.0
    f = factorial
    pref = (2 * j3 + 1) * f(j1 + j2 - j3) * f(j1 - j2 + j3) * \
        f(-j1 + j2 + j3) / f(j1 + j2 + j3 + 1)
    pref *= f(j3 + m3) * f(j3 - m3) * f(j1 - m1) * f(j1 + m1) * \
        f(j2 - m2) * f(j2 + m2)
    s = 0.0
    for k in range(0, j1 + j2 - j3 + 1):
        denom_terms = [k, j1 + j2 - j3 - k, j1 - m1 - k, j2 + m2 - k,
                       j3 - j2 + m1 + k, j3 - j1 - m2 + k]
        if any(d < 0 for d in denom_terms):
            continue
        denom = 1
        for d in denom_terms:
            denom *= f(d)
        s += (-1) ** k / denom
    return sqrt(pref) * s


@functools.lru_cache(maxsize=None)
def su2_clebsch_gordan(l1: int, l2: int, l3: int) -> np.ndarray:
    C = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1))
    for m1 in range(-l1, l1 + 1):
        for m2 in range(-l2, l2 + 1):
            for m3 in range(-l3, l3 + 1):
                C[m1 + l1, m2 + l2, m3 + l3] = _su2_cg_coeff(
                    l1, m1, l2, m2, l3, m3)
    return C


@functools.lru_cache(maxsize=None)
def _real_to_complex(l: int) -> np.ndarray:
    """Unitary Q with v_complex = Q @ v_real (e3nn convention; the
    (-1j)**l global phase makes the real-basis CG real)."""
    q = np.zeros((2 * l + 1, 2 * l + 1), dtype=complex)
    for m in range(-l, 0):
        q[l + m, l + abs(m)] = 1 / sqrt(2)
        q[l + m, l - abs(m)] = -1j / sqrt(2)
    q[l, l] = 1.0
    for m in range(1, l + 1):
        q[l + m, l + abs(m)] = (-1) ** m / sqrt(2)
        q[l + m, l - abs(m)] = 1j * (-1) ** m / sqrt(2)
    return (-1j) ** l * q


@functools.lru_cache(maxsize=None)
def real_clebsch_gordan(l1: int, l2: int, l3: int) -> np.ndarray:
    """CG coupling tensor in the real SH basis, [2l1+1, 2l2+1, 2l3+1]."""
    C = su2_clebsch_gordan(l1, l2, l3).astype(complex)
    Q1, Q2, Q3 = _real_to_complex(l1), _real_to_complex(l2), _real_to_complex(l3)
    # real tensor: contract the complex CG with Q1, Q2 and conj(Q3)
    # (sum over the complex index of each factor)
    Cr = np.einsum("ai,bj,abc,ck->ijk", Q1, Q2, C, np.conj(Q3))
    assert np.abs(Cr.imag).max() < 1e-9, (l1, l2, l3, np.abs(Cr.imag).max())
    return np.ascontiguousarray(Cr.real)


# ---------------------------------------------------------------------------
# real spherical harmonics (component-normalized), l <= 3
# ---------------------------------------------------------------------------

def spherical_harmonics(vec: jax.Array, l_max: int, eps: float = 1e-9):
    """vec [..., 3] -> dict {l: [..., 2l+1]} of real SH of the direction.

    Normalization: Y_0 = 1; higher l carry the standard sqrt((2l+1))
    component normalization (constant factors are absorbed by the learned
    radial weights downstream, so only ratios matter).
    """
    # safe norm: sqrt(max(r2, eps^2)) has zero (not NaN) gradient at r=0 —
    # required because forces differentiate through here (grad-of-grad)
    r2 = jnp.sum(vec * vec, axis=-1, keepdims=True)
    r = jnp.sqrt(jnp.maximum(r2, eps * eps))
    u = vec / jnp.maximum(r, eps)
    # zero vectors have no direction: l >= 1 harmonics must vanish there
    # (self-loop / padding edges), else they inject a constant
    # non-transforming component that breaks equivariance.
    valid = (r > eps).astype(vec.dtype)
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    out = {0: jnp.ones(vec.shape[:-1] + (1,), vec.dtype)}
    if l_max >= 1:
        out[1] = jnp.stack([y, z, x], axis=-1) * sqrt(3.0) * valid
    if l_max >= 2:
        out[2] = jnp.stack([
            sqrt(15.0) * x * y,
            sqrt(15.0) * y * z,
            sqrt(5.0) / 2.0 * (3 * z * z - 1.0),
            sqrt(15.0) * x * z,
            sqrt(15.0) / 2.0 * (x * x - y * y),
        ], axis=-1) * valid
    if l_max >= 3:
        out[3] = jnp.stack([
            sqrt(35.0 / 8.0) * y * (3 * x * x - y * y),
            sqrt(105.0) * x * y * z,
            sqrt(21.0 / 8.0) * y * (5 * z * z - 1.0),
            sqrt(7.0) / 2.0 * z * (5 * z * z - 3.0),
            sqrt(21.0 / 8.0) * x * (5 * z * z - 1.0),
            sqrt(105.0) / 2.0 * z * (x * x - y * y),
            sqrt(35.0 / 8.0) * x * (x * x - 3 * y * y),
        ], axis=-1) * valid
    return out


# ---------------------------------------------------------------------------
# radial basis
# ---------------------------------------------------------------------------

def bessel_rbf(r: jax.Array, n_rbf: int, r_cut: float) -> jax.Array:
    """Sine-Bessel radial basis with smooth polynomial cutoff (NequIP)."""
    r = jnp.maximum(r, 1e-9)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    basis = jnp.sin(n * jnp.pi * r[..., None] / r_cut) / r[..., None]
    # p=6 polynomial cutoff envelope
    u = jnp.clip(r / r_cut, 0.0, 1.0)
    env = 1.0 - 10.0 * u**3 + 15.0 * u**4 - 6.0 * u**5
    return basis * env[..., None]


# ---------------------------------------------------------------------------
# irreps feature dict helpers: feats = {l: [N, C, 2l+1]}
# ---------------------------------------------------------------------------

def irreps_zeros(n: int, channels: int, l_max: int, dtype=jnp.float32):
    return {l: jnp.zeros((n, channels, 2 * l + 1), dtype)
            for l in range(l_max + 1)}


def tensor_product(a, b_sh, l_max: int, cg_tables=None):
    """Channel-wise tensor product of node irreps ``a`` {l1: [E, C, m1]}
    with edge SH ``b_sh`` {l2: [E, m2]} -> {l3: [E, C, P_l3, m3]} where
    P_l3 enumerates contributing (l1, l2) paths."""
    out = {l: [] for l in range(l_max + 1)}
    for l1, fa in a.items():
        for l2, fb in b_sh.items():
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                cg = jnp.asarray(real_clebsch_gordan(l1, l2, l3),
                                 dtype=fa.dtype)
                out[l3].append(jnp.einsum("eci,ej,ijk->eck", fa, fb, cg))
    return {l: jnp.stack(v, axis=2) for l, v in out.items() if v}


def self_tensor_product(a, b, l_max: int):
    """Channel-wise product of two irreps dicts {l: [N, C, m]} (MACE
    symmetric contractions) -> {l3: [N, C, P, m3]}."""
    out = {l: [] for l in range(l_max + 1)}
    for l1, fa in a.items():
        for l2, fb in b.items():
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                cg = jnp.asarray(real_clebsch_gordan(l1, l2, l3),
                                 dtype=fa.dtype)
                out[l3].append(jnp.einsum("nci,ncj,ijk->nck", fa, fb, cg))
    return {l: jnp.stack(v, axis=2) for l, v in out.items() if v}


def linear_mix(feats, weights):
    """Per-l channel mixing: feats {l: [N, C_in(, P), m]} with weights
    {l: [C_in*P, C_out]} -> {l: [N, C_out, m]}."""
    out = {}
    for l, f in feats.items():
        if f.ndim == 4:
            n, c, p, m = f.shape
            f = f.transpose(0, 3, 1, 2).reshape(n, m, c * p)
        else:
            n, c, m = f.shape
            f = f.transpose(0, 2, 1)
        out[l] = jnp.einsum("nmc,cd->ndm", f, weights[l])
    return out
