"""EGNN (arXiv:2102.09844): E(n)-equivariant message passing without
spherical harmonics — scalar-distance MLP messages + coordinate updates.

Assigned config: 4 layers, d_hidden 64.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import GraphData, graph_readout, mlp_apply, mlp_init, segment_mp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_in: int = 16
    dtype: Any = jnp.float32

    def n_params(self) -> int:
        d = self.d_hidden
        per = (2 * d + 1) * d + d * d + d * d + d + (2 * d) * d + d * d
        return self.d_in * d + self.n_layers * per + d


def init_params(cfg: EGNNConfig, key) -> Params:
    d = cfg.d_hidden
    ks = jax.random.split(key, cfg.n_layers + 2)

    def layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return dict(
            phi_e=mlp_init(k1, [2 * d + 1, d, d], cfg.dtype),
            phi_x=mlp_init(k2, [d, d, 1], cfg.dtype),
            phi_h=mlp_init(k3, [2 * d, d, d], cfg.dtype),
        )

    layers = jax.vmap(layer)(jax.random.split(ks[0], cfg.n_layers))
    return dict(
        embed=mlp_init(ks[1], [cfg.d_in, d], cfg.dtype),
        layers=layers,
        readout=mlp_init(ks[2], [d, d, 1], cfg.dtype),
    )


def _layer(p, h, x, g: GraphData):
    N = h.shape[0]
    src, dst = g.senders, g.receivers
    diff = x[src] - x[dst]                                   # [E, 3]
    d2 = jnp.sum(diff * diff, axis=-1, keepdims=True)        # [E, 1]
    m = mlp_apply(p["phi_e"], jnp.concatenate([h[src], h[dst], d2], -1),
                  final_act=True)                            # [E, d]
    m = m * g.edge_mask[:, None]
    # coordinate update (mean-normalized for stability)
    cw = mlp_apply(p["phi_x"], m)                            # [E, 1]
    xmsg = diff * cw * g.edge_mask[:, None]
    x = x + segment_mp(xmsg, dst, N, "mean")
    # feature update
    agg = segment_mp(m, dst, N)
    h = h + mlp_apply(p["phi_h"], jnp.concatenate([h, agg], -1))
    return h, x


def forward(cfg: EGNNConfig, params: Params, feats, coords,
            g: GraphData) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (per-graph energy [G], node features [N, d], coords [N, 3])."""
    h = mlp_apply(params["embed"], feats)

    def body(carry, p):
        h, x = carry
        h, x = _layer(p, h, x, g)
        return (h, x), None

    # unrolled (<=5 layers): keeps XLA cost_analysis exact for the dry-run
    (h, x), _ = jax.lax.scan(body, (h, coords), params["layers"],
                             unroll=cfg.n_layers)
    node_e = mlp_apply(params["readout"], h)                 # [N, 1]
    energy = graph_readout(node_e, g.graph_ids, g.n_graphs, g.node_mask)
    return energy[:, 0], h, x


def energy_and_forces(cfg: EGNNConfig, params: Params, feats, coords, g):
    def e_fn(c):
        return jnp.sum(forward(cfg, params, feats, c, g)[0])
    e, neg_f = jax.value_and_grad(e_fn)(coords)
    return e, -neg_f
