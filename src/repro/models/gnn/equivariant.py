"""NequIP (arXiv:2101.03164) and MACE (arXiv:2206.07697) on the e3 library.

Structurally faithful JAX implementations:
* NequIP: per-layer equivariant convolution — neighbor irreps (x) SH of the
  edge direction through CG paths, radial-MLP path weights, segment-sum
  aggregation, per-l self-interaction, gated nonlinearity.
* MACE: per-layer density A (one-hop conv), then *higher-order* symmetric
  tensor-power contractions B up to correlation order nu=3 (the paper's
  ACE-style product basis), linear message, residual update, per-layer
  scalar readouts summed into the site energy.

Uniform channel width per l keeps parameter bookkeeping simple (noted in
DESIGN.md); equivariance is property-tested in tests/test_gnn.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import GraphData, graph_readout, mlp_apply, mlp_init
from .e3 import (bessel_rbf, irreps_zeros, linear_mix, real_clebsch_gordan,
                 self_tensor_product, spherical_harmonics)

Params = Dict[str, Any]


def _paths(l_max: int) -> List[Tuple[int, int, int]]:
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                out.append((l1, l2, l3))
    return out


@dataclasses.dataclass(frozen=True)
class EquivariantConfig:
    name: str = "nequip"
    arch: str = "nequip"          # "nequip" | "mace"
    n_layers: int = 5
    channels: int = 32            # d_hidden
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    correlation: int = 3          # MACE only
    n_species: int = 8
    dtype: Any = jnp.float32
    # beyond-paper distributed optimization: one fused, bf16,
    # output-sharded aggregation per output l instead of 15 f32 per-path
    # segment_sums (each of which all-reduces a full node array).
    fused_agg: bool = False
    shard_axes: tuple = ()        # flat mesh axes carrying nodes/edges

    def n_params(self) -> int:
        C, P = self.channels, len(_paths(self.l_max))
        per_layer = P * self.n_rbf * C
        per_layer += (self.l_max + 1) * (C * P) * C          # mix
        per_layer += self.l_max * C * C + C * C              # gates
        if self.arch == "mace":
            per_layer += (self.correlation - 1) * (self.l_max + 1) * 4 * C * C
            per_layer += C * 1
        return self.n_species * C + self.n_layers * per_layer + C


def _conv_init(cfg: EquivariantConfig, key) -> Params:
    C = cfg.channels
    paths = _paths(cfg.l_max)
    ks = jax.random.split(key, len(paths) + cfg.l_max + 3)
    p: Params = {}
    for i, (l1, l2, l3) in enumerate(paths):
        p[f"rad_{l1}{l2}{l3}"] = (
            jax.random.normal(ks[i], (cfg.n_rbf, C)) / np.sqrt(cfg.n_rbf)
        ).astype(cfg.dtype)
    # per-l mixing weights: [C * n_paths_to_l, C]
    per_l = {l: sum(1 for (_, _, l3) in paths if l3 == l)
             for l in range(cfg.l_max + 1)}
    for l in range(cfg.l_max + 1):
        p[f"mix_{l}"] = (jax.random.normal(ks[len(paths) + l],
                                           (C * per_l[l], C)) /
                         np.sqrt(C * per_l[l])).astype(cfg.dtype)
    # gates for l > 0
    p["gate_w"] = (jax.random.normal(ks[-1], (C, cfg.l_max * C)) /
                   np.sqrt(C)).astype(cfg.dtype)
    return p


def _conv_apply(cfg: EquivariantConfig, p: Params, feats, coords,
                g: GraphData):
    """One equivariant convolution; returns aggregated {l: [N, C, m]}."""
    N = coords.shape[0]
    src, dst = g.senders, g.receivers
    vec = coords[src] - coords[dst]
    # safe norm (zero gradient at r=0; forces differentiate through this)
    r = jnp.sqrt(jnp.maximum(jnp.sum(vec * vec, axis=-1), 1e-18))
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff) * g.edge_mask[:, None]
    sh = spherical_harmonics(vec, cfg.l_max)

    if cfg.fused_agg:
        from ...launch.constraints import hint
        ax = cfg.shard_axes or None
        # pure-bf16 message path: mixed-precision einsums make XLA hoist
        # f32 converts ABOVE the node-array gathers, silently restoring
        # f32 all-gathers (§Perf iteration 2 lesson)
        bf = jnp.bfloat16
        sh_b = {l: v.astype(bf) for l, v in sh.items()}
        rbf_b = rbf.astype(bf)
        per_l = {l: [] for l in range(cfg.l_max + 1)}
        for (l1, l2, l3) in _paths(cfg.l_max):
            w = rbf_b @ p[f"rad_{l1}{l2}{l3}"].astype(bf)
            fa = feats[l1].astype(bf)[src]
            cg = jnp.asarray(real_clebsch_gordan(l1, l2, l3), bf)
            msg = jnp.einsum("eci,ej,ijk,ec->eck", fa, sh_b[l2], cg, w)
            per_l[l3].append(msg)
        stacked = {}
        for l3, msgs in per_l.items():
            cat = jnp.concatenate(msgs, axis=1)               # [E, P*C, m]
            if ax:
                cat = hint(cat, ax, None, None)
            agg = jax.ops.segment_sum(cat, dst, num_segments=N)
            if ax:
                agg = hint(agg, ax, None, None)               # node-sharded
            Pn = len(msgs)
            C = msgs[0].shape[1]
            # stay bf16: promoting here would re-widen every node-array
            # collective downstream (§Perf iteration 3)
            agg = agg.reshape(N, Pn, C, 2 * l3 + 1)
            stacked[l3] = jnp.transpose(agg, (0, 2, 1, 3))    # [N, C, P, m]
        return linear_mix(stacked, {l: p[f"mix_{l}"]
                                    for l in range(cfg.l_max + 1)})

    agg = {l: [] for l in range(cfg.l_max + 1)}
    for (l1, l2, l3) in _paths(cfg.l_max):
        w = rbf @ p[f"rad_{l1}{l2}{l3}"]                      # [E, C]
        fa = feats[l1][src]                                   # [E, C, m1]
        cg = jnp.asarray(real_clebsch_gordan(l1, l2, l3), cfg.dtype)
        msg = jnp.einsum("eci,ej,ijk,ec->eck", fa, sh[l2], cg, w)
        out = jax.ops.segment_sum(msg, dst, num_segments=N)
        agg[l3].append(out)
    stacked = {l: jnp.stack(v, axis=2) for l, v in agg.items()}  # [N,C,P,m]
    return linear_mix(stacked, {l: p[f"mix_{l}"]
                                for l in range(cfg.l_max + 1)})


def _gate(cfg: EquivariantConfig, p: Params, feats):
    """Equivariant gated nonlinearity: silu on scalars, sigmoid(scalar)
    gates on the norms of l>0 features."""
    scalars = feats[0][..., 0]                                # [N, C]
    out = {0: jax.nn.silu(scalars)[..., None]}
    if cfg.l_max > 0:
        gates = jax.nn.sigmoid(scalars @ p["gate_w"])         # [N, l_max*C]
        C = cfg.channels
        for l in range(1, cfg.l_max + 1):
            gl = gates[:, (l - 1) * C: l * C]
            out[l] = feats[l] * gl[..., None]
    return out


# ---------------------------------------------------------------------------

def init_params(cfg: EquivariantConfig, key) -> Params:
    C = cfg.channels
    ks = jax.random.split(key, cfg.n_layers + 3)
    layers = []
    for i in range(cfg.n_layers):
        lp = _conv_init(cfg, ks[i])
        if cfg.arch == "mace":
            kk = jax.random.split(ks[i], 2 * (cfg.correlation - 1) *
                                  (cfg.l_max + 1) + 2)
            j = 0
            for nu in range(2, cfg.correlation + 1):
                per_l = {l: 0 for l in range(cfg.l_max + 1)}
                for (l1, l2, l3) in _paths(cfg.l_max):
                    per_l[l3] += 1
                for l in range(cfg.l_max + 1):
                    lp[f"bmix_{nu}_{l}"] = (
                        jax.random.normal(kk[j], (C * per_l[l], C)) /
                        np.sqrt(C * per_l[l])).astype(cfg.dtype)
                    j += 1
            lp["readout"] = (jax.random.normal(kk[-1], (C, 1)) /
                             np.sqrt(C)).astype(cfg.dtype)
        layers.append(lp)
    p = dict(
        embed=(jax.random.normal(ks[-2], (cfg.n_species, C)) * 0.5
               ).astype(cfg.dtype),
        layers=layers,
        readout=mlp_init(ks[-1], [C, C, 1], cfg.dtype),
    )
    return p


def forward(cfg: EquivariantConfig, params: Params, species, coords,
            g: GraphData):
    """species [N] int32, coords [N, 3] -> per-graph energy [G]."""
    N = coords.shape[0]
    C = cfg.channels
    # fused/distributed mode carries features in bf16: gathers of the node
    # array and their backward all-reduces are the dominant collective
    # traffic at ogb_products scale (§Perf iteration 2) — halving the word
    # size halves it; the energy readout accumulates in f32.
    fdtype = jnp.bfloat16 if cfg.fused_agg else cfg.dtype
    feats = irreps_zeros(N, C, cfg.l_max, fdtype)
    # cast the (small) table BEFORE the take: converting the [N, C] node
    # array after the gather would leave f32 node traffic in the program
    feats[0] = jnp.take(params["embed"].astype(fdtype), species,
                        axis=0)[..., None]

    energy_acc = jnp.zeros((N, 1), cfg.dtype)
    for lp in params["layers"]:
        if cfg.fused_agg:
            # cast layer params (small) once: keeps every node-array op —
            # and hence every collective — bf16-pure
            lp = jax.tree.map(lambda x: x.astype(fdtype), lp)
        conv = _conv_apply(cfg, lp, feats, coords, g)
        if cfg.arch == "mace":
            # higher-order ACE product basis: B_nu = sym. powers of A
            A = conv
            B = A
            msg = {l: A[l] for l in range(cfg.l_max + 1)}
            for nu in range(2, cfg.correlation + 1):
                prod = self_tensor_product(B, A, cfg.l_max)   # [N,C,P,m]
                B = linear_mix(prod, {l: lp[f"bmix_{nu}_{l}"]
                                      for l in range(cfg.l_max + 1)})
                msg = {l: msg[l] + B[l] for l in msg}
            feats = {l: feats[l] + msg[l] for l in feats}
            feats = _gate(cfg, lp, feats)
            energy_acc = energy_acc + \
                (feats[0][..., 0].astype(cfg.dtype) @ lp["readout"])
        else:
            feats = {l: feats[l] + conv[l] for l in feats}
            feats = _gate(cfg, lp, feats)
        # keep the carried node arrays in the low-precision format
        feats = {l: v.astype(fdtype) for l, v in feats.items()}

    node_e = mlp_apply(params["readout"],
                       feats[0][..., 0].astype(cfg.dtype))    # [N, 1]
    node_e = node_e + energy_acc
    energy = graph_readout(node_e, g.graph_ids, g.n_graphs, g.node_mask)
    return energy[:, 0]


def energy_and_forces(cfg: EquivariantConfig, params: Params, species,
                      coords, g: GraphData):
    def e_fn(c):
        return jnp.sum(forward(cfg, params, species, c, g))
    e, neg_f = jax.value_and_grad(e_fn)(coords)
    return e, -neg_f
