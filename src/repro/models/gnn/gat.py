"""GAT (arXiv:1710.10903): SDDMM edge scores -> segment softmax -> SpMM.

gat-cora assigned config: 2 layers, d_hidden 8, 8 heads, attn aggregator.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .common import GraphData, edge_softmax, segment_mp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat-cora"
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 8
    n_heads: int = 8
    n_classes: int = 7
    dtype: Any = jnp.float32

    def n_params(self) -> int:
        p = self.d_in * self.d_hidden * self.n_heads + 2 * self.n_heads * self.d_hidden
        p += (self.d_hidden * self.n_heads) * self.n_classes * 1 + 2 * self.n_classes
        return p


def init_params(cfg: GATConfig, key) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    h, dh = cfg.n_heads, cfg.d_hidden
    return dict(
        w1=(jax.random.normal(k1, (cfg.d_in, h * dh)) / np.sqrt(cfg.d_in)
            ).astype(cfg.dtype),
        a1_src=(jax.random.normal(k2, (h, dh)) * 0.1).astype(cfg.dtype),
        a1_dst=(jax.random.normal(k3, (h, dh)) * 0.1).astype(cfg.dtype),
        w2=(jax.random.normal(k4, (h * dh, cfg.n_classes)) /
            np.sqrt(h * dh)).astype(cfg.dtype),
        a2_src=(jax.random.normal(k2, (1, cfg.n_classes)) * 0.1).astype(cfg.dtype),
        a2_dst=(jax.random.normal(k3, (1, cfg.n_classes)) * 0.1).astype(cfg.dtype),
    )


def _gat_layer(x, g: GraphData, w, a_src, a_dst, n_heads):
    """x [N, d_in] -> [N, H, dh]."""
    N = x.shape[0]
    h = (x @ w).reshape(N, n_heads, -1)                       # [N, H, dh]
    s_src = jnp.einsum("nhd,hd->nh", h, a_src)
    s_dst = jnp.einsum("nhd,hd->nh", h, a_dst)
    scores = jax.nn.leaky_relu(s_src[g.senders] + s_dst[g.receivers], 0.2)
    alpha = edge_softmax(scores, g.receivers, g.edge_mask, N)  # [E, H]
    msgs = h[g.senders] * alpha[..., None]
    return segment_mp(msgs.reshape(msgs.shape[0], -1), g.receivers, N
                      ).reshape(N, n_heads, -1)


def forward(cfg: GATConfig, params: Params, x, g: GraphData) -> jax.Array:
    """Node classification logits [N, n_classes]."""
    h = _gat_layer(x, g, params["w1"], params["a1_src"], params["a1_dst"],
                   cfg.n_heads)
    h = jax.nn.elu(h.reshape(x.shape[0], -1))
    out = _gat_layer(h, g, params["w2"], params["a2_src"], params["a2_dst"], 1)
    return out[:, 0, :]


def loss(cfg: GATConfig, params: Params, x, g: GraphData, labels,
         label_mask) -> jax.Array:
    logits = forward(cfg, params, x, g).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = (logz - gold) * label_mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(label_mask), 1.0)
