"""Neighbor sampling for minibatch GNN training (GraphSAGE-style fanout).

``minibatch_lg`` (Reddit-scale: 233k nodes / 115M edges, batch 1024,
fanout 15-10) needs a *real* sampler: the host path samples from CSR with
numpy (data pipeline), and a jit-safe device path draws fixed-fanout
neighbor indices with jax.random (padded with self-loops where the degree
is short — standard with-replacement fanout sampling).
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def sample_block_host(indptr: np.ndarray, indices: np.ndarray,
                      seeds: np.ndarray, fanout: int,
                      rng: np.random.Generator
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One fanout hop on the host: returns (senders, receivers, next_seeds).
    senders/receivers index into the *global* node id space; receivers are
    the seeds, senders the sampled neighbors (message direction src->dst).
    """
    s_list, r_list = [], []
    for v in seeds:
        lo, hi = indptr[v], indptr[v + 1]
        deg = hi - lo
        if deg == 0:
            nbrs = np.full(fanout, v)
        else:
            nbrs = indices[lo + rng.integers(0, deg, fanout)]
        s_list.append(nbrs)
        r_list.append(np.full(fanout, v))
    senders = np.concatenate(s_list)
    receivers = np.concatenate(r_list)
    next_seeds = np.unique(np.concatenate([seeds, senders]))
    return senders, receivers, next_seeds


def sample_subgraph_host(indptr, indices, seeds, fanouts: List[int],
                         seed: int = 0):
    """Multi-hop sampled subgraph (outermost hop first, GraphSAGE order).
    Returns (node_ids, senders_local, receivers_local) with local
    renumbering; seeds occupy the first len(seeds) slots."""
    rng = np.random.default_rng(seed)
    all_s, all_r = [], []
    frontier = np.asarray(seeds)
    keep = [np.asarray(seeds)]
    for f in fanouts:
        s, r, frontier = sample_block_host(indptr, indices, frontier, f, rng)
        all_s.append(s)
        all_r.append(r)
        keep.append(frontier)
    node_ids, inv = np.unique(np.concatenate(
        [np.asarray(seeds)] + [np.concatenate(all_s)]), return_inverse=False), None
    node_ids = np.unique(np.concatenate([np.asarray(seeds),
                                         np.concatenate(all_s),
                                         np.concatenate(all_r)]))
    # seeds first
    seed_set = set(np.asarray(seeds).tolist())
    rest = np.array([v for v in node_ids if v not in seed_set])
    node_ids = np.concatenate([np.asarray(seeds), rest]).astype(np.int64)
    g2l = {int(v): i for i, v in enumerate(node_ids)}
    senders = np.array([g2l[int(v)] for v in np.concatenate(all_s)], np.int32)
    receivers = np.array([g2l[int(v)] for v in np.concatenate(all_r)], np.int32)
    return node_ids, senders, receivers


def sample_fanout_device(key, indptr, indices, seeds, fanout: int):
    """jit-safe single-hop fanout sampling (with replacement, padded CSR).

    indptr [N+1], indices [E] int32; seeds [B] -> (senders [B*fanout],
    receivers [B*fanout]).  Zero-degree seeds fall back to self-loops.
    """
    lo = indptr[seeds]
    deg = indptr[seeds + 1] - lo
    u = jax.random.randint(key, (seeds.shape[0], fanout), 0, 1 << 30)
    off = jnp.where(deg[:, None] > 0, u % jnp.maximum(deg[:, None], 1), 0)
    nbr = indices[(lo[:, None] + off).reshape(-1)]
    senders = jnp.where(jnp.repeat(deg, fanout) > 0, nbr,
                        jnp.repeat(seeds, fanout))
    receivers = jnp.repeat(seeds, fanout)
    return senders.astype(jnp.int32), receivers.astype(jnp.int32)
