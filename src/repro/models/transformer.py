"""Decoder-only LM family: dense + MoE, GQA, RoPE (incl. partial/2d),
QKV bias, sliding-window attention, SwiGLU — pure JAX, scan-over-layers
with remat, KV-cache prefill/decode.

One parameterized implementation covers the five assigned LM architectures
(olmoe-1b-7b, mixtral-8x7b, qwen1.5-32b, qwen2-1.5b, chatglm3-6b); see
``repro/configs/``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 32
    d_ff: int = 256
    vocab: int = 256
    qkv_bias: bool = False
    rope_pct: float = 1.0          # chatglm3 uses 0.5 ("2d" rotary)
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # mixtral SWA
    # MoE (dense model when n_experts == 0)
    n_experts: int = 0
    top_k: int = 2
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    tie_embeddings: bool = True
    dtype: Any = jnp.float32       # activation/param dtype (bf16 on TPU)
    remat: bool = True
    # serving-path options (production features for the decode_* cells):
    kv_quant_int8: bool = False    # int8 KV cache + per-(slot,head) scales
    decode_chunk: Optional[int] = None  # online-softmax chunked cache attn
    # blockwise (flash-style, pure-XLA) attention for long prefill/train;
    # only causal (i, j<=i) — and, with SWA, in-window — block pairs are
    # materialized, so memory is O(chunk^2) and FLOPs skip masked blocks.
    attn_chunk: Optional[int] = None
    # fully unroll internal scans (dry-run cost probes: XLA cost_analysis
    # counts loop bodies once, so probes lower loop-free programs)
    unroll: bool = False
    # beyond-paper distribution hints: pin q/k/v + attention carries to
    # batch-sharded/model-replicated layouts.  With few KV heads (GQA 2)
    # GSPMD otherwise invents head/sequence shardings whose dynamic slices
    # trigger "involuntary full rematerialization" copies of whole caches.
    dp_axes: tuple = ()           # mesh axes carrying the batch dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def n_params(self) -> int:
        """Total parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, dh = self.d_model, self.d_head
        attn = d * dh * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.is_moe:
            mlp = self.n_experts * 3 * d * self.d_ff_expert + d * self.n_experts
        else:
            mlp = 3 * d * self.d_ff
        per_layer = attn + mlp + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def n_active_params(self) -> int:
        """Activated params per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        attn = d * self.d_head * (self.n_heads * 2 + self.n_kv_heads * 2)
        mlp = self.top_k * 3 * d * self.d_ff_expert + d * self.n_experts
        per_layer = attn + mlp + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale=None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(shape[0]))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_layer(cfg: LMConfig, key) -> Params:
    d, dh = cfg.d_model, cfg.d_head
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 12)
    p = dict(
        ln1=jnp.ones((d,), cfg.dtype),
        ln2=jnp.ones((d,), cfg.dtype),
        wq=_dense_init(ks[0], (d, hq * dh), cfg.dtype),
        wk=_dense_init(ks[1], (d, hkv * dh), cfg.dtype),
        wv=_dense_init(ks[2], (d, hkv * dh), cfg.dtype),
        wo=_dense_init(ks[3], (hq * dh, d), cfg.dtype),
    )
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), cfg.dtype)
        p["bk"] = jnp.zeros((hkv * dh,), cfg.dtype)
        p["bv"] = jnp.zeros((hkv * dh,), cfg.dtype)
    if cfg.is_moe:
        e, ffe = cfg.n_experts, cfg.d_ff_expert
        p["router"] = _dense_init(ks[4], (d, e), cfg.dtype)
        p["w1"] = _dense_init(ks[5], (e, d, ffe), cfg.dtype)
        p["w3"] = _dense_init(ks[6], (e, d, ffe), cfg.dtype)
        p["w2"] = _dense_init(ks[7], (e, ffe, d), cfg.dtype)
    else:
        p["w1"] = _dense_init(ks[5], (d, cfg.d_ff), cfg.dtype)
        p["w3"] = _dense_init(ks[6], (d, cfg.d_ff), cfg.dtype)
        p["w2"] = _dense_init(ks[7], (cfg.d_ff, d), cfg.dtype)
    return p


def init_params(cfg: LMConfig, key) -> Params:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(cfg, k))(layer_keys)
    p = dict(
        embed=_dense_init(k_emb, (cfg.vocab, cfg.d_model), cfg.dtype, 0.02),
        ln_f=jnp.ones((cfg.d_model,), cfg.dtype),
        layers=layers,
    )
    if not cfg.tie_embeddings:
        p["lm_head"] = _dense_init(k_head, (cfg.d_model, cfg.vocab), cfg.dtype)
    return p


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope(x, positions, cfg: LMConfig):
    """Rotary embedding on the leading rope_pct fraction of head dims.

    x: [..., S, H, dh]; positions: [..., S] absolute positions.
    rope_pct=0.5 reproduces chatglm3's 2d/partial rotary.
    """
    dh = x.shape[-1]
    rot = int(dh * cfg.rope_pct)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)        # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    x_rot = jnp.concatenate([x1 * cos - x2 * sin,
                             x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([x_rot, x_pass], axis=-1)


def _qkv(cfg: LMConfig, p: Params, x):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.d_head)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    return q, k, v


def _blockwise_attention(cfg: LMConfig, q, k, v):
    """Flash-style causal attention in pure XLA (lax.scan over the static
    list of live (q-block, kv-block) pairs with an online softmax).

    q [B, S, Hkv, G, dh]; k, v [B, S, Hkv, dh].  Positions are arange(S).
    Only blocks with j <= i (causal) and, under SWA, (i-j)*C < window + C
    are computed: long-context FLOPs/memory scale with the *visible* window,
    not S^2.
    """
    B, S, H, G, dh = q.shape
    C = cfg.attn_chunk
    assert S % C == 0, (S, C)
    n = S // C
    pairs = [(i, j) for i in range(n) for j in range(i + 1)
             if cfg.sliding_window is None
             or (i - j) * C < cfg.sliding_window + C]
    pi = jnp.asarray([p[0] for p in pairs], jnp.int32)
    pj = jnp.asarray([p[1] for p in pairs], jnp.int32)

    qc = q.reshape(B, n, C, H, G, dh)
    kc = k.reshape(B, n, C, H, dh)
    vc = v.reshape(B, n, C, H, dh)
    inv_sqrt = 1.0 / jnp.sqrt(jnp.array(dh, jnp.float32))

    def step(state, ij):
        m, l, acc = state      # [n,B,H,G,C], [n,B,H,G,C], [n,B,H,G,C,dh]
        i, j = ij
        qb = jax.lax.dynamic_index_in_dim(qc, i, 1, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
        if cfg.dp_axes:   # keep blocks batch-sharded; stop GSPMD resharding
            qb, kb, vb = (_dp_hint(cfg, t) for t in (qb, kb, vb))
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32),
                       kb.astype(jnp.float32)) * inv_sqrt
        qpos = i * C + jnp.arange(C)
        kpos = j * C + jnp.arange(C)
        mask = kpos[None, :] <= qpos[:, None]
        if cfg.sliding_window is not None:
            mask &= (qpos[:, None] - kpos[None, :]) < cfg.sliding_window
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        mi = m[i]
        m_new = jnp.maximum(mi, jnp.max(s, axis=-1))          # [B,H,G,C]
        corr = jnp.where(jnp.isfinite(mi), jnp.exp(mi - m_new), 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_new[..., None]), 0.0)
        l_new = l[i] * corr + jnp.sum(p, axis=-1)
        acc_new = acc[i] * corr[..., None] + \
            jnp.einsum("bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
        return (m.at[i].set(m_new), l.at[i].set(l_new),
                acc.at[i].set(acc_new)), None

    m0 = jnp.full((n, B, H, G, C), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((n, B, H, G, C), jnp.float32)
    a0 = jnp.zeros((n, B, H, G, C, dh), jnp.float32)
    if cfg.dp_axes:
        from ..launch.constraints import hint
        m0 = hint(m0, None, cfg.dp_axes, None, None, None)
        l0 = hint(l0, None, cfg.dp_axes, None, None, None)
        a0 = hint(a0, None, cfg.dp_axes, None, None, None, None)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (pi, pj),
                                  unroll=len(pairs) if cfg.unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]              # [n,B,H,G,C,dh]
    out = jnp.moveaxis(out, 0, 1)                             # [B,n,H,G,C,dh]
    out = jnp.transpose(out, (0, 1, 4, 2, 3, 5))              # [B,n,C,H,G,dh]
    return out.reshape(B, S, H * G * dh).astype(q.dtype)


def _dp_hint(cfg: LMConfig, x, lead_batch: bool = True):
    """Constrain: batch dim -> dp axes, everything else replicated."""
    if not cfg.dp_axes:
        return x
    from ..launch.constraints import hint
    spec = (cfg.dp_axes,) + (None,) * (x.ndim - 1)
    return hint(x, *spec)


def attention(cfg: LMConfig, p: Params, x, positions):
    """Full (optionally sliding-window) causal self-attention, GQA."""
    B, S, _ = x.shape
    g = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(cfg, p, x)
    q = rope(q, positions, cfg)
    k = rope(k, positions, cfg)
    q, k, v = _dp_hint(cfg, q), _dp_hint(cfg, k), _dp_hint(cfg, v)
    if cfg.attn_chunk is not None and S > cfg.attn_chunk:
        q = q.reshape(B, S, cfg.n_kv_heads, g, cfg.d_head)
        return _blockwise_attention(cfg, q, k, v) @ p["wo"]
    q = q.reshape(B, S, cfg.n_kv_heads, g, cfg.d_head)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k) / jnp.sqrt(
        jnp.array(cfg.d_head, jnp.float32)).astype(x.dtype)
    ti = positions[:, None, :]   # key positions   [B, 1, S]
    si = positions[:, :, None]   # query positions [B, S, 1]
    mask = ti <= si
    if cfg.sliding_window is not None:
        mask &= (si - ti) < cfg.sliding_window
    scores = jnp.where(mask[:, None, None, :, :], scores.astype(jnp.float32),
                       -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    out = out.reshape(B, S, cfg.n_heads * cfg.d_head)
    return out @ p["wo"]


def swiglu(p, x):
    return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]


def moe_block(cfg: LMConfig, p: Params, x) -> Tuple[jax.Array, jax.Array]:
    """Capacity-bucketed top-k MoE with scatter/gather dispatch.

    Returns (output, aux_load_balance_loss).  The classic GShard one-hot
    dispatch materializes a [T, k, E, C] tensor — quadratic in tokens
    (C ~ T/E), ~20 GB/device at olmoe's train shape — so routing here is
    index-based: scatter token ids into the [E, C] capacity grid, gather
    rows, run experts, gather results back.  All intermediates are linear
    in T.  With experts sharded on "model" the gathers become the EP
    collectives.
    """
    B, S, d = x.shape
    T = B * S
    e, k = cfg.n_experts, cfg.top_k
    cap = int(max(1, (k * T * cfg.capacity_factor) // e))
    xt = x.reshape(T, d)
    logits = (xt @ p["router"]).astype(jnp.float32)          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                 # [T, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    flat_e = idx.reshape(-1)                                 # [T*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.float32)    # [T*k, E]
    # arrival order within each expert = position in its capacity buffer
    pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1.0
    pos = pos.astype(jnp.int32)                              # [T*k]
    keep = pos < cap

    # scatter kept (token, choice) pairs into the [E, C] grid (drop = OOB)
    tok_ids = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    token_idx = jnp.full((e, cap), T, jnp.int32)             # T = pad row
    token_idx = token_idx.at[flat_e, pos].set(tok_ids, mode="drop")

    x_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)])
    expert_in = jnp.take(x_pad, token_idx, axis=0)           # [E, C, d]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["w1"]))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, p["w3"])
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w2"])      # [E, C, d]

    # combine: each (token, choice) reads its expert row back
    pos_c = jnp.minimum(pos, cap - 1)
    vals = expert_out[flat_e, pos_c]                         # [T*k, d]
    vals = vals * keep[:, None].astype(vals.dtype)
    y = jnp.sum(vals.reshape(T, k, d) *
                gate_vals[..., None].astype(vals.dtype), axis=1)

    # load-balancing aux loss (Switch/GShard)
    frac_tokens = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32),
                           axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = jnp.sum(frac_tokens * frac_probs) * e
    return y.reshape(B, S, d), aux


def block(cfg: LMConfig, p: Params, x, positions):
    h = x + attention(cfg, p, rms_norm(x, p["ln1"]), positions)
    if cfg.is_moe:
        y, aux = moe_block(cfg, p, rms_norm(h, p["ln2"]))
    else:
        y, aux = swiglu(p, rms_norm(h, p["ln2"])), jnp.float32(0)
    return h + y, aux


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def forward(cfg: LMConfig, params: Params, tokens) -> Tuple[jax.Array, jax.Array]:
    """tokens [B, S] -> (logits [B, S, V], aux_loss)."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def layer_fn(carry, layer_params):
        x, aux = carry
        x, a = block(cfg, layer_params, x, positions)
        return (x, aux + a), None

    layer_fn_ = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
    (x, aux), _ = jax.lax.scan(layer_fn_, (x, jnp.float32(0)),
                               params["layers"],
                               unroll=cfg.n_layers if cfg.unroll else 1)
    x = rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, aux


def lm_loss(cfg: LMConfig, params: Params, tokens, targets,
            aux_weight: float = 0.01):
    logits, aux = forward(cfg, params, tokens)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + aux_weight * aux


# ---------------------------------------------------------------------------
# KV-cache serving
# ---------------------------------------------------------------------------

def cache_len(cfg: LMConfig, max_len: int) -> int:
    """Ring-buffer length: SWA models only ever need `window` entries."""
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_len)
    return max_len


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> Params:
    L = cache_len(cfg, max_len)
    shape = (cfg.n_layers, batch, L, cfg.n_kv_heads, cfg.d_head)
    cache = dict(pos=jnp.full((cfg.n_layers, batch, L), -1, jnp.int32))
    if cfg.kv_quant_int8:
        cache["k"] = jnp.zeros(shape, jnp.int8)
        cache["v"] = jnp.zeros(shape, jnp.int8)
        cache["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
        cache["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)
    else:
        cache["k"] = jnp.zeros(shape, cfg.dtype)
        cache["v"] = jnp.zeros(shape, cfg.dtype)
    return cache


def _quantize_kv(x):
    """x [..., dh] -> (int8 values, per-vector f32 scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _cache_attention(cfg: LMConfig, q, k_cache, v_cache, pos_cache, pos,
                     k_scale=None, v_scale=None):
    """Attention of one query token against the (ring) cache.

    q [B, Hkv, G, dh]; caches [B, T, Hkv, dh].  Two paths:
      * dense: one einsum over the full cache;
      * chunked (cfg.decode_chunk): lax.scan over cache chunks with an
        online softmax — peak memory O(chunk) instead of O(T), and int8
        chunks are dequantized per-chunk (the KV-quant + paging pattern;
        needed for 32k/500k-token caches, see DESIGN.md).
    """
    B, T = k_cache.shape[0], k_cache.shape[1]
    inv_sqrt = 1.0 / jnp.sqrt(jnp.array(cfg.d_head, jnp.float32))

    def score_block(kc, vc, pc, ks, vs):
        k = kc.astype(jnp.float32)
        v = vc.astype(jnp.float32)
        if ks is not None:
            k = k * ks[..., None]
            v = v * vs[..., None]
        s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32), k) * inv_sqrt
        valid = (pc >= 0) & (pc <= pos[:, None])
        if cfg.sliding_window is not None:
            valid &= (pos[:, None] - pc) < cfg.sliding_window
        s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
        return s, v

    if cfg.decode_chunk is None or cfg.decode_chunk >= T:
        s, v = score_block(k_cache, v_cache, pos_cache, k_scale, v_scale)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgt,btkd->bkgd", p, v)
        return out.astype(cfg.dtype)

    C = cfg.decode_chunk
    assert T % C == 0, (T, C)
    n_chunks = T // C
    H, G, dh = q.shape[1], q.shape[2], q.shape[3]

    def chunk(carry, idx):
        m, l, acc = carry
        ks = None if k_scale is None else \
            jax.lax.dynamic_slice_in_dim(k_scale, idx * C, C, axis=1)
        vs = None if v_scale is None else \
            jax.lax.dynamic_slice_in_dim(v_scale, idx * C, C, axis=1)
        kc = jax.lax.dynamic_slice_in_dim(k_cache, idx * C, C, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v_cache, idx * C, C, axis=1)
        pc = jax.lax.dynamic_slice_in_dim(pos_cache, idx * C, C, axis=1)
        s, v = score_block(kc, vc, pc, ks, vs)               # [B,K,G,C]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # all-masked chunks keep m == -inf; guard the exp's against nan
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_new[..., None]), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bkgt,btkd->bkgd", p, v)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, G), jnp.float32)
    a0 = jnp.zeros((B, H, G, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(chunk, (m0, l0, a0),
                                  jnp.arange(n_chunks),
                                  unroll=n_chunks if cfg.unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(cfg.dtype)


def decode_step(cfg: LMConfig, params: Params, cache: Params, token,
                pos) -> Tuple[jax.Array, Params]:
    """One decoding step: token [B], pos [B] -> (logits [B, V], new cache).

    The cache is a ring buffer of length cache_len (== window for SWA
    models — this is what makes mixtral's 500k-token decode O(window));
    absolute positions ride along for masking + RoPE correctness.
    """
    B = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0)      # [B, 1, d]
    slot = jnp.mod(pos, cache["k"].shape[2])                    # ring index
    quant = cfg.kv_quant_int8

    def layer_fn(carry, inputs):
        x, li = carry
        if quant:
            (layer_params, k_cache, v_cache, pos_cache,
             k_scale, v_scale) = inputs
        else:
            layer_params, k_cache, v_cache, pos_cache = inputs
            k_scale = v_scale = None
        h = rms_norm(x, layer_params["ln1"])
        q, knew, vnew = _qkv(cfg, layer_params, h)
        q = rope(q, pos[:, None], cfg)
        knew = rope(knew, pos[:, None], cfg)
        bidx = jnp.arange(B)
        if quant:
            kq, ks = _quantize_kv(knew[:, 0])
            vq, vs = _quantize_kv(vnew[:, 0])
            k_cache = k_cache.at[bidx, slot].set(kq)
            v_cache = v_cache.at[bidx, slot].set(vq)
            k_scale = k_scale.at[bidx, slot].set(ks)
            v_scale = v_scale.at[bidx, slot].set(vs)
        else:
            k_cache = k_cache.at[bidx, slot].set(knew[:, 0])
            v_cache = v_cache.at[bidx, slot].set(vnew[:, 0])
        pos_cache = pos_cache.at[bidx, slot].set(pos)
        g = cfg.n_heads // cfg.n_kv_heads
        qh = q.reshape(B, cfg.n_kv_heads, g, cfg.d_head)
        out = _cache_attention(cfg, qh, k_cache, v_cache, pos_cache, pos,
                               k_scale, v_scale)
        out = out.reshape(B, 1, cfg.n_heads * cfg.d_head) @ layer_params["wo"]
        h2 = x + out
        if cfg.is_moe:
            y, _ = moe_block(cfg, layer_params, rms_norm(h2, layer_params["ln2"]))
        else:
            y = swiglu(layer_params, rms_norm(h2, layer_params["ln2"]))
        outs = (k_cache, v_cache, pos_cache) + \
            ((k_scale, v_scale) if quant else ())
        return (h2 + y, li + 1), outs

    ins = (params["layers"], cache["k"], cache["v"], cache["pos"]) + \
        ((cache["k_scale"], cache["v_scale"]) if quant else ())
    (x, _), outs = jax.lax.scan(layer_fn, (x, 0), ins,
                                unroll=cfg.n_layers if cfg.unroll else 1)
    new_cache = dict(k=outs[0], v=outs[1], pos=outs[2])
    if quant:
        new_cache["k_scale"], new_cache["v_scale"] = outs[3], outs[4]
    x = rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head)[:, 0, :]
    return logits, new_cache


def prefill(cfg: LMConfig, params: Params, tokens, max_len: int):
    """Prefill: full forward + cache construction for subsequent decode."""
    B, S = tokens.shape
    L = cache_len(cfg, max_len)
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def layer_fn(x, layer_params):
        h = rms_norm(x, layer_params["ln1"])
        q, k, v = _qkv(cfg, layer_params, h)
        del q
        # recompute attention via the shared block for the hidden states
        x2, _ = block(cfg, layer_params, x, positions)
        k = rope(k, positions, cfg)
        # keep the last L positions in the ring buffer layout
        keep = min(L, S)
        slot = jnp.mod(positions[:, -keep:], L)
        k_cache = jnp.zeros((B, L, cfg.n_kv_heads, cfg.d_head), cfg.dtype)
        v_cache = jnp.zeros((B, L, cfg.n_kv_heads, cfg.d_head), cfg.dtype)
        pos_cache = jnp.full((B, L), -1, jnp.int32)
        bidx = jnp.arange(B)[:, None]
        k_cache = k_cache.at[bidx, slot].set(k[:, -keep:])
        v_cache = v_cache.at[bidx, slot].set(v[:, -keep:])
        pos_cache = pos_cache.at[bidx, slot].set(positions[:, -keep:])
        return x2, (k_cache, v_cache, pos_cache)

    x, (kc, vc, pc) = jax.lax.scan(layer_fn, x, params["layers"])
    x = rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, dict(k=kc, v=vc, pos=pc)
