from .adamw import (AdamWConfig, AdamWState, clip_by_global_norm,
                    global_norm, init, schedule, update)

__all__ = ["AdamWConfig", "AdamWState", "clip_by_global_norm", "global_norm",
           "init", "schedule", "update"]
