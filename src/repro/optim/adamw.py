"""AdamW + global-norm clipping + warmup-cosine schedule (pure JAX pytrees).
No optax in this environment — the optimizer is part of the substrate."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), n


def init(params) -> AdamWState:
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(params),
                      v=zeros(params))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_p = jax.tree.leaves(params)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = dict(grad_norm=gnorm, lr=lr)
    return new_p, AdamWState(step, new_m, new_v), metrics
