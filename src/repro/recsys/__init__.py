from .embedding import embedding_bag, embedding_lookup, onehot_lookup

__all__ = ["embedding_bag", "embedding_lookup", "onehot_lookup"]
