"""Embedding substrate for recsys: JAX has no native EmbeddingBag or
CSR sparse — the lookup path is built here from ``jnp.take`` +
``jax.ops.segment_sum`` (the assignment calls this out as part of the
system, not a stub).

Row-sharded tables: with the table's row axis sharded on the "model" mesh
axis, ``jnp.take`` lowers to a gather + collective; the dry-run path keeps
the lookup einsum-free so XLA chooses the collective schedule.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Plain lookup: table [V, d], ids [...] -> [..., d]."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(table: jax.Array, ids: jax.Array, offsets: jax.Array,
                  n_bags: int, mode: str = "sum",
                  weights: Optional[jax.Array] = None) -> jax.Array:
    """EmbeddingBag(sum|mean|max) over ragged bags.

    ids [nnz] flat indices; offsets [nnz] bag id per index (segment ids);
    returns [n_bags, d].  Matches torch.nn.EmbeddingBag semantics with
    per-sample weights.
    """
    vecs = jnp.take(table, ids, axis=0)                  # [nnz, d]
    if weights is not None:
        vecs = vecs * weights[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(vecs, offsets, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(vecs, offsets, num_segments=n_bags)
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, jnp.float32), offsets,
                                  num_segments=n_bags)
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(vecs, offsets, num_segments=n_bags)
    raise ValueError(mode)


def onehot_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Gather-free lookup: onehot(ids) @ table.

    Used on the sharded dry-run path when the table's rows live on the
    "model" axis: the one-hot matmul turns the lookup into an MXU-friendly
    partial-sum + all-reduce instead of a ragged cross-device gather.
    """
    oh = jax.nn.one_hot(ids, table.shape[0], dtype=table.dtype)
    return oh @ table
