from .engine import Request, ServeEngine
from .query_server import QueryRequest, QueryServer

__all__ = ["Request", "ServeEngine", "QueryRequest", "QueryServer"]
