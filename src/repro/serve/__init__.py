from .engine import Request, ServeEngine
from .query_server import QueryRequest, QueryServer, UpdateRequest

__all__ = ["Request", "ServeEngine", "QueryRequest", "QueryServer",
           "UpdateRequest"]
