from ..errors import (DeadLetterError, DeadlineExceeded, DeltaApplyFailed,
                      InjectedFault, QueryTooExpensive, ServingError)
from .admission import (GREEN, LANES, RED, YELLOW, AdmissionPolicy,
                        estimate_cost)
from .engine import Request, ServeEngine
from .faults import SITES, FaultInjector, FaultSpec
from .query_server import (QueryRequest, QueryServer, RetryPolicy,
                           UpdateRequest)

__all__ = ["Request", "ServeEngine", "QueryRequest", "QueryServer",
           "UpdateRequest", "RetryPolicy",
           "AdmissionPolicy", "estimate_cost",
           "GREEN", "YELLOW", "RED", "LANES",
           "FaultInjector", "FaultSpec", "SITES",
           "ServingError", "QueryTooExpensive", "DeadlineExceeded",
           "DeadLetterError", "DeltaApplyFailed", "InjectedFault"]
