"""Serving layer: continuous-batching futures-based query serving over a
shared :class:`~repro.core.session.QuerySession` (DESIGN.md Secs. 7–8).

Public surface (everything here is re-exported at this level):

* :class:`QueryServer` — intake + lifecycle: validates/admits requests,
  returns futures, owns the scheduler thread (``start=True``) or the
  deterministic deferred mode (``start=False`` + ``flush()``).
* :class:`AsyncQueryEngine` — the continuous-batching scheduler itself:
  segments fenced by delta barriers, GREEN-before-YELLOW lanes, partial
  buckets shipped on deadline pressure or ``batch_wait`` expiry, PR-7
  retry/bisect/dead-letter execution.
* :class:`QueryFuture` / :class:`UpdateFuture` — awaitable handles
  (``.result(timeout=)``, ``.done()``, ``.status``, non-blocking
  ``.value``).  ``QueryRequest`` / ``UpdateRequest`` are their PR-7
  names, kept as aliases.
* :class:`Status` — the one lifecycle enum (str-valued: ``"done"``,
  ``"dead_letter"``, ``"deadline"``, ``"applied"``, ``"failed"``, ...)
  shared with session results and the error taxonomy.
* :class:`RetryPolicy` — capped exponential backoff for transient
  serving failures.
* :class:`Version` / :class:`VersionedCacheStore` — the MVCC snapshot
  store behind ``QueryServer(..., mvcc=True)``: immutable copy-on-write
  versions, pinned readers, concurrent repair, rollback-as-drop
  (:mod:`repro.core.versions`, DESIGN.md Sec. 9).
* :class:`Telemetry` — sliding-window p50/p95/p99 per route, qps, batch
  occupancy, lane depths (``QueryServer.telemetry()`` snapshots it).
* :class:`AdmissionPolicy` / :func:`estimate_cost` and the lane
  constants ``GREEN`` / ``YELLOW`` / ``RED`` / ``LANES`` — cost-based
  admission control.
* :class:`FaultInjector` / :class:`FaultSpec` / ``SITES`` — seeded fault
  injection for chaos tests and benchmarks.
* the typed error taxonomy (:class:`ServingError` and subclasses).
* :class:`Request` / :class:`ServeEngine` — the unrelated toy LM decode
  loop (:mod:`repro.serve.lm`), kept at its historical import path.
"""
from ..core.versions import Version, VersionedCacheStore
from ..errors import (DeadLetterError, DeadlineExceeded, DeltaApplyFailed,
                      InjectedFault, QueryTooExpensive, ServingError,
                      Status)
from .admission import (GREEN, LANES, RED, YELLOW, AdmissionPolicy,
                        estimate_cost)
from .engine import (AsyncQueryEngine, QueryFuture, RetryPolicy,
                     UpdateFuture)
from .faults import SITES, FaultInjector, FaultSpec
from .lm import Request, ServeEngine
from .query_server import (QueryRequest, QueryServer, UpdateRequest,
                           VALID_KINDS)
from .telemetry import Telemetry

__all__ = [
    "QueryServer", "AsyncQueryEngine",
    "QueryFuture", "UpdateFuture", "QueryRequest", "UpdateRequest",
    "Status", "RetryPolicy", "Telemetry", "VALID_KINDS",
    "Version", "VersionedCacheStore",
    "AdmissionPolicy", "estimate_cost",
    "GREEN", "YELLOW", "RED", "LANES",
    "FaultInjector", "FaultSpec", "SITES",
    "ServingError", "QueryTooExpensive", "DeadlineExceeded",
    "DeadLetterError", "DeltaApplyFailed", "InjectedFault",
    "Request", "ServeEngine",
]
