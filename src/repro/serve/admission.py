"""Cost-based admission control for the query server (DESIGN.md Sec. 7).

In the spirit of virt-graph's GREEN/YELLOW/RED query routing: estimate a
query's cost *before* running it from fragmentation stats alone, route it
to a lane, and reject pathological ones with a typed
:class:`~repro.errors.QueryTooExpensive` that carries the estimate.

The estimate counts **semiring operations** of the cached per-query phase
(DESIGN.md Sec. 3), per query::

    side = n_boundary * states            # boundary-system side
    cost = w * (largest_fragment * states + side^2)
           [+ side^2 * log2(side)  if the product closure must be built]

* ``largest_fragment * states`` — the per-device local stage: the paper's
  response-time bound says evaluation is limited by the largest |F_i|
  (times the automaton for RPQs);
* ``side^2`` — the per-query combine against the (product) closure;
* ``w = 2`` for dist/bounded — tropical int32 arithmetic, no bitpacking,
  double the Boolean wire and compute;
* the ``log2`` term charges an RPQ for the repeated-squaring closure
  build when its automaton's product closure is not already cached —
  the dominant first-query cost, amortized away for later queries on
  the same automaton (so the same regex can be RED cold and GREEN warm).

Lanes: **GREEN** (cheap, low-latency), **YELLOW** (expensive but
admitted — drained after the green lane so cheap queries never queue
behind heavy ones), **RED** (rejected at submit).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from ..core.fragments import Fragmentation
from ..errors import QueryTooExpensive

GREEN = "green"
YELLOW = "yellow"
RED = "red"
LANES = (GREEN, YELLOW, RED)


def estimate_cost(fr: Fragmentation, kind: str, states: int = 1,
                  closure_cached: bool = True) -> float:
    """Per-query cost estimate in semiring ops (see module docstring).
    Pure function of fragmentation stats — never touches a device."""
    states = max(int(states), 1)
    side = max(fr.n_boundary, 1) * states
    weight = 2.0 if kind in ("dist", "bounded") else 1.0
    cost = weight * (fr.largest_fragment() * states + side * side)
    if not closure_cached:
        cost += side * side * max(math.log2(side), 1.0)
    return cost


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Lane thresholds over :func:`estimate_cost` values.

    ``green_max``: costs above it route to the YELLOW lane (None: every
    admitted query is GREEN).  ``red_max``: costs above it are rejected
    with :class:`~repro.errors.QueryTooExpensive` (None: never reject —
    the safe default)."""

    green_max: Optional[float] = None
    red_max: Optional[float] = None

    def __post_init__(self):
        if (self.green_max is not None and self.red_max is not None
                and self.red_max < self.green_max):
            raise ValueError(f"red_max ({self.red_max}) must be >= "
                             f"green_max ({self.green_max})")

    def lane(self, cost: float) -> str:
        if self.red_max is not None and cost > self.red_max:
            return RED
        if self.green_max is not None and cost > self.green_max:
            return YELLOW
        return GREEN

    def admit(self, kind: str, cost: float) -> str:
        """Lane for ``cost``; raises on RED."""
        lane = self.lane(cost)
        if lane == RED:
            raise QueryTooExpensive(kind, cost, self.red_max)
        return lane

    @classmethod
    def for_fragmentation(cls, fr: Fragmentation,
                          green_factor: float = 8.0,
                          red_max: Optional[float] = None,
                          ) -> "AdmissionPolicy":
        """Default policy: the green lane holds queries within
        ``green_factor`` x the cheapest (reach) cost — plain reach/dist
        and small cached RPQs — while big-automaton and cold-closure RPQs
        go YELLOW.  Rejection stays off unless ``red_max`` is given."""
        return cls(green_max=green_factor * estimate_cost(fr, "reach"),
                   red_max=red_max)
