"""Continuous-batching async engine for distributed reachability serving
(DESIGN.md Sec. 8).

Concurrent submitters enqueue typed requests and immediately receive
awaitable futures (:class:`QueryFuture` / :class:`UpdateFuture`); a
background scheduler thread continuously forms bounded-size chunks from
whatever is pending and executes each as ONE ``session.run`` mixed batch
— the session planner fuses the chunk into one compiled execution per
(kind, automaton) group, so the paper's one-collective-per-group
guarantee is preserved under continuous load.

Scheduling model:

* The intake queue is a sequence of **segments** separated by graph
  updates.  A delta is a natural snapshot barrier: every query submitted
  before it is served before the delta applies (pre-delta futures answer
  against the pre-delta ``cache_version``), and queries submitted after
  it wait behind it.  Fencing is therefore structural — no timestamps,
  no read locks on the cache.
* **MVCC mode** (constructed with a
  :class:`~repro.core.versions.VersionedCacheStore`) removes the barrier
  entirely: deltas route to a dedicated repair worker thread that
  commits each as a new copy-on-write version while query chunks keep
  forming and executing against the pinned head snapshot — the queue
  stays one segment, reads never wait for a repair, and a delta becomes
  visible exactly when its version publishes (DESIGN.md Sec. 9).
* Within a segment, requests sit in their admission lane (GREEN first,
  then YELLOW, PR-7 semantics).  A chunk ships when the lane holds a full
  batch, a barrier or flush is pending behind it, the oldest deadline in
  the lane is within ``ship_margin`` of expiring (partial-bucket
  shipping), or the oldest request has waited ``batch_wait`` — the knob
  that trades per-request latency for batch occupancy.
* Execution reuses the PR-7 robustness stack unchanged: expired requests
  fail fast with :class:`~repro.errors.DeadlineExceeded`, failed chunks
  retry with capped exponential backoff, chunks that keep failing are
  bisected until the poison request is quarantined alone
  (:class:`~repro.errors.DeadLetterError`), and a failing delta rolls
  back and resolves its future ``FAILED`` without blocking the queue.

Every future reaches **exactly one** terminal :class:`~repro.errors.Status`
(asserted), and every resolution feeds the live
:class:`~repro.serve.telemetry.Telemetry` layer.

The engine also runs *without* a scheduler thread (``start()`` never
called): requests defer until :meth:`flush`, which runs the same
scheduling loop inline — the deterministic mode tests and the PR-7
``drain()`` compatibility path use.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

from ..core.automaton import QueryAutomaton
from ..core.fragments import GraphDelta
from ..core.plan import Dist, Query, Reach, Rpq
from ..core.session import QuerySession
from ..errors import (DeadLetterError, DeadlineExceeded, DeltaApplyFailed,
                      Status)
from .admission import GREEN, YELLOW
from .telemetry import Telemetry


@dataclasses.dataclass
class RetryPolicy:
    """Capped exponential backoff for transient serving failures: attempt
    ``i`` (2nd, 3rd, ...) sleeps ``min(base * 2^(i-2), max)`` ms first.
    Permanent faults (``exc.permanent``) skip retries entirely."""

    max_attempts: int = 3
    base_delay_ms: float = 5.0
    max_delay_ms: float = 200.0

    def delay_s(self, retry_index: int) -> float:
        """Sleep before the ``retry_index``-th retry (1-based), seconds."""
        ms = min(self.base_delay_ms * (2.0 ** (retry_index - 1)),
                 self.max_delay_ms)
        return ms / 1e3


class _Future:
    """Common awaitable machinery for query and update futures."""

    def __init__(self):
        self._event = threading.Event()
        self._seq: Optional[int] = None     # global resolution order
        self.status: Status = Status.PENDING
        self.value: object = None           # raw result once resolved
        self.error: Optional[BaseException] = None
        self.submitted_at: Optional[float] = None   # engine clock
        self.resolved_at: Optional[float] = None

    def done(self) -> bool:
        """True once the future holds a terminal status."""
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        """Block until resolved and return the value, or raise the typed
        terminal error (``DeadlineExceeded`` / ``DeadLetterError`` /
        ``DeltaApplyFailed``).  Raises :class:`TimeoutError` if the future
        is still unresolved after ``timeout`` seconds — including on a
        server that was constructed with ``start=False`` and not flushed.
        """
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"{type(self).__name__} unresolved after "
                f"{timeout!r}s (status {self.status}); deferred servers "
                "(start=False) need flush() before result() returns")
        if self.error is not None:
            raise self.error
        return self.value

    @property
    def latency_s(self) -> Optional[float]:
        """Submit-to-resolve latency on the engine clock (None while
        pending)."""
        if self.resolved_at is None or self.submitted_at is None:
            return None
        return self.resolved_at - self.submitted_at


class QueryFuture(_Future):
    """Awaitable handle for one submitted query.

    Returned by :meth:`repro.serve.QueryServer.submit`; not constructed
    directly.  ``result()`` blocks for the answer (bool for
    reach/bounded/rpq, hop count or None for dist); ``value`` is the
    non-blocking raw view (None until resolved), ``status`` the live
    :class:`~repro.errors.Status`.  ``cache_version`` is the rvset-cache
    snapshot the answer was computed against — the fencing witness.
    """

    def __init__(self, s: int, t: int, kind: str = "reach",
                 bound: Optional[int] = None, regex: Optional[str] = None,
                 automaton: Optional[QueryAutomaton] = None,
                 lane: str = GREEN, cost: float = 0.0,
                 deadline: Optional[float] = None):
        super().__init__()
        self.s = s
        self.t = t
        self.kind = kind
        self.bound = bound
        self.regex = regex
        self.automaton = automaton
        self.lane = lane
        self.cost = cost
        self.deadline = deadline            # absolute engine-clock seconds
        self.cache_version: Optional[int] = None
        self.attempts = 0                   # engine attempts it rode in
        self.degraded = False               # served by the vmap fallback
        self._enqueued_wall: Optional[float] = None   # batch_wait pacing

    def to_query(self) -> Query:
        if self.kind == "reach":
            return Reach(self.s, self.t)
        if self.kind == "dist":
            return Dist(self.s, self.t)
        if self.kind == "bounded":
            return Dist(self.s, self.t, bound=self.bound)
        return Rpq(self.s, self.t, regex=self.regex,
                   automaton=self.automaton)

    def __repr__(self) -> str:
        return (f"QueryFuture({self.kind} {self.s}->{self.t}, "
                f"status={self.status}, lane={self.lane})")


class UpdateFuture(_Future):
    """Awaitable handle for one submitted graph delta.

    Returned by :meth:`repro.serve.QueryServer.submit_delta`.
    ``result()`` blocks for the :class:`~repro.core.incremental
    .UpdateStats` (or raises :class:`~repro.errors.DeltaApplyFailed` if
    the delta rolled back); terminal ``status`` is ``APPLIED`` or
    ``FAILED``.
    """

    def __init__(self, delta: GraphDelta):
        super().__init__()
        self.delta = delta

    def __repr__(self) -> str:
        return f"UpdateFuture(status={self.status})"


class _Segment:
    """Queries between two snapshot barriers, bucketed by admission
    lane."""

    __slots__ = ("lanes",)

    def __init__(self):
        self.lanes: Dict[str, collections.deque] = {
            GREEN: collections.deque(), YELLOW: collections.deque()}

    def depth(self) -> int:
        return sum(len(q) for q in self.lanes.values())


class AsyncQueryEngine:
    """Continuous-batching scheduler over one shared
    :class:`~repro.core.session.QuerySession` (see module docstring)."""

    #: how long the scheduler's graceful join waits before giving up
    JOIN_TIMEOUT_S = 60.0

    def __init__(self, session: QuerySession, batch_size: int = 64,
                 retry: Optional[RetryPolicy] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 ship_margin_s: float = 0.025,
                 batch_wait_s: float = 0.002,
                 telemetry: Optional[Telemetry] = None,
                 store=None,
                 dead_letter_cap: Optional[int] = 256):
        assert batch_size > 0
        self.session = session
        self.batch_size = batch_size
        self.retry = retry or RetryPolicy()
        self._clock = clock
        self._sleep = sleep
        self.ship_margin = ship_margin_s
        self.batch_wait = batch_wait_s
        self.telemetry = telemetry or Telemetry()
        # MVCC mode: a core.versions.VersionedCacheStore over this session.
        # Deltas then bypass the barrier queue and commit concurrently on
        # the repair worker while chunks serve against the pinned head.
        self.store = store
        # _mutex guards the queue/counters; it is reentrant because batch
        # formation (under the condition) resolves expired futures inline
        self._mutex = threading.RLock()
        self._work = threading.Condition(self._mutex)
        self._queue: collections.deque = collections.deque()  # _Segment|UpdateFuture
        self._in_flight: List[_Future] = []   # popped, not yet resolved
        self._flushes = 0                     # active flush() calls
        self._resolved_seq = 0
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        # MVCC repair lane: pending deltas + the worker draining them
        self._repairs: collections.deque = collections.deque()
        self._repair_cond = threading.Condition(self._mutex)
        self._repair_thread: Optional[threading.Thread] = None
        # one executor at a time: either the scheduler thread or an
        # inline flush, never both (the repair worker is deliberately
        # OUTSIDE this mutex — repairs must overlap query serving)
        self._serve_mutex = threading.Lock()
        # dead letters keep only the newest ``dead_letter_cap`` poison
        # requests (None = unbounded) so sustained poison traffic cannot
        # grow memory without limit; evictions are counted, not silent
        self.dead_letter_cap = dead_letter_cap
        self.dead_letters: collections.deque = collections.deque(
            maxlen=dead_letter_cap)
        self.dead_letters_evicted = 0
        self.batches_run = 0
        self.updates_applied = 0
        self.updates_failed = 0
        self.retries = 0          # extra engine attempts beyond the first

    # -- intake ------------------------------------------------------------

    def submit(self, fut: QueryFuture) -> QueryFuture:
        """Enqueue an admitted query future (intake validation is the
        server's job)."""
        with self._work:
            if self._stop:
                raise RuntimeError("engine is stopped; no new submissions")
            if not self._queue or not isinstance(self._queue[-1], _Segment):
                self._queue.append(_Segment())
            lane = fut.lane if fut.lane in (GREEN, YELLOW) else GREEN
            fut.submitted_at = self._clock()
            # batch_wait pacing must track real elapsed time even when
            # self._clock is a fake test clock (see _form_chunk):
            # repr: ignore[RPR003] wall-clock batch pacing is by design
            fut._enqueued_wall = time.monotonic()
            self._queue[-1].lanes[lane].append(fut)
            self._work.notify_all()
        return fut

    def submit_update(self, fut: UpdateFuture) -> UpdateFuture:
        """Enqueue a graph delta — a snapshot barrier in the default mode,
        a concurrent repair-lane entry in MVCC mode (the query queue stays
        one segment and never fences)."""
        with self._work:
            if self._stop:
                raise RuntimeError("engine is stopped; no new submissions")
            fut.submitted_at = self._clock()
            if self.store is not None:
                self._repairs.append(fut)
                self._repair_cond.notify_all()
            else:
                self._queue.append(fut)
            self._work.notify_all()
        return fut

    def backlog(self) -> int:
        """Submitted-but-unresolved count (queued + executing)."""
        with self._mutex:
            queued = sum(e.depth() if isinstance(e, _Segment) else 1
                         for e in self._queue)
            return queued + len(self._repairs) + len(self._in_flight)

    def depths(self) -> Dict[str, int]:
        """Live per-lane queue depths plus pending update count."""
        with self._mutex:
            out = {GREEN: 0, YELLOW: 0, "updates": 0}
            for e in self._queue:
                if isinstance(e, _Segment):
                    for lane, q in e.lanes.items():
                        out[lane] += len(q)
                else:
                    out["updates"] += 1
            out["updates"] += len(self._repairs)
            return out

    def mvcc_gauges(self) -> Optional[Dict[str, object]]:
        """Live MVCC observability (None outside MVCC mode): the store's
        version/pin/drop gauges plus the repair-lane depth."""
        if self.store is None:
            return None
        gauges = self.store.gauges()
        with self._mutex:
            gauges["repair_queue_depth"] = len(self._repairs) + sum(
                1 for f in self._in_flight if isinstance(f, UpdateFuture))
        return gauges

    # -- lifecycle ---------------------------------------------------------

    @property
    def running(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def start(self) -> "AsyncQueryEngine":
        """Spawn the background scheduler thread (idempotent), plus the
        dedicated repair worker in MVCC mode."""
        with self._mutex:
            if self.running:
                return self
            self._stop = False
            self._thread = threading.Thread(
                target=self._loop, name="repro-query-scheduler", daemon=True)
            self._thread.start()
            if self.store is not None:
                self._repair_thread = threading.Thread(
                    target=self._repair_loop, name="repro-repair-worker",
                    daemon=True)
                self._repair_thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the scheduler (and repair worker).  ``drain=True``
        (default) serves everything already queued first; ``drain=False``
        abandons pending futures (they stay unresolved forever)."""
        if drain:
            self.flush()
        with self._work:
            self._stop = True
            self._work.notify_all()
            self._repair_cond.notify_all()
        for t in (self._thread, self._repair_thread):
            if t is not None and t is not threading.current_thread():
                t.join(timeout=self.JOIN_TIMEOUT_S)
        self._thread = None
        self._repair_thread = None

    # -- synchronous barrier ----------------------------------------------

    def flush(self) -> List[_Future]:
        """Serve everything submitted before this call and return those
        futures in resolution order.

        With a running scheduler this just waits (the flush flag makes
        the scheduler ship partial buckets eagerly); without one it runs
        the same scheduling loop inline — the deterministic path the
        compatibility ``drain()`` uses.
        """
        with self._mutex:
            targets = self._unresolved()
            self._flushes += 1
            self._work.notify_all()
        try:
            if self.running:
                for f in targets:
                    f._event.wait()
            else:
                self._run_inline(targets)
        finally:
            with self._mutex:
                self._flushes -= 1
        return sorted(targets, key=lambda f: f._seq)

    def _unresolved(self) -> List[_Future]:
        """Every queued or in-flight future (caller holds the mutex)."""
        out: List[_Future] = []
        for e in self._queue:
            if isinstance(e, _Segment):
                for q in e.lanes.values():
                    out.extend(q)
            else:
                out.append(e)
        out.extend(self._repairs)
        out.extend(f for f in self._in_flight if not f.done())
        return out

    def _run_inline(self, targets: List[_Future]) -> None:
        """Flush without a scheduler thread: run the scheduling loop on
        the calling thread until every target is resolved."""
        with self._serve_mutex:
            while not all(f.done() for f in targets):
                work = self._next_work_nowait()
                if work is None:
                    if all(f.done() for f in targets):
                        break
                    raise RuntimeError(
                        "flush stalled: unresolved futures but no "
                        "runnable work (lost request?)")
                self._execute(work)

    # -- scheduler loop ----------------------------------------------------

    def _loop(self) -> None:
        while True:
            work = self._next_work()
            if work is None:
                return
            with self._serve_mutex:
                self._execute(work)

    def _execute(self, work) -> None:
        if isinstance(work, UpdateFuture):
            if self.store is not None:
                self._apply_update_mvcc(work)
            else:
                self._apply_update(work)
        else:
            self._serve_chunk(work)

    def _repair_loop(self) -> None:
        """MVCC repair worker: commit pending deltas as new versions,
        concurrently with the scheduler's query serving (no _serve_mutex —
        that exclusion is exactly what MVCC removes)."""
        while True:
            with self._repair_cond:
                while not self._repairs and not self._stop:
                    self._repair_cond.wait()
                if self._stop:
                    return    # drain=True flushed first; else abandon, like
                    #           the scheduler does with its queue
                fut = self._repairs.popleft()
                self._in_flight.append(fut)
            self._apply_update_mvcc(fut)

    def _next_work(self):
        """Block until a chunk or barrier is ready to execute; None on
        stop."""
        with self._work:
            while True:
                if self._stop:
                    return None
                work = self._pop_ready()
                if work is not None:
                    return work
                head = self._head_segment()
                if head is None or head.depth() == 0:
                    self._work.wait()          # notified on submit/stop
                else:
                    self._work.wait(self._poll_s(head))

    def _next_work_nowait(self):
        """Non-blocking variant for inline flush (flush flag is set, so
        any non-empty lane forms a chunk).  Pending MVCC repairs drain
        *after* the queued chunks — the deterministic analogue of the live
        ordering, where already-formed chunks answer the pre-delta head."""
        with self._mutex:
            work = self._pop_ready()
            if work is not None:
                return work
            if self._repairs:
                fut = self._repairs.popleft()
                self._in_flight.append(fut)
                return fut
            return None

    def _head_segment(self) -> Optional[_Segment]:
        """Drop exhausted leading segments; return the head segment (or
        None when the queue is empty / headed by an update).  Caller
        holds the mutex."""
        while (len(self._queue) > 1
               and isinstance(self._queue[0], _Segment)
               and self._queue[0].depth() == 0):
            self._queue.popleft()
        if not self._queue:
            return None
        head = self._queue[0]
        return head if isinstance(head, _Segment) else None

    def _pop_ready(self):
        """Pop the next executable unit (update barrier or query chunk)
        if one is ready.  Caller holds the mutex."""
        self._head_segment()
        if not self._queue:
            return None
        head = self._queue[0]
        if isinstance(head, UpdateFuture):
            self._queue.popleft()
            self._in_flight.append(head)
            return head
        if head.depth() == 0:
            return None
        return self._form_chunk(head)

    def _form_chunk(self, seg: _Segment) -> Optional[List[QueryFuture]]:
        """Expire dead requests, then pop a chunk from the preferred lane
        when a ship condition holds.  Caller holds the mutex."""
        now = self._clock()
        for lane, q in seg.lanes.items():
            live: collections.deque = collections.deque()
            while q:
                r = q.popleft()
                if r.deadline is not None and now >= r.deadline:
                    r.error = DeadlineExceeded(
                        f"deadline expired "
                        f"{(now - r.deadline) * 1e3:.1f}ms before the "
                        f"{r.kind} query ({r.s}, {r.t}) was served")
                    self._resolve(r, Status.DEADLINE)
                else:
                    live.append(r)
            seg.lanes[lane] = live
        lane = GREEN if seg.lanes[GREEN] else YELLOW   # green ships first
        reqs = seg.lanes[lane]
        if not reqs:
            return None
        ship = (len(reqs) >= self.batch_size
                or len(self._queue) > 1      # barrier fenced behind us
                or self._flushes > 0
                or self._stop
                or self._deadline_pressed(reqs, now)
                # repr: ignore[RPR003] wall-clock pairs _enqueued_wall above
                or (time.monotonic() - reqs[0]._enqueued_wall
                    >= self.batch_wait))
        if not ship:
            return None
        chunk = [reqs.popleft()
                 for _ in range(min(self.batch_size, len(reqs)))]
        for r in chunk:
            r.status = Status.RUNNING
        self._in_flight.extend(chunk)
        return chunk

    def _deadline_pressed(self, reqs, now: float) -> bool:
        """True when the oldest latency budget in the lane is nearly spent
        — ship the partially-full bucket now rather than risk blowing it
        while waiting for the bucket to fill."""
        deadlines = [r.deadline for r in reqs if r.deadline is not None]
        if not deadlines:
            return False
        return min(deadlines) - now <= self.ship_margin

    def _poll_s(self, seg: _Segment) -> float:
        """Bounded wait until the head segment's next ship condition can
        trigger on its own (batch_wait expiry or deadline pressure)."""
        wait = self.batch_wait
        oldest = None
        for q in seg.lanes.values():
            for r in q:
                if oldest is None or r._enqueued_wall < oldest:
                    oldest = r._enqueued_wall
                if r.deadline is not None:
                    press = r.deadline - self.ship_margin - self._clock()
                    wait = min(wait, press)
        if oldest is not None:
            wait = min(wait,  # repr: ignore[RPR003] pairs _enqueued_wall
                       self.batch_wait - (time.monotonic() - oldest))
        return max(1e-4, min(wait, 0.05))

    # -- execution (PR-7 robustness stack, unchanged semantics) ------------

    def _serve_chunk(self, reqs: List[QueryFuture]) -> None:
        """Fail requests that expired while queued behind a slow batch,
        then serve the rest with retries."""
        now = self._clock()
        live = []
        for r in reqs:
            if r.deadline is not None and now >= r.deadline:
                r.error = DeadlineExceeded(
                    f"deadline expired {(now - r.deadline) * 1e3:.1f}ms "
                    f"before the {r.kind} query ({r.s}, {r.t}) was served")
                self._resolve(r, Status.DEADLINE)
            else:
                live.append(r)
        self._serve_with_retry(live)

    def _serve_with_retry(self, reqs: List[QueryFuture]) -> None:
        """One chunk through the engine with capped-backoff retries; a
        chunk that exhausts its retries is bisected so the poison request
        is dead-lettered alone and its batchmates get served."""
        if not reqs:
            return
        last: Optional[BaseException] = None
        for attempt in range(1, self.retry.max_attempts + 1):
            if attempt > 1:
                self.retries += 1
                self._sleep(self.retry.delay_s(attempt - 1))
            for r in reqs:
                r.attempts += 1
            try:
                self._serve_batch(reqs)
            except Exception as exc:           # noqa: BLE001 — retried
                last = exc
                if getattr(exc, "permanent", False):
                    break                      # retrying cannot help
                continue
            for r in reqs:
                self._resolve(r, Status.DONE)
            return
        if len(reqs) == 1:
            r = reqs[0]
            r.error = DeadLetterError(r.attempts, last)
            if (self.dead_letter_cap is not None
                    and len(self.dead_letters) >= self.dead_letter_cap):
                self.dead_letters_evicted += 1   # deque drops the oldest
            self.dead_letters.append(r)
            self._resolve(r, Status.DEAD_LETTER)
            return
        mid = len(reqs) // 2                   # bisect: quarantine poison
        self._serve_with_retry(reqs[:mid])
        self._serve_with_retry(reqs[mid:])

    def _serve_batch(self, reqs: List[QueryFuture]) -> None:
        """ONE session.run mixed batch; the planner fuses it into one
        compiled execution per (kind, automaton) group.  In MVCC mode the
        batch pins the head snapshot for its whole run — a concurrently
        publishing repair never moves the ground under it, and the pinned
        version cannot be evicted until the batch releases it (per-attempt
        re-pinning under retries is sound: head reads are monotonic)."""
        if self.store is not None:
            ver = self.store.acquire_head()
            try:
                results = self.session.run([r.to_query() for r in reqs],
                                           version=ver)
            finally:
                self.store.release(ver)
        else:
            results = self.session.run([r.to_query() for r in reqs])
        for r, res in zip(reqs, results):
            r.value = res.distance if r.kind == "dist" else res.answer
            r.cache_version = res.cache_version
            r.degraded = res.degraded
        self.batches_run += 1
        self.telemetry.record_batch(len(reqs), self.batch_size)

    def _apply_update(self, fut: UpdateFuture) -> None:
        """Apply one barrier delta.  On failure the session has already
        rolled back to the pre-delta snapshot; the failure resolves the
        future and serving continues — a poison delta never blocks the
        requests queued behind it."""
        try:
            fut.value = self.session.apply(fut.delta)
        except DeltaApplyFailed as exc:
            fut.error = exc
            self.updates_failed += 1
            self._resolve(fut, Status.FAILED)
            return
        self.updates_applied += 1
        self._resolve(fut, Status.APPLIED)

    def _apply_update_mvcc(self, fut: UpdateFuture) -> None:
        """Commit one delta as a new MVCC version.  On failure the clone
        is dropped and the head keeps serving — no rollback, no pause;
        the failure resolves the future ``FAILED`` like the barrier
        path."""
        try:
            _ver, fut.value = self.store.commit_delta(fut.delta)
        except DeltaApplyFailed as exc:
            fut.error = exc
            self.updates_failed += 1
            self._resolve(fut, Status.FAILED)
            return
        self.updates_applied += 1
        self._resolve(fut, Status.APPLIED)

    def _resolve(self, fut: _Future, status: Status) -> None:
        """Move a future to its terminal status — exactly once, ever."""
        assert fut.status in (Status.PENDING, Status.RUNNING), \
            f"future resolved twice ({fut.status} -> {status}): {fut!r}"
        fut.status = status
        fut.resolved_at = self._clock()
        with self._mutex:
            self._resolved_seq += 1
            fut._seq = self._resolved_seq
            try:
                self._in_flight.remove(fut)
            except ValueError:
                pass                           # expired before dispatch
        route = (f"{fut.kind}/{fut.lane}" if isinstance(fut, QueryFuture)
                 else "update")
        self.telemetry.record(route, fut.latency_s, status)
        fut._event.set()
