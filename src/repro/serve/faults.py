"""Deterministic fault injection for the serving stack (DESIGN.md Sec. 7).

A :class:`FaultInjector` is threaded through ``repro.connect(fr,
chaos=...)`` / ``QueryServer(fr, chaos=...)`` and consulted at four
injection points — the *sites* — that bracket every external effect the
engines perform:

=================  =========================================================
site               guards
=================  =========================================================
``upload``         host→device transfer of the fragment arrays for a
                   sharded batch (``distributed._device_inputs``)
``engine.shard_map``  invocation of a compiled one-collective sharded batch
``engine.vmap``    invocation of a host (vmap) batched engine — also the
                   degraded-mode fallback path
``delta.repair``   cache repair after ``fr.apply_delta`` mutated the host
                   arrays (both the host and sharded update paths), so a
                   failure here exercises genuine mid-update rollback
=================  =========================================================

Failures are **deterministic and seedable**: each site draws from its own
``numpy`` PCG64 stream seeded by ``(seed, site index)``, so a chaos
schedule replays identically regardless of how other sites interleave.
Per-site :class:`FaultSpec`\\ s give a failure ``rate`` and an optional
``max_failures`` budget (after which the site heals — the way to test
that retries eventually succeed).  ``poison`` pairs model a query that is
broken *in itself*: any engine batch containing one raises a
``permanent`` :class:`~repro.errors.InjectedFault` every time, which is
what drives the server's bisect-to-dead-letter path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Tuple, Union

import numpy as np

from ..errors import InjectedFault

#: every injection point the library consults, in stream-seed order
SITES = ("delta.repair", "engine.shard_map", "engine.vmap", "upload")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Failure behaviour of one site: fail each draw with probability
    ``rate``; after ``max_failures`` injected failures the site heals
    (None: never heals)."""

    rate: float = 0.0
    max_failures: Optional[int] = None

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"fault rate must be in [0, 1], got {self.rate}")


class FaultInjector:
    """Deterministic, seedable chaos schedule over the injection SITES.

    ``rates`` maps site name -> ``FaultSpec`` (or a bare float rate);
    ``poison`` is an iterable of (s, t) query pairs that permanently fail
    any engine batch containing them.  Counters ``draws`` / ``failures``
    (site -> int) let tests assert the schedule actually fired.
    """

    def __init__(self, seed: int = 0,
                 rates: Optional[Dict[str, Union[float, FaultSpec]]] = None,
                 poison: Iterable[Tuple[int, int]] = ()):
        specs: Dict[str, FaultSpec] = {}
        for site, spec in (rates or {}).items():
            if site not in SITES:
                raise ValueError(f"unknown fault site {site!r}; expected "
                                 f"one of {SITES}")
            if not isinstance(spec, FaultSpec):
                spec = FaultSpec(rate=float(spec))
            specs[site] = spec
        self.seed = int(seed)
        self.specs = specs
        self.poison = {(int(s), int(t)) for s, t in poison}
        # one independent PCG64 stream per site: the schedule at a site
        # never depends on how often the other sites were consulted
        self._rng = {site: np.random.default_rng([self.seed, i])
                     for i, site in enumerate(SITES)}
        self.draws: Dict[str, int] = {site: 0 for site in SITES}
        self.failures: Dict[str, int] = {site: 0 for site in SITES}

    def maybe_fail(self, site: str, pairs=None) -> None:
        """Consult the schedule at ``site``; raise
        :class:`~repro.errors.InjectedFault` when it fires.

        ``pairs`` (engine sites only) is the [N, 2] (s, t) batch about to
        run: if it contains a poison pair the fault is ``permanent`` —
        retries keep failing until bisection isolates the poison request.
        """
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}; expected one "
                             f"of {SITES}")
        self.draws[site] += 1
        if pairs is not None and self.poison:
            for s, t in np.asarray(pairs).reshape(-1, 2):
                if (int(s), int(t)) in self.poison:
                    self.failures[site] += 1
                    raise InjectedFault(site, permanent=True,
                                        detail=f"poison pair "
                                               f"({int(s)}, {int(t)})")
        spec = self.specs.get(site)
        if spec is None or spec.rate <= 0.0:
            return
        if (spec.max_failures is not None
                and self.failures[site] >= spec.max_failures):
            return                      # budget spent: the site has healed
        if self._rng[site].random() < spec.rate:
            self.failures[site] += 1
            raise InjectedFault(
                site, detail=f"transient #{self.failures[site]} "
                             f"(seed {self.seed})")
