"""Batched serving loop: prefill + greedy decode with a KV cache.

The decode step is the unit the decode_* / long_* dry-run cells lower; this
module adds the request-level machinery around it (continuous batching of
a request queue into fixed-size decode batches, per-request stop lengths).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as T


@dataclasses.dataclass
class Request:
    prompt: np.ndarray             # [S] int32
    max_new_tokens: int = 16
    generated: Optional[List[int]] = None


class ServeEngine:
    """Fixed-batch continuous decoder (slots model, vLLM-style scheduling
    at toy scale)."""

    def __init__(self, cfg: T.LMConfig, params, batch: int, max_len: int):
        self.cfg, self.params = cfg, params
        self.batch, self.max_len = batch, max_len
        self._decode = jax.jit(
            lambda p, c, t, pos: T.decode_step(cfg, p, c, t, pos))

    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve a queue of requests in fixed-size batches."""
        out: List[Request] = []
        for i in range(0, len(requests), self.batch):
            out.extend(self._serve_batch(requests[i:i + self.batch]))
        return out

    def _serve_batch(self, reqs: List[Request]) -> List[Request]:
        B = self.batch
        S = max(len(r.prompt) for r in reqs)
        prompts = np.zeros((B, S), np.int32)
        for j, r in enumerate(reqs):
            prompts[j, S - len(r.prompt):] = r.prompt      # left-pad
        cache = T.init_cache(self.cfg, B, self.max_len)
        # prefill by stepping (keeps one compiled step; fine at toy scale)
        logits = None
        for i in range(S):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(prompts[:, i]),
                                         jnp.full((B,), i, jnp.int32))
        tok = jnp.argmax(logits, axis=-1)
        n_new = max(r.max_new_tokens for r in reqs)
        gen = [tok]
        for i in range(n_new - 1):
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.full((B,), S + i, jnp.int32))
            tok = jnp.argmax(logits, axis=-1)
            gen.append(tok)
        gen_np = np.stack([np.asarray(g) for g in gen], axis=1)  # [B, n_new]
        for j, r in enumerate(reqs):
            r.generated = gen_np[j, : r.max_new_tokens].tolist()
        return reqs
