"""Fault-tolerant batched query serving over a QuerySession
(DESIGN.md Secs. 3.4, 5 & 7).

Requests accumulate in a queue and are drained in bounded-size chunks,
each served by ONE ``session.run`` mixed batch — the session's planner
fuses every chunk into one compiled execution per (kind, automaton)
group, with batch sizes padded to buckets so the engine never retraces
under bursty traffic.  All three query classes are served, including
regular path queries (``kind="rpq"`` with a regex or automaton).

Robustness (Sec. 7), layered on that loop:

* **Admission control** — ``submit`` estimates each query's cost from
  fragmentation stats (:mod:`repro.serve.admission`) and routes it to the
  GREEN (cheap) or YELLOW (expensive) lane; RED queries are rejected at
  intake with a typed :class:`~repro.errors.QueryTooExpensive`.  The
  drain flushes the green lane first, so cheap queries never queue
  behind heavy ones.
* **Deadlines** — ``submit(..., deadline_ms=)`` gives a request a latency
  budget.  The drain ships a *partially-full* bucket when the oldest
  budget in a lane is nearly spent, and fails already-expired requests
  fast with :class:`~repro.errors.DeadlineExceeded` instead of serving
  them arbitrarily late.
* **Retry / bisect / dead-letter** — a failed chunk retries with capped
  exponential backoff; permanent faults skip the backoff.  A chunk that
  keeps failing is bisected so the poison request is quarantined into
  ``dead_letters`` (status ``"dead_letter"``) while its batchmates are
  served — a poison request can never block the queue.
* **Update isolation** — ``submit_delta`` keeps snapshot consistency
  (queries before an update answer pre-delta; a batch never spans an
  update).  A failing delta is rolled back by the session
  (:class:`~repro.errors.DeltaApplyFailed`; pre-delta cache intact),
  recorded on its request (status ``"failed"``), and the drain continues.

Every request reaches **exactly one** terminal status per submission:
``done`` / ``dead_letter`` / ``deadline`` for queries, ``applied`` /
``failed`` for updates — never lost, never double-served (asserted).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple, Union

from ..core.automaton import QueryAutomaton
from ..core.fragments import Fragmentation, GraphDelta
from ..core.incremental import UpdateStats
from ..core.plan import Dist, Query, Reach, Rpq
from ..core.session import QuerySession, connect
from ..errors import (DeadLetterError, DeadlineExceeded, DeltaApplyFailed,
                      QueryTooExpensive)
from .admission import GREEN, YELLOW, AdmissionPolicy, estimate_cost
from .faults import FaultInjector

VALID_KINDS = ("reach", "dist", "bounded", "rpq")

# request lifecycle: PENDING -> exactly one terminal status
PENDING = "pending"
DONE = "done"                 # query answered (result filled)
DEAD_LETTER = "dead_letter"   # query quarantined after retries + bisection
DEADLINE = "deadline"         # query failed fast: budget expired unserved
APPLIED = "applied"           # update applied (result = UpdateStats)
FAILED = "failed"             # update failed and was rolled back


@dataclasses.dataclass
class RetryPolicy:
    """Capped exponential backoff for transient serving failures: attempt
    ``i`` (2nd, 3rd, ...) sleeps ``min(base * 2^(i-2), max)`` ms first.
    Permanent faults (``exc.permanent``) skip retries entirely."""

    max_attempts: int = 3
    base_delay_ms: float = 5.0
    max_delay_ms: float = 200.0

    def delay_s(self, retry_index: int) -> float:
        """Sleep before the ``retry_index``-th retry (1-based), seconds."""
        ms = min(self.base_delay_ms * (2.0 ** (retry_index - 1)),
                 self.max_delay_ms)
        return ms / 1e3


@dataclasses.dataclass
class QueryRequest:
    s: int
    t: int
    kind: str = "reach"              # one of VALID_KINDS
    bound: Optional[int] = None      # bounded queries only
    regex: Optional[str] = None      # rpq only (exactly one of regex /
    automaton: Optional[QueryAutomaton] = None     # automaton)
    result: object = None            # bool / int-or-None once served
    # rvset-cache version the answer was computed against (snapshot id)
    cache_version: Optional[int] = None
    # -- robustness metadata (DESIGN.md Sec. 7) -----------------------------
    lane: str = GREEN                # admission lane (green / yellow)
    cost: float = 0.0                # admission cost estimate, semiring ops
    deadline: Optional[float] = None  # absolute clock() time, seconds
    status: str = PENDING            # lifecycle (see module constants)
    error: Optional[BaseException] = None   # terminal failure, if any
    attempts: int = 0                # engine attempts this request rode in
    degraded: bool = False           # served by the vmap fallback

    def to_query(self) -> Query:
        if self.kind == "reach":
            return Reach(self.s, self.t)
        if self.kind == "dist":
            return Dist(self.s, self.t)
        if self.kind == "bounded":
            return Dist(self.s, self.t, bound=self.bound)
        return Rpq(self.s, self.t, regex=self.regex,
                   automaton=self.automaton)


@dataclasses.dataclass
class UpdateRequest:
    delta: GraphDelta
    result: Optional[UpdateStats] = None   # filled once applied
    status: str = PENDING                  # applied / failed
    error: Optional[BaseException] = None  # DeltaApplyFailed when failed


class QueryServer:
    """Bounded-batch fault-tolerant server over one (dynamic)
    Fragmentation."""

    def __init__(self, fr: Fragmentation, batch_size: int = 64,
                 warm: bool = True, with_dist: bool = False,
                 backend: str = "auto",
                 session: Optional[QuerySession] = None,
                 admission: Optional[AdmissionPolicy] = None,
                 retry: Optional[RetryPolicy] = None,
                 chaos: Optional[FaultInjector] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 ship_margin_ms: float = 25.0):
        """``with_dist=True`` eagerly builds the tropical cache too; the
        default leaves it to build lazily on the first dist/bounded query,
        so reach-only servers never pay for it.  Pass an existing
        ``session`` to share its caches/backend, or a ``backend`` name to
        open a fresh one (see :func:`repro.connect`).

        ``admission`` defaults to :meth:`AdmissionPolicy.for_fragmentation`
        (meaningful lanes, no rejection); ``retry`` to a 3-attempt capped
        backoff.  ``chaos`` threads a
        :class:`~repro.serve.faults.FaultInjector` through the session.
        ``clock``/``sleep`` are injectable for deterministic deadline and
        backoff tests; ``ship_margin_ms`` is how close to the oldest
        deadline the drain ships a partially-full bucket."""
        assert batch_size > 0
        self.fr = fr
        self.batch_size = batch_size
        self.with_dist = with_dist
        self.session = session or connect(fr, backend=backend, chaos=chaos)
        if session is not None and chaos is not None:
            session.chaos = chaos
        self.admission = admission or AdmissionPolicy.for_fragmentation(fr)
        self.retry = retry or RetryPolicy()
        self._clock = clock
        self._sleep = sleep
        self.ship_margin = ship_margin_ms / 1e3
        self._queue: List[Union[QueryRequest, UpdateRequest]] = []
        self.dead_letters: List[QueryRequest] = []
        self.batches_run = 0
        self.updates_applied = 0
        self.updates_failed = 0
        self.retries = 0          # extra engine attempts beyond the first
        self.rejected = 0         # RED-lane submissions refused
        if warm:
            self.session.warm(with_dist=with_dist)

    # -- request intake ----------------------------------------------------

    def submit(self, s: int, t: int, kind: str = "reach",
               bound: Optional[int] = None, regex: Optional[str] = None,
               automaton: Optional[QueryAutomaton] = None,
               deadline_ms: Optional[float] = None) -> QueryRequest:
        """Validate, admit, and enqueue one query.

        Raises ``ValueError`` on malformed arguments (unknown kind, bad
        kind/arg combination, endpoint outside ``[0, n)``) and
        :class:`~repro.errors.QueryTooExpensive` when admission control
        rejects the query; neither leaves anything queued.
        ``deadline_ms`` gives the request a latency budget measured from
        now (see :meth:`drain`)."""
        if kind not in VALID_KINDS:
            raise ValueError(f"unknown query kind {kind!r}; expected one "
                             f"of {VALID_KINDS}")
        if kind == "bounded" and bound is None:
            raise ValueError("bounded queries require a bound")
        if kind != "bounded" and bound is not None:
            raise ValueError(f"bound= is only valid for kind='bounded', "
                             f"not {kind!r}")
        if kind == "rpq" and (regex is None) == (automaton is None):
            raise ValueError("rpq queries require exactly one of regex= "
                             "or automaton=")
        if kind != "rpq" and (regex is not None or automaton is not None):
            raise ValueError(f"regex/automaton are only valid for "
                             f"kind='rpq', not {kind!r}")
        s, t = int(s), int(t)
        n = self.fr.g.n
        for name, v in (("s", s), ("t", t)):
            if not 0 <= v < n:
                raise ValueError(
                    f"query endpoint {name}={v} is out of range for a "
                    f"graph with {n} nodes (valid ids: 0..{n - 1})")
        lane, cost = self._admit(kind, s, t, regex, automaton)
        deadline = (None if deadline_ms is None
                    else self._clock() + deadline_ms / 1e3)
        req = QueryRequest(s, t, kind, bound, regex, automaton,
                           lane=lane, cost=cost, deadline=deadline)
        self._queue.append(req)
        return req

    def _admit(self, kind: str, s: int, t: int, regex, automaton):
        """Admission decision: (lane, cost estimate).  Raises
        :class:`~repro.errors.QueryTooExpensive` for the RED lane."""
        states, cached = 1, True
        if kind == "rpq":
            qa = automaton
            if qa is None:
                qa = self.session._resolve_automaton(Rpq(s, t, regex=regex))
            states = qa.n_states
            c = self.fr.rvset_cache
            cached = c is not None and qa.cache_key() in c.rpq_closures
        cost = estimate_cost(self.fr, kind, states=states,
                             closure_cached=cached)
        try:
            lane = self.admission.admit(kind, cost)
        except QueryTooExpensive:
            self.rejected += 1
            raise
        return lane, cost

    def submit_delta(self, delta: GraphDelta) -> UpdateRequest:
        """Enqueue a graph update.  It is applied during ``drain`` in
        submission order: earlier queries see the pre-delta snapshot,
        later ones the repaired cache (or, if the delta fails and rolls
        back, the unchanged pre-delta cache)."""
        req = UpdateRequest(delta)
        self._queue.append(req)
        return req

    def pending(self) -> int:
        return len(self._queue)

    # -- serving loop ------------------------------------------------------

    def drain(self) -> List[Union[QueryRequest, UpdateRequest]]:
        """Serve the whole queue; returns the requests in resolution order,
        each with ``result``/``error`` filled and a terminal ``status``.

        Queries are bucketed per admission lane (green flushed first) in
        bounded-size batches; a bucket also ships *early* when the oldest
        deadline in its lane is within ``ship_margin`` of expiring.  An
        update first flushes the queries queued before it (snapshot
        consistency — reordering only ever happens between two updates),
        then applies; failures never leave the queue blocked."""
        queue, self._queue = self._queue, []   # new submits -> fresh queue
        served: List[Union[QueryRequest, UpdateRequest]] = []
        lanes = {GREEN: [], YELLOW: []}

        def flush(lane: str) -> None:
            reqs = lanes[lane]
            while reqs:
                chunk = reqs[: self.batch_size]
                del reqs[: len(chunk)]
                self._serve_chunk(chunk, served)

        def flush_all() -> None:
            flush(GREEN)                       # low-latency lane first
            flush(YELLOW)

        for req in queue:
            if isinstance(req, UpdateRequest):
                flush_all()                    # pre-delta queries answered
                self._apply_update(req, served)
                continue
            lane = req.lane if req.lane in lanes else GREEN
            lanes[lane].append(req)
            if (len(lanes[lane]) >= self.batch_size
                    or self._deadline_pressed(lanes[lane])):
                flush(lane)
        flush_all()
        return served

    def _deadline_pressed(self, reqs: List[QueryRequest]) -> bool:
        """True when the oldest latency budget in the lane is nearly spent
        — ship the partially-full bucket now rather than risk blowing it
        while waiting for the bucket to fill."""
        deadlines = [r.deadline for r in reqs if r.deadline is not None]
        if not deadlines:
            return False
        return min(deadlines) - self._clock() <= self.ship_margin

    def _serve_chunk(self, reqs: List[QueryRequest], served) -> None:
        """Fail already-expired requests fast, then serve the rest with
        retries."""
        now = self._clock()
        live = []
        for r in reqs:
            if r.deadline is not None and now >= r.deadline:
                r.error = DeadlineExceeded(
                    f"deadline expired {(now - r.deadline) * 1e3:.1f}ms "
                    f"before the {r.kind} query ({r.s}, {r.t}) was served")
                self._resolve(r, DEADLINE, served)
            else:
                live.append(r)
        self._serve_with_retry(live, served)

    def _serve_with_retry(self, reqs: List[QueryRequest], served) -> None:
        """One chunk through the engine with capped-backoff retries; a
        chunk that exhausts its retries is bisected so the poison request
        is dead-lettered alone and its batchmates get served."""
        if not reqs:
            return
        last: Optional[BaseException] = None
        for attempt in range(1, self.retry.max_attempts + 1):
            if attempt > 1:
                self.retries += 1
                self._sleep(self.retry.delay_s(attempt - 1))
            for r in reqs:
                r.attempts += 1
            try:
                self._serve_batch(reqs)
            except Exception as exc:           # noqa: BLE001 — retried
                last = exc
                if getattr(exc, "permanent", False):
                    break                      # retrying cannot help
                continue
            for r in reqs:
                self._resolve(r, DONE, served)
            return
        if len(reqs) == 1:
            r = reqs[0]
            r.error = DeadLetterError(r.attempts, last)
            self.dead_letters.append(r)
            self._resolve(r, DEAD_LETTER, served)
            return
        mid = len(reqs) // 2                   # bisect: quarantine poison
        self._serve_with_retry(reqs[:mid], served)
        self._serve_with_retry(reqs[mid:], served)

    def _apply_update(self, req: UpdateRequest, served) -> None:
        """Apply one queued delta.  On failure the session has already
        rolled back to the pre-delta snapshot; the failure is recorded on
        the request and the drain continues — a poison delta never blocks
        the requests queued behind it."""
        try:
            req.result = self.session.apply(req.delta)
        except DeltaApplyFailed as exc:
            req.error = exc
            self.updates_failed += 1
            self._resolve(req, FAILED, served)
            return
        self.updates_applied += 1
        self._resolve(req, APPLIED, served)

    def _resolve(self, req, status: str, served) -> None:
        """Move a request to its terminal status — exactly once, ever."""
        assert req.status == PENDING, \
            f"request resolved twice ({req.status} -> {status}): {req!r}"
        req.status = status
        served.append(req)

    def _serve_batch(self, reqs: List[QueryRequest]) -> None:
        """ONE session.run mixed batch; the planner fuses it into one
        compiled execution per (kind, automaton) group."""
        results = self.session.run([r.to_query() for r in reqs])
        for r, res in zip(reqs, results):
            r.result = res.distance if r.kind == "dist" else res.answer
            r.cache_version = res.cache_version
            r.degraded = res.degraded
        self.batches_run += 1

    # -- convenience -------------------------------------------------------

    def serve_pairs(self, pairs: Sequence[Tuple[int, int]],
                    kind: str = "reach", **kw) -> List[object]:
        """Submit + drain in one call; returns the results for ``pairs``
        only (any previously queued requests are served too, but their
        results stay on their own request objects)."""
        mine = [self.submit(s, t, kind=kind, **kw) for s, t in pairs]
        self.drain()
        return [r.result for r in mine]
