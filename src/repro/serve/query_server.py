"""Batched query-serving loop over a QuerySession (DESIGN.md Secs. 3.4 & 5).

Mirrors the LM ``ServeEngine`` slots model for graph queries: requests
accumulate in a queue and are drained in bounded-size chunks, each served
by ONE ``session.run`` mixed batch — the session's planner fuses every
chunk into one compiled execution per (kind, automaton) group, with batch
sizes padded to buckets so the engine never retraces under bursty traffic.
All three query classes are served, including regular path queries
(``kind="rpq"`` with a regex or a prebuilt automaton).

Dynamic graphs: ``submit_delta`` enqueues a :class:`GraphDelta` *into the
same queue*, so updates and queries interleave in submission order with
snapshot consistency — every query submitted before an update is answered
against the pre-delta cache (the drain loop flushes pending query batches
before applying an update; a batch never spans an update boundary), and
every query submitted after it sees the incrementally repaired cache.
Answers are stamped with the ``cache_version`` they were computed against.

The first ``submit``/``drain`` against a fresh Fragmentation pays the
amortized cache build; every batch after that is the cheap per-query
phase only, and updates cost an incremental repair instead of a rebuild.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

from ..core.automaton import QueryAutomaton
from ..core.fragments import Fragmentation, GraphDelta
from ..core.incremental import UpdateStats
from ..core.plan import Dist, Query, Reach, Rpq
from ..core.session import QuerySession, connect

VALID_KINDS = ("reach", "dist", "bounded", "rpq")


@dataclasses.dataclass
class QueryRequest:
    s: int
    t: int
    kind: str = "reach"              # one of VALID_KINDS
    bound: Optional[int] = None      # bounded queries only
    regex: Optional[str] = None      # rpq only (exactly one of regex /
    automaton: Optional[QueryAutomaton] = None     # automaton)
    result: object = None            # bool / int-or-None once served
    # rvset-cache version the answer was computed against (snapshot id)
    cache_version: Optional[int] = None

    def to_query(self) -> Query:
        if self.kind == "reach":
            return Reach(self.s, self.t)
        if self.kind == "dist":
            return Dist(self.s, self.t)
        if self.kind == "bounded":
            return Dist(self.s, self.t, bound=self.bound)
        return Rpq(self.s, self.t, regex=self.regex,
                   automaton=self.automaton)


@dataclasses.dataclass
class UpdateRequest:
    delta: GraphDelta
    result: Optional[UpdateStats] = None   # filled once applied


class QueryServer:
    """Bounded-batch continuous server over one (dynamic) Fragmentation."""

    def __init__(self, fr: Fragmentation, batch_size: int = 64,
                 warm: bool = True, with_dist: bool = False,
                 backend: str = "auto",
                 session: Optional[QuerySession] = None):
        """``with_dist=True`` eagerly builds the tropical cache too; the
        default leaves it to build lazily on the first dist/bounded query,
        so reach-only servers never pay for it.  Pass an existing
        ``session`` to share its caches/backend, or a ``backend`` name to
        open a fresh one (see :func:`repro.connect`)."""
        assert batch_size > 0
        self.fr = fr
        self.batch_size = batch_size
        self.with_dist = with_dist
        self.session = session or connect(fr, backend=backend)
        self._queue: List[Union[QueryRequest, UpdateRequest]] = []
        self.batches_run = 0
        self.updates_applied = 0
        if warm:
            self.session.warm(with_dist=with_dist)

    # -- request intake ----------------------------------------------------

    def submit(self, s: int, t: int, kind: str = "reach",
               bound: Optional[int] = None, regex: Optional[str] = None,
               automaton: Optional[QueryAutomaton] = None) -> QueryRequest:
        if kind not in VALID_KINDS:
            raise ValueError(f"unknown query kind {kind!r}; expected one "
                             f"of {VALID_KINDS}")
        if kind == "bounded" and bound is None:
            raise ValueError("bounded queries require a bound")
        if kind != "bounded" and bound is not None:
            raise ValueError(f"bound= is only valid for kind='bounded', "
                             f"not {kind!r}")
        if kind == "rpq" and (regex is None) == (automaton is None):
            raise ValueError("rpq queries require exactly one of regex= "
                             "or automaton=")
        if kind != "rpq" and (regex is not None or automaton is not None):
            raise ValueError(f"regex/automaton are only valid for "
                             f"kind='rpq', not {kind!r}")
        req = QueryRequest(int(s), int(t), kind, bound, regex, automaton)
        self._queue.append(req)
        return req

    def submit_delta(self, delta: GraphDelta) -> UpdateRequest:
        """Enqueue a graph update.  It is applied during ``drain`` in
        submission order: earlier queries see the pre-delta snapshot,
        later ones the repaired cache."""
        req = UpdateRequest(delta)
        self._queue.append(req)
        return req

    def pending(self) -> int:
        return len(self._queue)

    # -- serving loop ------------------------------------------------------

    def drain(self) -> List[Union[QueryRequest, UpdateRequest]]:
        """Serve the whole queue in submission order; returns the served
        requests with ``result`` filled in.  Queries are drained in
        bounded-size batches; an update first flushes the queries queued
        before it (snapshot consistency), then repairs the cache."""
        queue, self._queue = self._queue, []   # new submits go to a fresh
        served: List[Union[QueryRequest, UpdateRequest]] = []   # queue
        chunk: List[QueryRequest] = []         # never grows past batch_size

        def flush():
            while chunk:
                batch = chunk[: self.batch_size]
                self._serve_batch(batch)       # raises -> batch stays queued
                del chunk[: len(batch)]
                served.extend(batch)

        idx = 0                                # next queue element to handle
        try:
            while idx < len(queue):
                req = queue[idx]
                idx += 1
                if isinstance(req, UpdateRequest):
                    try:
                        flush()                # pre-delta queries answered
                    except Exception:
                        idx -= 1               # update untouched: retryable
                        raise
                    # a bad update is reported via the raised exception and
                    # dropped; everything queued after it survives
                    req.result = self.session.apply(req.delta)
                    self.updates_applied += 1
                    served.append(req)
                else:
                    chunk.append(req)
                    if len(chunk) >= self.batch_size:
                        flush()
            flush()
        except Exception:
            # unserved queries + the un-iterated tail stay queued for the
            # next drain (ahead of anything submitted meanwhile)
            self._queue[:0] = chunk + queue[idx:]
            raise
        return served

    def _serve_batch(self, reqs: List[QueryRequest]) -> None:
        """ONE session.run mixed batch; the planner fuses it into one
        compiled execution per (kind, automaton) group."""
        results = self.session.run([r.to_query() for r in reqs])
        for r, res in zip(reqs, results):
            r.result = res.distance if r.kind == "dist" else res.answer
            r.cache_version = res.cache_version
        self.batches_run += 1

    # -- convenience -------------------------------------------------------

    def serve_pairs(self, pairs: Sequence[Tuple[int, int]],
                    kind: str = "reach", **kw) -> List[object]:
        """Submit + drain in one call; returns the results for ``pairs``
        only (any previously queued requests are served too, but their
        results stay on their own request objects)."""
        mine = [self.submit(s, t, kind=kind, **kw) for s, t in pairs]
        self.drain()
        return [r.result for r in mine]
