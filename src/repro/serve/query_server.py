"""Batched reachability-query serving loop (DESIGN.md Sec. 3.4-3.5).

Mirrors the LM ``ServeEngine`` slots model for graph queries: requests
accumulate in a queue and are drained in fixed-size batches through ONE
jitted ``dis_reach_batch`` / ``dis_dist_batch`` call each (fixed batch
shape == one compiled program; short batches are padded with a repeat of
the last request, so the engine never retraces under bursty traffic).

Dynamic graphs: ``submit_delta`` enqueues a :class:`GraphDelta` *into the
same queue*, so updates and queries interleave in submission order with
snapshot consistency — every query submitted before an update is answered
against the pre-delta cache (the drain loop flushes pending query batches
before applying an update; a batch never spans an update boundary), and
every query submitted after it sees the incrementally repaired cache.

The first ``submit``/``drain`` against a fresh Fragmentation pays the
amortized rvset-cache build; every batch after that is the cheap per-query
phase only, and updates cost an incremental repair instead of a rebuild.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.cache import dis_dist_batch, dis_reach_batch, prepare_rvset_cache
from ..core.fragments import Fragmentation, GraphDelta
from ..core.incremental import UpdateStats, apply_delta


@dataclasses.dataclass
class QueryRequest:
    s: int
    t: int
    kind: str = "reach"              # "reach" | "dist" | "bounded"
    bound: Optional[int] = None
    result: object = None            # bool / int-or-None once served
    # rvset-cache version the answer was computed against (snapshot id)
    cache_version: Optional[int] = None


@dataclasses.dataclass
class UpdateRequest:
    delta: GraphDelta
    result: Optional[UpdateStats] = None   # filled once applied


class QueryServer:
    """Fixed-batch continuous server over one (dynamic) Fragmentation."""

    def __init__(self, fr: Fragmentation, batch_size: int = 64,
                 warm: bool = True, with_dist: bool = False):
        """``with_dist=True`` eagerly builds the tropical cache too;
        the default leaves it to build lazily on the first dist/bounded
        query, so reach-only servers never pay for it."""
        assert batch_size > 0
        self.fr = fr
        self.batch_size = batch_size
        self.with_dist = with_dist
        self._queue: List[Union[QueryRequest, UpdateRequest]] = []
        self.batches_run = 0
        self.updates_applied = 0
        if warm:
            prepare_rvset_cache(fr, with_dist=with_dist)

    # -- request intake ----------------------------------------------------

    def submit(self, s: int, t: int, kind: str = "reach",
               bound: Optional[int] = None) -> QueryRequest:
        assert kind in ("reach", "dist", "bounded")
        if kind == "bounded" and bound is None:
            raise ValueError("bounded queries require a bound")
        req = QueryRequest(int(s), int(t), kind, bound)
        self._queue.append(req)
        return req

    def submit_delta(self, delta: GraphDelta) -> UpdateRequest:
        """Enqueue a graph update.  It is applied during ``drain`` in
        submission order: earlier queries see the pre-delta snapshot,
        later ones the repaired cache."""
        req = UpdateRequest(delta)
        self._queue.append(req)
        return req

    def pending(self) -> int:
        return len(self._queue)

    # -- serving loop ------------------------------------------------------

    def drain(self) -> List[Union[QueryRequest, UpdateRequest]]:
        """Serve the whole queue in submission order; returns the served
        requests with ``result`` filled in.  Queries are drained in
        fixed-size batches; an update first flushes the queries queued
        before it (snapshot consistency), then repairs the cache."""
        queue, self._queue = self._queue, []   # new submits go to a fresh
        served: List[Union[QueryRequest, UpdateRequest]] = []   # queue
        chunk: List[QueryRequest] = []         # never grows past batch_size

        def flush():
            while chunk:
                batch = chunk[: self.batch_size]
                self._serve_batch(batch)       # raises -> batch stays queued
                del chunk[: len(batch)]
                served.extend(batch)

        idx = 0                                # next queue element to handle
        try:
            while idx < len(queue):
                req = queue[idx]
                idx += 1
                if isinstance(req, UpdateRequest):
                    try:
                        flush()                # pre-delta queries answered
                    except Exception:
                        idx -= 1               # update untouched: retryable
                        raise
                    # a bad update is reported via the raised exception and
                    # dropped; everything queued after it survives
                    req.result = apply_delta(self.fr, req.delta)
                    self.updates_applied += 1
                    served.append(req)
                else:
                    chunk.append(req)
                    if len(chunk) >= self.batch_size:
                        flush()
            flush()
        except Exception:
            # unserved queries + the un-iterated tail stay queued for the
            # next drain (ahead of anything submitted meanwhile)
            self._queue[:0] = chunk + queue[idx:]
            raise
        return served

    def _serve_batch(self, reqs: List[QueryRequest]) -> None:
        pad = self.batch_size - len(reqs)
        padded = reqs + [reqs[-1]] * pad          # repeat: no retrace
        pairs = np.array([(r.s, r.t) for r in padded], dtype=np.int64)
        # one jitted call per kind present in the batch
        kinds = {r.kind for r in reqs}
        if "reach" in kinds:
            ans = dis_reach_batch(self.fr, pairs)
            for i, r in enumerate(reqs):
                if r.kind == "reach":
                    r.result = bool(ans[i])
        if kinds & {"dist", "bounded"}:
            d = dis_dist_batch(self.fr, pairs)
            for i, r in enumerate(reqs):
                if r.kind == "dist":
                    r.result = None if d[i] < 0 else int(d[i])
                elif r.kind == "bounded":
                    r.result = bool(0 <= d[i] <= r.bound)
        version = self.fr.rvset_cache.version     # built by the calls above
        for r in reqs:
            r.cache_version = version
        self.batches_run += 1

    # -- convenience -------------------------------------------------------

    def serve_pairs(self, pairs: Sequence[Tuple[int, int]],
                    kind: str = "reach") -> List[object]:
        """Submit + drain in one call; returns the results for ``pairs``
        only (any previously queued requests are served too, but their
        results stay on their own request objects)."""
        mine = [self.submit(s, t, kind=kind) for s, t in pairs]
        self.drain()
        return [r.result for r in mine]
