"""Batched reachability-query serving loop (DESIGN.md Sec. 3.4).

Mirrors the LM ``ServeEngine`` slots model for graph queries: requests
accumulate in a queue and are drained in fixed-size batches through ONE
jitted ``dis_reach_batch`` / ``dis_dist_batch`` call each (fixed batch
shape == one compiled program; short batches are padded with a repeat of
the last request, so the engine never retraces under bursty traffic).

The first ``submit``/``drain`` against a fresh Fragmentation pays the
amortized rvset-cache build; every batch after that is the cheap per-query
phase only.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.cache import dis_dist_batch, dis_reach_batch, prepare_rvset_cache
from ..core.fragments import Fragmentation


@dataclasses.dataclass
class QueryRequest:
    s: int
    t: int
    kind: str = "reach"              # "reach" | "dist" | "bounded"
    bound: Optional[int] = None
    result: object = None            # bool / int-or-None once served


class QueryServer:
    """Fixed-batch continuous server over one Fragmentation."""

    def __init__(self, fr: Fragmentation, batch_size: int = 64,
                 warm: bool = True, with_dist: bool = False):
        """``with_dist=True`` eagerly builds the tropical cache too;
        the default leaves it to build lazily on the first dist/bounded
        query, so reach-only servers never pay for it."""
        assert batch_size > 0
        self.fr = fr
        self.batch_size = batch_size
        self.with_dist = with_dist
        self._queue: List[QueryRequest] = []
        self.batches_run = 0
        if warm:
            prepare_rvset_cache(fr, with_dist=with_dist)

    # -- request intake ----------------------------------------------------

    def submit(self, s: int, t: int, kind: str = "reach",
               bound: Optional[int] = None) -> QueryRequest:
        assert kind in ("reach", "dist", "bounded")
        if kind == "bounded" and bound is None:
            raise ValueError("bounded queries require a bound")
        req = QueryRequest(int(s), int(t), kind, bound)
        self._queue.append(req)
        return req

    def pending(self) -> int:
        return len(self._queue)

    # -- serving loop ------------------------------------------------------

    def drain(self) -> List[QueryRequest]:
        """Serve the whole queue in fixed-size batches; returns the served
        requests with ``result`` filled in, in submission order."""
        served: List[QueryRequest] = []
        while self._queue:
            chunk = self._queue[: self.batch_size]
            del self._queue[: len(chunk)]
            self._serve_batch(chunk)
            served.extend(chunk)
        return served

    def _serve_batch(self, reqs: List[QueryRequest]) -> None:
        pad = self.batch_size - len(reqs)
        padded = reqs + [reqs[-1]] * pad          # repeat: no retrace
        pairs = np.array([(r.s, r.t) for r in padded], dtype=np.int64)
        # one jitted call per kind present in the batch
        kinds = {r.kind for r in reqs}
        if "reach" in kinds:
            ans = dis_reach_batch(self.fr, pairs)
            for i, r in enumerate(reqs):
                if r.kind == "reach":
                    r.result = bool(ans[i])
        if kinds & {"dist", "bounded"}:
            d = dis_dist_batch(self.fr, pairs)
            for i, r in enumerate(reqs):
                if r.kind == "dist":
                    r.result = None if d[i] < 0 else int(d[i])
                elif r.kind == "bounded":
                    r.result = bool(0 <= d[i] <= r.bound)
        self.batches_run += 1

    # -- convenience -------------------------------------------------------

    def serve_pairs(self, pairs: Sequence[Tuple[int, int]],
                    kind: str = "reach") -> List[object]:
        """Submit + drain in one call; returns the results for ``pairs``
        only (any previously queued requests are served too, but their
        results stay on their own request objects)."""
        mine = [self.submit(s, t, kind=kind) for s, t in pairs]
        self.drain()
        return [r.result for r in mine]
