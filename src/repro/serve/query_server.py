"""Futures-based query server over a QuerySession (DESIGN.md Secs. 3.4,
5, 7 & 8).

:class:`QueryServer` is the intake layer of the continuous-batching
stack: it validates and admits requests (PR-7 admission lanes, RED
rejection) and hands them to the :class:`~repro.serve.engine
.AsyncQueryEngine`, which forms fused (kind, automaton) batches from
whatever is pending and executes each as ONE ``session.run`` on the
shared session.  ``submit`` returns a :class:`~repro.serve.engine
.QueryFuture` immediately; ``submit_delta`` an :class:`~repro.serve
.engine.UpdateFuture` that fences the queue as a snapshot barrier.

Two serving modes:

* **continuous** (``start=True``, default): a background scheduler
  thread serves as load arrives; callers block on
  ``future.result(timeout=)`` only for their own answers, so concurrent
  submitters overlap instead of serializing.
* **deferred** (``start=False``): nothing runs until :meth:`flush`,
  which executes the same scheduling loop inline — fully deterministic,
  what the chaos/deadline tests and the legacy ``drain()`` path use.

The PR-7 robustness stack carries over unchanged (admission lanes,
deadlines with partial-bucket shipping, retry/bisect/dead-letter, delta
rollback, degraded fallback); see :mod:`repro.serve.engine` for the
scheduling model and :mod:`repro.serve.telemetry` for the live
p50/p95/p99 / qps / occupancy / lane-depth feed behind
:meth:`QueryServer.telemetry`.

``drain()`` — the PR-7 synchronous API — survives as a deprecated
compatibility wrapper around :meth:`flush`.
"""
from __future__ import annotations

import time
import warnings
from typing import Callable, List, Optional, Sequence, Tuple

from ..core.automaton import QueryAutomaton
from ..core.fragments import Fragmentation, GraphDelta
from ..core.plan import Rpq
from ..core.session import QuerySession, connect
from ..errors import QueryTooExpensive, Status
from .admission import AdmissionPolicy, estimate_cost
from .engine import (AsyncQueryEngine, QueryFuture, RetryPolicy,
                     UpdateFuture)
from .faults import FaultInjector
from .telemetry import Telemetry

VALID_KINDS = ("reach", "dist", "bounded", "rpq")

# PR-7 string statuses — now values of the one Status enum (Status is a
# str subclass, so e.g. DONE == Status.DONE == "done" all hold)
PENDING = Status.PENDING
DONE = Status.DONE
DEAD_LETTER = Status.DEAD_LETTER
DEADLINE = Status.DEADLINE
APPLIED = Status.APPLIED
FAILED = Status.FAILED

# PR-7 names for the request records; submissions now return futures
# with the same attribute surface (s/t/kind/lane/status/error/attempts/
# cache_version, and `.value` where the old mutable `.result` field was)
QueryRequest = QueryFuture
UpdateRequest = UpdateFuture


class QueryServer:
    """Continuous-batching fault-tolerant server over one (dynamic)
    Fragmentation."""

    def __init__(self, fr: Fragmentation, batch_size: int = 64,
                 warm: bool = True, with_dist: bool = False,
                 backend: str = "auto",
                 session: Optional[QuerySession] = None,
                 admission: Optional[AdmissionPolicy] = None,
                 retry: Optional[RetryPolicy] = None,
                 chaos: Optional[FaultInjector] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 ship_margin_ms: float = 25.0,
                 batch_wait_ms: float = 2.0,
                 start: bool = True,
                 telemetry_window: int = 2048,
                 mvcc: bool = False,
                 versions: int = 4,
                 dead_letter_cap: Optional[int] = 256):
        """``with_dist=True`` eagerly builds the tropical cache too; the
        default leaves it to build lazily on the first dist/bounded query,
        so reach-only servers never pay for it.  Pass an existing
        ``session`` to share its caches/backend with other servers (the
        session serializes group execution), or a ``backend`` name to
        open a fresh one (see :func:`repro.connect`).

        ``admission`` defaults to :meth:`AdmissionPolicy.for_fragmentation`
        (meaningful lanes, no rejection); ``retry`` to a 3-attempt capped
        backoff.  ``chaos`` threads a
        :class:`~repro.serve.faults.FaultInjector` through the session.
        ``clock``/``sleep`` are injectable for deterministic deadline and
        backoff tests; ``ship_margin_ms`` is how close to the oldest
        deadline the scheduler ships a partially-full bucket, and
        ``batch_wait_ms`` how long it lets a partial bucket wait for
        batchmates before shipping anyway (the latency/occupancy knob).

        ``start=False`` skips the scheduler thread: requests defer until
        :meth:`flush` (deterministic mode).

        ``mvcc=True`` serves reads from an MVCC snapshot store
        (:class:`~repro.core.versions.VersionedCacheStore`, keeping up to
        ``versions`` snapshots live): deltas commit as copy-on-write
        versions on a dedicated repair worker while query chunks keep
        running against the pinned head — no scheduler barriers, reads
        never wait for a repair (DESIGN.md Sec. 9).  The default
        (``False``) keeps the PR-8 barrier semantics, where a delta
        fences the queue.  ``dead_letter_cap`` bounds the retained
        dead-letter list (oldest evicted and counted; ``None`` =
        unbounded)."""
        assert batch_size > 0
        self.fr = fr
        self.with_dist = with_dist
        self.session = session or connect(fr, backend=backend, chaos=chaos)
        if session is not None and chaos is not None:
            session.chaos = chaos
        self.admission = admission or AdmissionPolicy.for_fragmentation(fr)
        self._clock = clock
        self.rejected = 0         # RED-lane submissions refused
        if warm:
            self.session.warm(with_dist=with_dist)
        self.store = None
        if mvcc:
            from ..core.versions import VersionedCacheStore
            self.store = VersionedCacheStore(self.session,
                                             capacity=versions)
        self.engine = AsyncQueryEngine(
            self.session, batch_size=batch_size,
            retry=retry or RetryPolicy(), clock=clock, sleep=sleep,
            ship_margin_s=ship_margin_ms / 1e3,
            batch_wait_s=batch_wait_ms / 1e3,
            telemetry=Telemetry(window=telemetry_window),
            store=self.store, dead_letter_cap=dead_letter_cap)
        if start:
            self.engine.start()

    # -- request intake ----------------------------------------------------

    def submit(self, s: int, t: int, kind: str = "reach",
               bound: Optional[int] = None, regex: Optional[str] = None,
               automaton: Optional[QueryAutomaton] = None,
               deadline_ms: Optional[float] = None) -> QueryFuture:
        """Validate, admit, and enqueue one query; returns its
        :class:`~repro.serve.engine.QueryFuture` immediately.

        Raises ``ValueError`` on malformed arguments (unknown kind, bad
        kind/arg combination, endpoint outside ``[0, n)``) and
        :class:`~repro.errors.QueryTooExpensive` when admission control
        rejects the query; neither leaves anything queued.
        ``deadline_ms`` gives the request a latency budget measured from
        now; an expired request resolves ``DEADLINE`` instead of being
        served arbitrarily late."""
        if kind not in VALID_KINDS:
            raise ValueError(f"unknown query kind {kind!r}; expected one "
                             f"of {VALID_KINDS}")
        if kind == "bounded" and bound is None:
            raise ValueError("bounded queries require a bound")
        if kind != "bounded" and bound is not None:
            raise ValueError(f"bound= is only valid for kind='bounded', "
                             f"not {kind!r}")
        if kind == "rpq" and (regex is None) == (automaton is None):
            raise ValueError("rpq queries require exactly one of regex= "
                             "or automaton=")
        if kind != "rpq" and (regex is not None or automaton is not None):
            raise ValueError(f"regex/automaton are only valid for "
                             f"kind='rpq', not {kind!r}")
        s, t = int(s), int(t)
        n = self.fr.g.n
        for name, v in (("s", s), ("t", t)):
            if not 0 <= v < n:
                raise ValueError(
                    f"query endpoint {name}={v} is out of range for a "
                    f"graph with {n} nodes (valid ids: 0..{n - 1})")
        lane, cost = self._admit(kind, s, t, regex, automaton)
        deadline = (None if deadline_ms is None
                    else self._clock() + deadline_ms / 1e3)
        fut = QueryFuture(s, t, kind, bound, regex, automaton,
                          lane=lane, cost=cost, deadline=deadline)
        return self.engine.submit(fut)

    def _admit(self, kind: str, s: int, t: int, regex, automaton):
        """Admission decision: (lane, cost estimate).  Raises
        :class:`~repro.errors.QueryTooExpensive` for the RED lane."""
        states, cached = 1, True
        if kind == "rpq":
            qa = automaton
            if qa is None:
                qa = self.session._resolve_automaton(Rpq(s, t, regex=regex))
            states = qa.n_states
            # price against the cache the query will actually run on: the
            # head version's in MVCC mode, the shared one otherwise
            fr = self.store.head().fr if self.store is not None else self.fr
            c = fr.rvset_cache
            cached = c is not None and qa.cache_key() in c.rpq_closures
        cost = estimate_cost(self.fr, kind, states=states,
                             closure_cached=cached)
        try:
            lane = self.admission.admit(kind, cost)
        except QueryTooExpensive:
            self.rejected += 1
            raise
        return lane, cost

    def submit_delta(self, delta: GraphDelta) -> UpdateFuture:
        """Enqueue a graph update; returns its :class:`~repro.serve
        .engine.UpdateFuture` immediately.

        Default mode: the delta is a snapshot barrier — queries submitted
        before it are served against the pre-delta cache, queries after
        it wait for the repaired cache (or, if the delta fails and rolls
        back, resume against the unchanged pre-delta cache).

        MVCC mode (``mvcc=True``): the delta repairs **concurrently** on
        the repair worker and never fences the queue; it becomes visible
        to new batches exactly when its version publishes (the commit
        point is ``future.result()``), and a failed delta is dropped
        while the head keeps serving."""
        return self.engine.submit_update(UpdateFuture(delta))

    def pending(self) -> int:
        """Submitted-but-unresolved request count."""
        return self.engine.backlog()

    # -- serving -----------------------------------------------------------

    def flush(self) -> List[object]:
        """Synchronous barrier: serve everything submitted before this
        call; returns those futures in resolution order, each holding a
        terminal ``status`` and a ``value``/``error``."""
        return self.engine.flush()

    def drain(self) -> List[object]:
        """Deprecated PR-7 API: alias of :meth:`flush`.

        .. deprecated:: PR 8
           Submissions return awaitable futures now — block on
           ``future.result(timeout=)`` for individual answers, or call
           :meth:`flush` where a full synchronous barrier is really
           wanted.
        """
        warnings.warn(
            "QueryServer.drain() is deprecated: submissions return "
            "futures now; use future.result(timeout=) for per-request "
            "answers or QueryServer.flush() for a synchronous barrier",
            DeprecationWarning, stacklevel=2)
        return self.flush()

    def close(self, drain: bool = True) -> None:
        """Stop the scheduler thread (serving the backlog first unless
        ``drain=False``).  Idempotent; deferred-mode servers just flush."""
        self.engine.stop(drain=drain)

    def __enter__(self) -> "QueryServer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(drain=exc_type is None)

    # -- introspection -----------------------------------------------------

    def telemetry(self) -> dict:
        """Live serving dashboard: p50/p95/p99 latency per route
        (kind/lane), queries/sec, batch occupancy, lane depths, status
        counts (see :class:`~repro.serve.telemetry.Telemetry`); in MVCC
        mode also an ``"mvcc"`` gauge block — live version count, pinned
        readers per version, repair-queue depth, versions
        committed/dropped/evicted."""
        return self.engine.telemetry.snapshot(
            lane_depths=self.engine.depths(),
            gauges=self.engine.mvcc_gauges())

    @property
    def batch_size(self) -> int:
        return self.engine.batch_size

    @property
    def dead_letters(self) -> List[QueryFuture]:
        """Retained dead-lettered requests, oldest first (a list copy of
        the engine's capped buffer — at most ``dead_letter_cap``)."""
        return list(self.engine.dead_letters)

    @property
    def dead_letters_evicted(self) -> int:
        """Dead-lettered requests dropped by the retention cap."""
        return self.engine.dead_letters_evicted

    @property
    def batches_run(self) -> int:
        return self.engine.batches_run

    @property
    def retries(self) -> int:
        return self.engine.retries

    @property
    def updates_applied(self) -> int:
        return self.engine.updates_applied

    @property
    def updates_failed(self) -> int:
        return self.engine.updates_failed

    # -- convenience -------------------------------------------------------

    def serve_pairs(self, pairs: Sequence[Tuple[int, int]],
                    kind: str = "reach", **kw) -> List[object]:
        """Submit a batch of ``(s, t)`` pairs and block for their answers
        (raising the typed error if one fails terminally).  In deferred
        mode this flushes the whole queue first."""
        mine = [self.submit(s, t, kind=kind, **kw) for s, t in pairs]
        if not self.engine.running:
            self.engine.flush()
        return [f.result() for f in mine]
