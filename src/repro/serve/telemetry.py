"""Live serving telemetry for the continuous-batching engine
(DESIGN.md Sec. 8.4).

The scheduler records one sample per resolved future and one sample per
executed batch; :meth:`Telemetry.snapshot` folds those into the serving
dashboard numbers: p50/p95/p99 latency per route (a route is
``"<kind>/<lane>"`` for queries, ``"update"`` for deltas), overall
queries/sec over the sliding window, mean batch occupancy (formed chunk
size over the configured batch size — how full the fused buckets ship),
and the per-lane queue depths the engine passes in.

Everything is windowed (bounded deques), so a long-running server's
telemetry stays O(window) no matter how many requests it has served, and
every recorder takes one short lock, so submitter threads, the scheduler
thread, and snapshot readers never block each other for long.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, List, Optional


def percentile(samples: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 1]) of an unsorted sample list."""
    if not samples:
        return 0.0
    ordered = sorted(samples)
    idx = min(len(ordered) - 1, int(q * len(ordered)))
    return ordered[idx]


class Telemetry:
    """Sliding-window latency/throughput/occupancy recorder.

    ``window`` bounds the number of retained samples per route and the
    throughput/occupancy windows.  ``clock`` only times the qps window
    (latencies are measured by the engine, which may run on a fake clock
    in tests; throughput is always wall-clock).
    """

    def __init__(self, window: int = 2048, clock=time.monotonic):
        self.window = int(window)
        self._clock = clock
        self._lock = threading.Lock()
        # route -> deque of latencies in seconds
        self._latency: Dict[str, deque] = {}
        # resolve timestamps (wall clock) for the qps window
        self._events: deque = deque(maxlen=self.window)
        # (chunk_size, batch_size) per executed batch
        self._batches: deque = deque(maxlen=self.window)
        # terminal status -> count, over the server's whole lifetime
        self.status_counts: Dict[str, int] = {}
        self.resolved = 0

    # -- recorders (called by the engine) ---------------------------------

    def record(self, route: str, latency_s: Optional[float],
               status) -> None:
        """One future reached a terminal status."""
        with self._lock:
            self.resolved += 1
            key = str(status)
            self.status_counts[key] = self.status_counts.get(key, 0) + 1
            self._events.append(self._clock())
            if latency_s is not None:
                lane = self._latency.get(route)
                if lane is None:
                    lane = self._latency[route] = deque(maxlen=self.window)
                lane.append(float(latency_s))

    def record_batch(self, chunk_size: int, batch_size: int) -> None:
        """One fused chunk was executed."""
        with self._lock:
            self._batches.append((int(chunk_size), max(1, int(batch_size))))

    # -- readers -----------------------------------------------------------

    def snapshot(self, lane_depths: Optional[Dict[str, int]] = None,
                 gauges: Optional[Dict] = None) -> Dict:
        """One coherent dashboard sample (plain dict, json-serializable).

        ``gauges``: live MVCC gauges from
        :meth:`AsyncQueryEngine.mvcc_gauges` (version/pin/repair-queue
        state) — included under ``"mvcc"`` when the server runs in MVCC
        mode, absent otherwise."""
        with self._lock:
            routes = {}
            for route, lane in self._latency.items():
                ms = [s * 1e3 for s in lane]
                routes[route] = {
                    "count": len(ms),
                    "p50_ms": percentile(ms, 0.50),
                    "p95_ms": percentile(ms, 0.95),
                    "p99_ms": percentile(ms, 0.99),
                }
            if len(self._events) >= 2:
                span = self._events[-1] - self._events[0]
                qps = (len(self._events) - 1) / span if span > 0 else 0.0
            else:
                qps = 0.0
            if self._batches:
                occupancy = (sum(c / b for c, b in self._batches)
                             / len(self._batches))
            else:
                occupancy = 0.0
            out = {
                "resolved": self.resolved,
                "qps": qps,
                "batches": len(self._batches),
                "batch_occupancy": occupancy,
                "lane_depths": dict(lane_depths or {}),
                "routes": routes,
                "statuses": dict(self.status_counts),
            }
            if gauges is not None:
                out["mvcc"] = dict(gauges)
            return out
