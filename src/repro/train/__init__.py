from .trainer import Trainer, TrainerConfig, reshard
from . import compression

__all__ = ["Trainer", "TrainerConfig", "reshard", "compression"]
