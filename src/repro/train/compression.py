"""Gradient compression for the data-parallel all-reduce.

Int8 quantization with error feedback (1-bit-Adam-style residual carry):
each step the gradient is quantized per-leaf with a single f32 scale, the
quantization error is added back into the next step's gradient, so the
*accumulated* update stays unbiased.  On a real mesh the int8 payload is
what crosses ICI (8x wire reduction vs f32); ``compressed_psum`` shows the
shard_map form.  The simulation path (``compress_decompress``) applies the
same arithmetic without a mesh so single-host tests exercise the error
dynamics.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize_leaf(g, err):
    g32 = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = g32 - deq
    return q, scale, deq, new_err


def init_error(params) -> Any:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)


def compress_decompress(grads, err) -> Tuple[Any, Any]:
    """Returns (dequantized grads, new error feedback state)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    outs = [_quantize_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    deq = tdef.unflatten([o[2] for o in outs])
    new_err = tdef.unflatten([o[3] for o in outs])
    return deq, new_err


def compressed_psum(g, axis_name: str, err):
    """shard_map form: quantize -> int32 psum of int8 payload -> dequant.
    Scales are psum'd too (tiny); wire payload is the int8 tensor."""
    g32 = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    local_deq = q.astype(jnp.float32) * scale
    new_err = g32 - local_deq
    total = jax.lax.psum(q.astype(jnp.int32) * 1, axis_name).astype(jnp.float32)
    # every shard contributed its own scale; use the psum'd per-shard scaled
    # payloads: sum_i q_i * scale_i == psum(q * scale) -- do scale inside
    total_scaled = jax.lax.psum(local_deq, axis_name)
    del total
    return total_scaled, new_err
