"""Fault-tolerant training loop.

Production concerns implemented here (and exercised by tests):
  * checkpoint/restart: periodic async checkpoints via ckpt.CheckpointManager;
    ``run`` recovers from a step-level failure by restoring the last
    checkpoint and *replaying the data stream* (the loader is
    step-indexed, so recovery is bitwise-deterministic);
  * gradient accumulation / microbatching (lax.scan over chunks);
  * optional int8 gradient compression with error feedback;
  * straggler mitigation: per-step wall-time watermark — steps slower than
    ``straggler_factor`` x EMA are counted and surfaced via metrics (on a
    synchronous SPMD pod the remedy is checkpoint-replace, which is exactly
    the restart path above; the hook lets a cluster agent trigger it);
  * elastic re-meshing: ``reshard`` moves the state onto a new mesh/sharding
    when the device pool changes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..ckpt import CheckpointManager
from ..optim import adamw
from . import compression


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    ckpt_async: bool = True
    keep_ckpts: int = 3
    grad_accum: int = 1
    compress_grads: bool = False
    straggler_factor: float = 3.0
    max_restarts: int = 2


class Trainer:
    def __init__(self, cfg: TrainerConfig, opt_cfg: adamw.AdamWConfig,
                 loss_fn: Callable, params: Any):
        """loss_fn(params, batch) -> scalar loss."""
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.loss_fn = loss_fn
        self.state = dict(params=params, opt=adamw.init(params),
                          step=jnp.zeros((), jnp.int32))
        if cfg.compress_grads:
            self.state["err"] = compression.init_error(params)
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep_ckpts)
        self._step_fn = jax.jit(self._build_step())
        self._ema = None
        self.straggler_events = 0

    # -- jitted step -----------------------------------------------------------
    def _build_step(self):
        accum = self.cfg.grad_accum
        compress = self.cfg.compress_grads
        loss_fn, opt_cfg = self.loss_fn, self.opt_cfg

        def step(state, batch):
            params = state["params"]
            if accum > 1:
                def micro(c, mb):
                    l, g = jax.value_and_grad(loss_fn)(params, mb)
                    return (c[0] + l, jax.tree.map(jnp.add, c[1], g)), None
                zero = jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), params)
                (loss, grads), _ = jax.lax.scan(
                    micro, (jnp.float32(0), zero), batch)
                loss = loss / accum
                grads = jax.tree.map(lambda g: g / accum, grads)
            else:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            new_state = dict(state)
            if compress:
                grads, new_state["err"] = compression.compress_decompress(
                    grads, state["err"])
            params, opt, metrics = adamw.update(opt_cfg, grads, state["opt"],
                                                params)
            new_state.update(params=params, opt=opt, step=state["step"] + 1)
            metrics["loss"] = loss
            return new_state, metrics

        return step

    # -- fault-tolerant outer loop ----------------------------------------------
    def run(self, data_fn: Callable[[int], Any], n_steps: int,
            fail_hook: Optional[Callable[[int], None]] = None
            ) -> Dict[str, float]:
        """data_fn(step) -> batch (deterministic, replayable).
        fail_hook (tests): may raise at a given step to simulate a node
        failure; the loop restores and replays."""
        restarts = 0
        metrics: Dict[str, float] = {}
        while int(self.state["step"]) < n_steps:
            step = int(self.state["step"])
            try:
                if fail_hook is not None:
                    fail_hook(step)
                t0 = time.perf_counter()
                batch = data_fn(step)
                self.state, m = self._step_fn(self.state, batch)
                jax.block_until_ready(self.state["params"])
                dt = time.perf_counter() - t0
                self._track_straggler(dt)
                metrics = {k: float(v) for k, v in m.items()}
                new_step = step + 1
                if new_step % self.cfg.ckpt_every == 0 or new_step == n_steps:
                    self.ckpt.save(new_step, self.state,
                                   blocking=not self.cfg.ckpt_async)
            except Exception:
                restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise
                self.restore()
        self.ckpt.wait()
        metrics["restarts"] = restarts
        metrics["straggler_events"] = self.straggler_events
        return metrics

    def restore(self) -> None:
        self.ckpt.wait()
        last = self.ckpt.latest_step()
        if last is not None:
            tree = self.ckpt.restore(last)
            self.state = jax.tree.map(jnp.asarray, tree)

    def _track_straggler(self, dt: float) -> None:
        if self._ema is None:
            self._ema = dt
        else:
            if dt > self.cfg.straggler_factor * self._ema:
                self.straggler_events += 1
            self._ema = 0.9 * self._ema + 0.1 * dt


def reshard(tree: Any, mesh, pspec_fn: Callable[[str, Any], Any]) -> Any:
    """Elastic scaling: place ``tree`` onto ``mesh`` with per-leaf specs
    from pspec_fn(path, leaf) — used when the device pool grows/shrinks."""
    from jax.sharding import NamedSharding

    def place(path, leaf):
        spec = pspec_fn(jax.tree_util.keystr(path), leaf)
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(place, tree)
