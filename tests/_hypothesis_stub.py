"""Minimal deterministic fallback for ``hypothesis`` (used when the real
package is not installed; see conftest.py).

Implements just the surface this test-suite uses — ``given``, ``settings``,
and the ``strategies`` entries ``integers``, ``lists``, ``sampled_from``,
``booleans``, ``data`` — as a seeded pseudo-random example generator.  Each
test function gets a deterministic stream derived from its name, so runs
are reproducible.  No shrinking, no database; with real hypothesis
installed (CI) this module is never imported.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw_fn, name="strategy"):
        self._draw = draw_fn
        self._name = name

    def example_from(self, rng: random.Random):
        return self._draw(rng)

    def __repr__(self):
        return f"<stub {self._name}>"


def integers(min_value=0, max_value=1 << 30):
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     f"integers({min_value},{max_value})")


def booleans():
    return _Strategy(lambda rng: bool(rng.getrandbits(1)), "booleans")


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))], "sampled_from")


def lists(elements, min_size=0, max_size=10):
    def draw(rng):
        size = rng.randint(min_size, max_size)
        return [elements.example_from(rng) for _ in range(size)]
    return _Strategy(draw, f"lists[{min_size},{max_size}]")


class DataObject:
    """Stand-in for hypothesis' interactive data strategy."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.example_from(self._rng)


def data():
    return _Strategy(lambda rng: DataObject(rng), "data")


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*g_args, **g_kwargs):
    assert not g_args, "stub given() supports keyword strategies only"

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            base = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = random.Random((base << 16) ^ i)
                drawn = {k: s.example_from(rng) for k, s in g_kwargs.items()}
                fn(*args, **kwargs, **drawn)

        # hide the drawn parameters from pytest's fixture resolution
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items()
                  if name not in g_kwargs]
        wrapper.__signature__ = sig.replace(parameters=params)
        del wrapper.__wrapped__
        return wrapper
    return deco


class _StrategiesModule:
    integers = staticmethod(integers)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)
    data = staticmethod(data)


def install(sys_modules) -> None:
    """Register this stub as ``hypothesis`` / ``hypothesis.strategies``."""
    import types

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "sampled_from", "lists", "data"):
        setattr(strategies, name, globals()[name])
    hyp.strategies = strategies
    hyp.__stub__ = True
    sys_modules["hypothesis"] = hyp
    sys_modules["hypothesis.strategies"] = strategies
