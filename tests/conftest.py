"""Test config. NOTE: do NOT set XLA_FLAGS / fake device counts here —
smoke tests must see the single real CPU device.  Multi-device tests
spawn subprocesses that set XLA_FLAGS before importing jax."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))
