"""Test config. NOTE: do NOT set XLA_FLAGS / fake device counts here —
smoke tests must see the single real CPU device.  Multi-device tests
spawn subprocesses that set XLA_FLAGS before importing jax."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# Property tests use hypothesis when installed; otherwise fall back to the
# deterministic stub so the suite still runs in hermetic environments.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub
    _hypothesis_stub.install(sys.modules)


# jaxlib 0.4.x's CPU JIT sporadically segfaults in backend_compile once a
# single process has accumulated enough live compiled executables (seen at
# ~200 suite tests; reproducible at pristine checkouts, crash point moves
# with compile count).  Dropping the caches between modules keeps the live
# executable set small; each module only pays its own warm-up again.
import pytest  # noqa: E402


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    import jax
    jax.clear_caches()


@pytest.fixture(autouse=True)
def _runtime_lock_order(request):
    """Under the chaos/mvcc suites, run every session/store/engine built
    by the test on instrumented locks and fail on any acquisition-order
    inversion (DESIGN.md Sec. 10.3, rules LCK001-003)."""
    marks = {m.name for m in request.node.iter_markers()}
    if not marks & {"chaos", "mvcc"}:
        yield
        return
    from repro.analysis.locks import monitored
    with monitored() as mon:
        yield
    assert not mon.violations, [str(v) for v in mon.violations]
