"""Test config. NOTE: do NOT set XLA_FLAGS / fake device counts here —
smoke tests must see the single real CPU device.  Multi-device tests
spawn subprocesses that set XLA_FLAGS before importing jax."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# Property tests use hypothesis when installed; otherwise fall back to the
# deterministic stub so the suite still runs in hermetic environments.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub
    _hypothesis_stub.install(sys.modules)
