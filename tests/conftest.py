"""Test config. NOTE: do NOT set XLA_FLAGS / fake device counts here —
smoke tests must see the single real CPU device.  Multi-device tests
spawn subprocesses that set XLA_FLAGS before importing jax."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# Property tests use hypothesis when installed; otherwise fall back to the
# deterministic stub so the suite still runs in hermetic environments.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub
    _hypothesis_stub.install(sys.modules)


# jaxlib 0.4.x's CPU JIT sporadically segfaults in backend_compile once a
# single process has accumulated enough live compiled executables (seen at
# ~200 suite tests; reproducible at pristine checkouts, crash point moves
# with compile count).  Dropping the caches between modules keeps the live
# executable set small; each module only pays its own warm-up again.
import pytest  # noqa: E402


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    import jax
    jax.clear_caches()
