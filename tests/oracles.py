"""Host oracles used by the test-suite (networkx + pure python)."""
from __future__ import annotations

from collections import deque

import networkx as nx

from repro.core.automaton import L_S, L_T, L_WILD


def nx_digraph(g):
    G = nx.DiGraph()
    G.add_nodes_from(range(g.n))
    G.add_edges_from(zip(g.src.tolist(), g.dst.tolist()))
    return G


def oracle_reach(g, s, t) -> bool:
    return nx.has_path(nx_digraph(g), s, t)


def oracle_dist(g, s, t):
    try:
        return nx.shortest_path_length(nx_digraph(g), s, t)
    except nx.NetworkXNoPath:
        return None


def oracle_rpq(g, s, t, qa) -> bool:
    """Product-automaton BFS over (node, state)."""
    if s == t:
        return bool(qa.nullable)
    adj = [[] for _ in range(g.n)]
    for u, v in zip(g.src.tolist(), g.dst.tolist()):
        adj[u].append(v)

    def match(v, q):
        lq = qa.state_labels[q]
        if lq >= 0:
            return g.labels[v] == lq
        if lq == L_WILD:
            return True
        if lq == L_S:
            return v == s
        if lq == L_T:
            return v == t
        return False

    start = (s, 0)
    seen = {start}
    dq = deque([start])
    while dq:
        v, q = dq.popleft()
        for v2 in adj[v]:
            for q2 in range(qa.n_states):
                if qa.trans[q, q2] and match(v2, q2):
                    if v2 == t and q2 == qa.final:
                        return True
                    if (v2, q2) not in seen:
                        seen.add((v2, q2))
                        dq.append((v2, q2))
    return False
