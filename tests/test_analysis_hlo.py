"""repro.analysis.hlo_check: the structured HLO/StableHLO parser and the
HLO001-HLO004 invariant checks (DESIGN.md Sec. 10.1).

Golden snippets mirror real jax 0.4.x output: the quoted generic form for
region-carrying StableHLO ops, the pretty ``stablehlo.while`` spelling,
and the compiled HLO dialect with ``-start``/``-done`` async pairs.  The
negative tests inject exactly the failures the pass exists to catch — a
second collective, a looped collective, a wrong payload, and a
|V|-scaling operand on the wire — and assert each is reported.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import check_program, parse_program

# --- golden StableHLO (lowered, unoptimized) -------------------------------

SHLO_ONE_COLLECTIVE = """
module @jit_batch attributes {mhlo.num_partitions = 8 : i32} {
  func.func public @main(%arg0: tensor<48x2xui32>) -> tensor<48x2xui32> {
    %0 = "stablehlo.all_reduce"(%arg0) <{channel_handle =
        #stablehlo.channel_handle<handle = 1, type = 1>, replica_groups =
        dense<[[0, 1, 2, 3, 4, 5, 6, 7]]> : tensor<1x8xi64>,
        use_global_device_ids}> ({
    ^bb0(%a: tensor<ui32>, %b: tensor<ui32>):
      %9 = stablehlo.or %a, %b : tensor<ui32>
      stablehlo.return %9 : tensor<ui32>
    }) : (tensor<48x2xui32>) -> tensor<48x2xui32>
    return %0 : tensor<48x2xui32>
  }
}
"""

SHLO_LOOPED = """
module @jit_loop {
  func.func public @main(%arg0: tensor<8x4xi32>) -> tensor<8x4xi32> {
    %c = stablehlo.constant dense<0> : tensor<i32>
    %1:2 = stablehlo.while(%iterArg = %arg0, %i = %c)
        : tensor<8x4xi32>, tensor<i32>
     cond {
      %2 = stablehlo.compare LT, %i, %i : (tensor<i32>, tensor<i32>)
          -> tensor<i1>
      stablehlo.return %2 : tensor<i1>
    } do {
      %3 = "stablehlo.all_gather"(%iterArg) <{all_gather_dim = 0 : i64,
          replica_groups = dense<[[0, 1]]> : tensor<1x2xi64>}> :
          (tensor<8x4xi32>) -> tensor<8x4xi32>
      stablehlo.return %3, %i : tensor<8x4xi32>, tensor<i32>
    }
    return %1#0 : tensor<8x4xi32>
  }
}
"""

SHLO_CALLED_IN_LOOP = """
module @jit_call {
  func.func private @shout(%arg0: tensor<4xf32>) -> tensor<4xf32> {
    %0 = "stablehlo.all_reduce"(%arg0) <{replica_groups =
        dense<[[0, 1]]> : tensor<1x2xi64>}> ({
    ^bb0(%a: tensor<f32>, %b: tensor<f32>):
      %9 = stablehlo.add %a, %b : tensor<f32>
      stablehlo.return %9 : tensor<f32>
    }) : (tensor<4xf32>) -> tensor<4xf32>
    return %0 : tensor<4xf32>
  }
  func.func public @main(%arg0: tensor<4xf32>) -> tensor<4xf32> {
    %c = stablehlo.constant dense<0> : tensor<i32>
    %1:2 = stablehlo.while(%iterArg = %arg0, %i = %c)
        : tensor<4xf32>, tensor<i32>
     cond {
      %2 = stablehlo.compare LT, %i, %i : (tensor<i32>, tensor<i32>)
          -> tensor<i1>
      stablehlo.return %2 : tensor<i1>
    } do {
      %3 = func.call @shout(%iterArg) : (tensor<4xf32>) -> tensor<4xf32>
      stablehlo.return %3, %i : tensor<4xf32>, tensor<i32>
    }
    return %1#0 : tensor<4xf32>
  }
}
"""

# --- golden compiled HLO (optimized, async pair + tuple + while) -----------

HLO_ASYNC_AND_WHILE = """
HloModule jit_batch, entry_computation_layout={()->u32[48,2]{1,0}}

%or.clone (x: u32[], y: u32[]) -> u32[] {
  %x = u32[] parameter(0)
  %y = u32[] parameter(1)
  ROOT %or = u32[] or(u32[] %x, u32[] %y)
}

%body (p: (s32[], u32[48,2])) -> (s32[], u32[48,2]) {
  %p = (s32[], u32[48,2]{1,0}) parameter(0)
  ROOT %tup = (s32[], u32[48,2]{1,0}) tuple()
}

%cond (p.1: (s32[], u32[48,2])) -> pred[] {
  %p.1 = (s32[], u32[48,2]{1,0}) parameter(0)
  ROOT %lt = pred[] constant(false)
}

ENTRY %main () -> u32[48,2] {
  %z = u32[48,2]{1,0} iota(), iota_dimension=0
  %ar-start = (u32[48,2]{1,0}, u32[48,2]{1,0}) all-reduce-start(u32[48,2]{1,0} %z), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%or.clone
  %ar-done = u32[48,2]{1,0} all-reduce-done((u32[48,2]{1,0}, u32[48,2]{1,0}) %ar-start)
  %w = (s32[], u32[48,2]{1,0}) while((s32[], u32[48,2]{1,0}) %init), condition=%cond, body=%body
  ROOT %out = u32[48,2]{1,0} get-tuple-element((s32[], u32[48,2]{1,0}) %w), index=1
}
"""

HLO_TUPLE_ALL_TO_ALL = """
ENTRY %main {
  %a2a = (s32[64]{0}, s32[64]{0}) all-to-all(s32[64]{0} %a, s32[64]{0} %b), dimensions={0}
}
"""


def test_stablehlo_single_collective_payload():
    m = parse_program(SHLO_ONE_COLLECTIVE)
    assert m.dialect == "stablehlo"
    assert [c.kind for c in m.collectives] == ["all-reduce"]
    (c,) = m.collectives
    assert not c.in_loop
    assert [str(t) for t in c.results] == ["ui32[48,2]"]
    assert c.payload_bits == 48 * 2 * 32
    assert check_program(m, expect_count=1,
                         expected_bits=48 * 2 * 32) == []


def test_stablehlo_collective_inside_while_flagged():
    m = parse_program(SHLO_LOOPED)
    assert m.n_while == 1
    (c,) = m.collectives
    assert c.kind == "all-gather" and c.in_loop
    vs = check_program(m, expect_count=1)
    assert any(v.rule == "HLO002" for v in vs)


def test_stablehlo_loop_taint_through_call():
    """A collective in a helper func.call'ed from a while body is still a
    looped collective — taint flows through the call graph."""
    m = parse_program(SHLO_CALLED_IN_LOOP)
    (c,) = m.collectives
    assert c.in_loop
    assert any(v.rule == "HLO002" for v in check_program(m))


def test_hlo_async_pair_counts_once_and_while_tracked():
    m = parse_program(HLO_ASYNC_AND_WHILE)
    assert m.dialect == "hlo"
    assert m.n_while == 1
    assert [c.kind for c in m.collectives] == ["all-reduce"]
    (c,) = m.collectives
    assert c.async_pair and not c.in_loop
    # payload from the -done result, not the (in, out) start tuple
    assert c.payload_bits == 48 * 2 * 32
    assert check_program(m, expect_count=1,
                         expected_bits=48 * 2 * 32) == []


def test_hlo_tuple_result_sums_elements():
    m = parse_program(HLO_TUPLE_ALL_TO_ALL)
    (c,) = m.collectives
    assert c.kind == "all-to-all"
    assert c.payload_bits == 2 * 64 * 32


def test_unknown_dtype_raises():
    bad = SHLO_ONE_COLLECTIVE.replace("ui32", "f99")
    with pytest.raises(ValueError, match="unknown element type"):
        parse_program(bad)


# --- injected failures: each must be caught --------------------------------

def test_injected_second_collective_caught():
    doubled = SHLO_ONE_COLLECTIVE.replace(
        "    return %0 : tensor<48x2xui32>",
        """    %1 = "stablehlo.all_reduce"(%0) <{replica_groups =
        dense<[[0, 1]]> : tensor<1x2xi64>}> ({
    ^bb0(%a: tensor<ui32>, %b: tensor<ui32>):
      %9 = stablehlo.or %a, %b : tensor<ui32>
      stablehlo.return %9 : tensor<ui32>
    }) : (tensor<48x2xui32>) -> tensor<48x2xui32>
    return %1 : tensor<48x2xui32>""")
    m = parse_program(doubled)
    assert len(m.collectives) == 2
    vs = check_program(m, expect_count=1)
    assert [v.rule for v in vs] == ["HLO001"]


def test_injected_payload_mismatch_caught():
    m = parse_program(SHLO_ONE_COLLECTIVE)
    vs = check_program(m, expect_count=1, expected_bits=48 * 2 * 32 + 32)
    assert [v.rule for v in vs] == ["HLO003"]


def test_injected_graph_sized_wire_operand_caught():
    """A |V|-sized dimension on the wire breaks Theorem 5.5 (traffic must
    scale with the fragmentation, not the graph)."""
    m = parse_program(SHLO_ONE_COLLECTIVE)
    vs = check_program(m, expect_count=1, forbidden_dims=(48,),
                       allowed_dims=())
    assert any(v.rule == "HLO004" for v in vs)
    # the same dims pass when they belong to the declared wire model
    assert check_program(m, expect_count=1, forbidden_dims=(48,),
                         allowed_dims=(48, 2)) == []


# --- the CLI: full acceptance run ------------------------------------------

def test_cli_verifies_all_kinds_versions_and_topologies(tmp_path):
    """``python -m repro.analysis --all`` must pass clean on this repo,
    covering 3 kinds x {exact-fit k=8, packed k=32-on-8} x >= 2 live MVCC
    versions, and produce the JSON report artifact."""
    here = os.path.dirname(__file__)
    src = os.path.abspath(os.path.join(here, "..", "src"))
    root = os.path.abspath(os.path.join(here, ".."))
    out_path = tmp_path / "report.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--all",
         "--root", root, "--out", str(out_path)],
        capture_output=True, text=True, timeout=900, env=env, cwd=root)
    assert proc.returncode == 0, proc.stderr[-3000:]
    report = json.loads(out_path.read_text())
    assert report["ok"] is True
    assert report["counts"] == {"hlo": 0, "lint": 0, "locks": 0}
    covered = report["hlo"]["covered"]
    assert any(c.startswith("k8d8: 2 versions") and "fpd=1" in c
               for c in covered), covered
    assert any(c.startswith("k32d8: 2 versions") and "fpd=4" in c
               for c in covered), covered
    assert report["locks"]["order"][0] == "engine._serve_mutex"
