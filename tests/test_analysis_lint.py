"""repro.analysis.lint: the RPR rule catalog (DESIGN.md Sec. 10.2).

Each rule gets a positive snippet (the exact bug class a previous PR hit)
and a negative twin (the idiomatic fix), checked through ``lint_source``;
the repo itself must lint clean — that IS the baseline the satellite task
established (every RPR001 hit was fixed with an explicit copy, every
justified wall-clock use carries a ``repr: ignore`` with a reason).
"""
import os


from repro.analysis import lint_paths, lint_source


def rules(vs):
    return [v.rule for v in vs]


# --- RPR001: jnp.asarray may alias a mutable host buffer (PR 7) ------------

def test_rpr001_asarray_on_fragment_arrays_flagged():
    src = (
        "import jax.numpy as jnp\n"
        "def shard(fr):\n"
        "    return {k: jnp.asarray(v) for k, v in fr.arrays.items()}\n"
    )
    assert rules(lint_source(src)) == ["RPR001"]


def test_rpr001_taint_flows_through_views_not_copies():
    src = (
        "import jax.numpy as jnp\n"
        "def f(fr, row_ids, owner, nb):\n"
        "    esrc = fr.arrays['esrc']\n"
        "    view = esrc.reshape(-1)\n"          # view: still aliased
        "    bad = jnp.asarray(view)\n"
        "    cols = fr.arrays['tgt_local'][owner[row_ids]][:, :nb]\n"
        "    ok = jnp.asarray(cols)\n"           # advanced indexing: a copy
        "    safe = jnp.asarray(esrc.copy())\n"  # explicit copy
        "    return bad, ok, safe\n"
    )
    vs = lint_source(src)
    assert rules(vs) == ["RPR001"]
    assert ":5" in vs[0].where


def test_rpr001_jnp_array_is_the_fix():
    src = (
        "import jax.numpy as jnp\n"
        "def shard(fr):\n"
        "    return {k: jnp.array(v) for k, v in fr.arrays.items()}\n"
    )
    assert lint_source(src) == []


# --- RPR002: device transfer while holding a lock --------------------------

def test_rpr002_device_put_under_lock_flagged():
    src = (
        "import jax\n"
        "class S:\n"
        "    def go(self, x):\n"
        "        with self._lock:\n"
        "            y = jax.device_put(x)\n"
        "        return y\n"
    )
    vs = lint_source(src)
    assert rules(vs) == ["RPR002"]
    assert "lock taken at line 4" in vs[0].context


def test_rpr002_transfer_outside_lock_ok():
    src = (
        "import jax\n"
        "class S:\n"
        "    def go(self, x):\n"
        "        with self._lock:\n"
        "            n = len(x)\n"
        "        return jax.device_put(x), n\n"
    )
    assert lint_source(src) == []


# --- RPR003: unseeded randomness / wall-clock on serving paths -------------

def test_rpr003_wall_clock_and_unseeded_rng_on_serve_path():
    src = (
        "import time\n"
        "import numpy as np\n"
        "import random\n"
        "def schedule():\n"
        "    t0 = time.monotonic()\n"
        "    jitter = np.random.random()\n"
        "    pick = random.choice([1, 2])\n"
        "    return t0 + jitter + pick\n"
    )
    assert rules(lint_source(src, serve_path=True)) == ["RPR003"] * 3


def test_rpr003_seeded_generator_ok_and_rule_is_serve_only():
    src = (
        "import numpy as np\n"
        "def schedule():\n"
        "    rng = np.random.default_rng(0)\n"
        "    return rng.random()\n"
    )
    assert lint_source(src, serve_path=True) == []
    clocky = "import time\ndef f():\n    return time.time()\n"
    assert lint_source(clocky, serve_path=False) == []
    assert rules(lint_source(clocky, serve_path=True)) == ["RPR003"]


# --- RPR004: unbounded container growth on serving paths (PR 9) ------------

def test_rpr004_append_only_list_flagged():
    src = (
        "class Q:\n"
        "    def __init__(self):\n"
        "        self.dead = []\n"
        "    def push(self, x):\n"
        "        self.dead.append(x)\n"
    )
    vs = lint_source(src, serve_path=True)
    assert rules(vs) == ["RPR004"]
    assert "dead" in vs[0].message


def test_rpr004_drained_or_bounded_containers_ok():
    src = (
        "import collections\n"
        "class Q:\n"
        "    def __init__(self):\n"
        "        self.window = collections.deque(maxlen=64)\n"
        "        self.batch = []\n"
        "    def push(self, x):\n"
        "        self.window.append(x)\n"
        "        self.batch.append(x)\n"
        "    def flush(self):\n"
        "        out, self.batch = self.batch, []\n"    # drained: ok
        "        return out\n"
    )
    assert lint_source(src, serve_path=True) == []


# --- RPR005: mutable state captured by cached closures ---------------------

def test_rpr005_lru_cache_over_mutable_state_flagged():
    src = (
        "import functools\n"
        "@functools.lru_cache(maxsize=8)\n"
        "def plan(fr):\n"
        "    return fr.arrays['esrc'].sum()\n"
    )
    assert rules(lint_source(src)) == ["RPR005"]


def test_rpr005_cache_on_immutable_key_ok():
    src = (
        "import functools\n"
        "@functools.lru_cache(maxsize=8)\n"
        "def plan(n, kind):\n"
        "    return n * 2 + len(kind)\n"
    )
    assert lint_source(src) == []


# --- suppressions ----------------------------------------------------------

def test_justified_ignore_suppresses_only_that_rule():
    src = (
        "import time\n"
        "def f():\n"
        "    # repr: ignore[RPR003] wall-clock batch pacing is by design\n"
        "    return time.monotonic()\n"
    )
    assert lint_source(src, serve_path=True) == []


def test_bare_ignore_is_itself_a_violation():
    src = (
        "import time\n"
        "def f():\n"
        "    return time.monotonic()  # repr: ignore[RPR003]\n"
    )
    vs = lint_source(src, serve_path=True)
    assert rules(vs) == ["RPR000"]      # zero silent suppressions


def test_ignore_for_wrong_rule_does_not_suppress():
    src = (
        "import time\n"
        "def f():\n"
        "    # repr: ignore[RPR001] totally unrelated justification\n"
        "    return time.monotonic()\n"
    )
    assert rules(lint_source(src, serve_path=True)) == ["RPR003"]


# --- the repo itself is the clean baseline ---------------------------------

def test_repo_lints_clean():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src", "repro"))
    assert [str(v) for v in lint_paths([src])] == []
