"""repro.analysis.locks: static acquisition-graph extraction + the
runtime-instrumented mode (DESIGN.md Sec. 10.3).

The self-tests the ISSUE requires: an injected lock inversion must be
caught BOTH statically (a doctored module fed to the extractor) and at
runtime (wrong-order acquisition on instrumented locks), while the real
repo stays clean in both modes.
"""
import threading

import numpy as np

from repro.analysis import (LOCK_ORDER, InstrumentedLock, LockMonitor,
                            check_lock_order, monitored)
from repro.analysis.locks import check_edges, extract_acquisition_graph
from repro.graph import erdos_renyi, random_partition


# --- static mode -----------------------------------------------------------

def test_repo_acquisition_graph_respects_declared_order():
    import os
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    vs, edges = check_lock_order(root)
    assert [str(v) for v in vs] == []
    # the extraction is not vacuous: the known hot edges are present
    assert ("store._repair_lock", "session._lock") in edges
    assert ("store._repair_lock", "store._lock") in edges
    assert ("engine._mutex", "telemetry._lock") in edges


def _doctored(tmp_path, name, body):
    p = tmp_path / name
    p.write_text(body)
    return str(p)


def test_injected_static_inversion_caught(tmp_path):
    """store._lock held while taking store._repair_lock inverts the
    declared order and must be rejected."""
    bad = (
        "class VersionedCacheStore:\n"
        "    def commit_delta(self, delta):\n"
        "        with self._lock:\n"
        "            with self._repair_lock:\n"
        "                pass\n"
    )
    vs, edges = check_lock_order(
        files={_doctored(tmp_path, "versions.py", bad): "store"})
    assert ("store._lock", "store._repair_lock") in edges
    assert [v.rule for v in vs] == ["LCK001"]
    assert "store._lock -> store._repair_lock" in vs[0].where


def test_injected_inversion_through_cross_module_call_caught(tmp_path):
    """The inversion only exists interprocedurally: telemetry holds its
    lock and calls back into the session, which takes session._lock."""
    tele = (
        "class Telemetry:\n"
        "    def record(self, sess):\n"
        "        with self._lock:\n"
        "            self.session.snapshot()\n"
    )
    sess = (
        "class QuerySession:\n"
        "    def snapshot(self):\n"
        "        with self._lock:\n"
        "            return 1\n"
    )
    vs, edges = check_lock_order(files={
        _doctored(tmp_path, "telemetry.py", tele): "telemetry",
        _doctored(tmp_path, "session.py", sess): "session",
    })
    assert ("telemetry._lock", "session._lock") in edges
    assert [v.rule for v in vs] == ["LCK001"]


def test_static_self_deadlock_on_plain_lock(tmp_path):
    bad = (
        "class Telemetry:\n"
        "    def snapshot(self):\n"
        "        with self._lock:\n"
        "            with self._lock:\n"
        "                pass\n"
    )
    vs, _ = check_lock_order(
        files={_doctored(tmp_path, "telemetry.py", bad): "telemetry"})
    assert [v.rule for v in vs] == ["LCK002"]


def test_static_reentrant_self_edge_allowed(tmp_path):
    ok = (
        "class QuerySession:\n"
        "    def run(self):\n"
        "        with self._lock:\n"
        "            self._plan()\n"
        "    def _plan(self):\n"
        "        with self._lock:\n"
        "            pass\n"
    )
    vs, edges = check_lock_order(
        files={_doctored(tmp_path, "session.py", ok): "session"})
    assert ("session._lock", "session._lock") in edges
    assert vs == []


def test_static_undeclared_lock_reported(tmp_path):
    bad = (
        "class QuerySession:\n"
        "    def run(self):\n"
        "        with self._lock:\n"
        "            with self._shadow_lock:\n"
        "                pass\n"
    )
    vs, _ = check_lock_order(
        files={_doctored(tmp_path, "session.py", bad): "session"})
    assert [v.rule for v in vs] == ["LCK003"]
    assert "session._shadow_lock" in vs[0].message


def test_condition_objects_alias_the_engine_mutex(tmp_path):
    """with self._work: ... in engine code is an engine._mutex
    acquisition — the Condition wraps it."""
    eng = (
        "class AsyncQueryEngine:\n"
        "    def _next_work(self):\n"
        "        with self._work:\n"
        "            self.telemetry.record(1)\n"
    )
    tele = (
        "class Telemetry:\n"
        "    def record(self, x):\n"
        "        with self._lock:\n"
        "            pass\n"
    )
    edges = extract_acquisition_graph({
        _doctored(tmp_path, "engine.py", eng): "engine",
        _doctored(tmp_path, "telemetry.py", tele): "telemetry",
    })
    assert ("engine._mutex", "telemetry._lock") in edges
    assert check_edges(edges) == []


# --- runtime mode ----------------------------------------------------------

def _locks(monitor):
    return (InstrumentedLock(threading.RLock(), "engine._mutex", monitor),
            InstrumentedLock(threading.Lock(), "telemetry._lock", monitor))


def test_runtime_ordered_acquisition_clean():
    mon = LockMonitor()
    mutex, tlock = _locks(mon)
    with mutex:
        with tlock:
            pass
    assert mon.violations == []


def test_runtime_inversion_caught():
    mon = LockMonitor()
    mutex, tlock = _locks(mon)
    with tlock:
        with mutex:
            pass
    assert [v.rule for v in mon.violations] == ["LCK001"]
    assert "engine._mutex acquired while holding telemetry._lock" in \
        mon.violations[0].message


def test_runtime_inversion_across_threads_is_per_thread():
    """Each thread's stack is independent: thread A holding telemetry
    does not poison thread B's ordered acquisition."""
    mon = LockMonitor()
    mutex, tlock = _locks(mon)
    hold = threading.Event()
    done = threading.Event()

    def holder():
        with tlock:
            hold.set()
            done.wait(timeout=5)

    th = threading.Thread(target=holder)
    th.start()
    hold.wait(timeout=5)
    with mutex:                    # ordered for THIS thread
        pass
    done.set()
    th.join()
    assert mon.violations == []


def test_runtime_nonreentrant_double_acquire_flagged():
    mon = LockMonitor()
    # RLock inner so the test does not actually deadlock; the NAME
    # store._lock is declared non-reentrant
    lk = InstrumentedLock(threading.RLock(), "store._lock", mon)
    with lk:
        with lk:
            pass
    assert [v.rule for v in mon.violations] == ["LCK002"]


def test_runtime_undeclared_lock_flagged():
    mon = LockMonitor()
    lk = InstrumentedLock(threading.Lock(), "mystery._lock", mon)
    with lk:
        pass
    assert [v.rule for v in mon.violations] == ["LCK003"]


def test_condition_over_instrumented_rlock_keeps_stack_consistent():
    """Condition.wait releases ALL recursion levels through
    _release_save; the monitor must drop the name so the reacquisition
    after notify is not a false inversion."""
    mon = LockMonitor()
    mutex, tlock = _locks(mon)
    cond = threading.Condition(mutex)
    woke = []

    def waiter():
        with cond:
            cond.wait(timeout=5)
            with tlock:            # ordered acquisition after wakeup
                woke.append(1)

    th = threading.Thread(target=waiter)
    th.start()
    import time
    time.sleep(0.1)
    with cond:
        cond.notify_all()
    th.join()
    assert woke == [1]
    assert mon.violations == []


def test_monitored_serving_stack_end_to_end():
    """A real QueryServer built under monitored() runs every dispatch,
    flush, and telemetry read on instrumented locks — and stays clean."""
    from repro.core import fragment_graph
    from repro.serve import QueryServer

    g = erdos_renyi(14, 26, n_labels=3, seed=3)
    fr = fragment_graph(g, random_partition(g, 2, 3), 2)
    with monitored() as mon:
        srv = QueryServer(fr, batch_size=4, start=False)
        assert isinstance(srv.engine._mutex, InstrumentedLock)
        rng = np.random.default_rng(0)
        reqs = [srv.submit(int(rng.integers(g.n)), int(rng.integers(g.n)))
                for _ in range(6)]
        srv.flush()
        vals = [r.value for r in reqs]
        srv.telemetry()
        srv.close()
    assert all(v in (True, False) for v in vals)
    assert [str(v) for v in mon.violations] == []


def test_lock_order_is_total_and_matches_design():
    assert list(LOCK_ORDER) == [
        "engine._serve_mutex", "engine._mutex", "store._repair_lock",
        "session._lock", "store._lock", "telemetry._lock"]
