"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned arch and run one actual step on CPU, asserting finite outputs.
(The FULL configs are exercised only via the dry-run, which lowers
ShapeDtypeStructs without allocation.)"""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, all_cells, get_arch
from repro.configs.families.base import zeros_from_abstract


def test_registry_has_all_ten():
    expected = {"olmoe-1b-7b", "mixtral-8x7b", "qwen1.5-32b", "qwen2-1.5b",
                "chatglm3-6b", "egnn", "mace", "nequip", "gat-cora",
                "bert4rec"}
    assert set(ARCHS) == expected
    assert len(all_cells()) == 40


SMOKE_CELLS = [(aid, sid) for aid, arch in ARCHS.items()
               for sid in arch.shape_ids()
               if arch.skip_reason(sid) is None]


@pytest.mark.parametrize("aid,sid", SMOKE_CELLS,
                         ids=[f"{a}::{s}" for a, s in SMOKE_CELLS])
def test_smoke_cell(aid, sid):
    arch = get_arch(aid)
    prog = arch.build(sid, multipod=False, reduced=True)
    args = zeros_from_abstract(prog.abstract_args, seed=hash(aid) % 1000)
    out = jax.jit(prog.step_fn)(*args)
    leaves = jax.tree.leaves(out)
    assert leaves, "step produced no outputs"
    for leaf in leaves:
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating):
            assert np.isfinite(arr).all(), (aid, sid, arr.dtype)


def test_skips_are_only_long_context_full_attention():
    skips = [(a, s, ARCHS[a].skip_reason(s)) for a, s in all_cells()
             if ARCHS[a].skip_reason(s)]
    assert sorted(a for a, s, _ in skips) == sorted(
        ["olmoe-1b-7b", "qwen1.5-32b", "qwen2-1.5b", "chatglm3-6b"])
    assert all(s == "long_500k" for _, s, _ in skips)


def test_full_configs_match_assignment():
    """Spot-check exact assigned hyperparameters."""
    q32 = get_arch("qwen1.5-32b").base_cfg
    assert (q32.n_layers, q32.d_model, q32.n_heads, q32.d_ff,
            q32.vocab) == (64, 5120, 40, 27392, 152064)
    assert q32.qkv_bias
    mix = get_arch("mixtral-8x7b").base_cfg
    assert (mix.n_layers, mix.d_model, mix.n_experts, mix.top_k,
            mix.d_ff_expert, mix.sliding_window) == (32, 4096, 8, 2, 14336,
                                                     4096)
    olm = get_arch("olmoe-1b-7b").base_cfg
    assert (olm.n_experts, olm.top_k, olm.d_ff_expert,
            olm.vocab) == (64, 8, 1024, 50304)
    q2 = get_arch("qwen2-1.5b").base_cfg
    assert (q2.n_layers, q2.d_model, q2.n_heads, q2.n_kv_heads,
            q2.d_ff, q2.vocab) == (28, 1536, 12, 2, 8960, 151936)
    glm = get_arch("chatglm3-6b").base_cfg
    assert (glm.n_layers, glm.d_model, glm.n_heads, glm.n_kv_heads,
            glm.d_ff, glm.vocab) == (28, 4096, 32, 2, 13696, 65024)
    assert glm.rope_pct == 0.5
    b4r = get_arch("bert4rec").full_cfg
    assert (b4r.embed_dim, b4r.n_blocks, b4r.n_heads,
            b4r.seq_len) == (64, 2, 2, 200)
