"""Continuous-batching async serving: futures, fencing, telemetry
(DESIGN.md Sec. 8).

The contracts under test, all with the scheduler thread *running* (the
deferred ``start=False`` mode is covered throughout the chaos/session/
incremental suites):

* concurrent submitters get oracle-exact answers, and every future
  resolves exactly once;
* a mid-stream delta is a snapshot barrier on both backends — pre-delta
  futures answer against the pre-delta cache (witnessed by the stamped
  ``cache_version``), post-delta futures against the repaired one;
* deadlines and poison requests resolve typed (``DEADLINE`` /
  ``DEAD_LETTER``) without wedging the scheduler;
* the deprecated ``drain()`` warns and still returns the PR-7 shape;
* telemetry aggregates what actually happened.
"""
import threading

import numpy as np
import pytest

from repro import connect
from repro.core import GraphDelta, fragment_graph
from repro.errors import (DeadLetterError, DeadlineExceeded, InjectedFault,
                          Status)
from repro.graph import erdos_renyi, random_partition
from repro.serve import FaultInjector, QueryServer, RetryPolicy

from oracles import oracle_dist, oracle_reach

RESULT_TIMEOUT_S = 120.0      # generous: first result may pay the compiles


def _case(n, m, k, seed, **kw):
    g = erdos_renyi(n, m, n_labels=3, seed=seed)
    fr = fragment_graph(g, random_partition(g, k, seed), k, **kw)
    return g, fr


def _unreachable_pair(g, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(500):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        if s != t and not oracle_reach(g, s, t):
            return s, t
    pytest.skip("graph is (almost) strongly connected")


# ---------------------------------------------------------------------------
# concurrent submitters: oracle-exact, exactly-once
# ---------------------------------------------------------------------------

def test_concurrent_submitters_oracle_exact_exactly_once():
    g, fr = _case(30, 90, 3, seed=5)
    n_workers, per_worker = 4, 12
    failures = []

    def worker(wid, srv):
        rng = np.random.default_rng(wid)
        futs = []
        for i in range(per_worker):
            s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
            kind = "dist" if i % 2 else "reach"
            futs.append((s, t, kind, srv.submit(s, t, kind=kind)))
        for s, t, kind, f in futs:
            got = f.result(timeout=RESULT_TIMEOUT_S)
            want = (oracle_dist(g, s, t) if kind == "dist"
                    else oracle_reach(g, s, t))
            if got != want or f.status is not Status.DONE:
                failures.append((wid, s, t, kind, got, want, f.status))

    with QueryServer(fr, batch_size=8, batch_wait_ms=1.0) as srv:
        threads = [threading.Thread(target=worker, args=(w, srv))
                   for w in range(n_workers)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=RESULT_TIMEOUT_S)
        assert not any(th.is_alive() for th in threads)
        assert failures == []
        # exactly-once: every submission reached exactly one terminal
        # status (the engine asserts no future resolves twice)
        snap = srv.telemetry()
        total = n_workers * per_worker
        assert snap["resolved"] == total
        assert snap["statuses"] == {"done": total}
        assert srv.pending() == 0


def test_two_servers_share_one_session():
    """Multiple intake frontends over ONE session: the session lock
    serializes group execution, both serve oracle-exact from the shared
    caches."""
    g, fr = _case(24, 70, 2, seed=9)
    sess = connect(fr)
    srv_a = QueryServer(fr, session=sess, batch_size=4, batch_wait_ms=1.0)
    srv_b = QueryServer(fr, session=sess, batch_size=4, batch_wait_ms=1.0)
    try:
        assert srv_a.session is srv_b.session
        rng = np.random.default_rng(2)
        futs = []
        for i in range(20):
            s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
            futs.append((s, t, (srv_a if i % 2 else srv_b).submit(s, t)))
        for s, t, f in futs:
            assert f.result(timeout=RESULT_TIMEOUT_S) == oracle_reach(g, s, t)
    finally:
        srv_a.close()
        srv_b.close()


# ---------------------------------------------------------------------------
# mid-stream deltas are snapshot barriers (both backends)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["vmap", "shard_map"])
def test_mid_stream_delta_fencing(backend):
    g, fr = _case(24, 30, 3, seed=11, reserve_boundary=8, reserve_edges=24,
                  reserve_stubs=12)
    s, t = _unreachable_pair(g)
    with QueryServer(fr, batch_size=4, backend=backend) as srv:
        pre = srv.submit(s, t)
        upd = srv.submit_delta(GraphDelta.insert([(s, t)]))
        post = srv.submit(s, t)
        # pre-delta future answers against the pre-delta snapshot, even
        # though the delta was already queued when it (maybe) executed
        assert pre.result(timeout=RESULT_TIMEOUT_S) is False
        assert upd.result(timeout=RESULT_TIMEOUT_S).mode in (
            "repair", "recompute", "repair_sharded", "rebuild")
        assert upd.status is Status.APPLIED
        assert post.result(timeout=RESULT_TIMEOUT_S) is True
        # the fencing witness: version stamped at execution time
        assert pre.cache_version < post.cache_version
        assert srv.updates_applied == 1


# ---------------------------------------------------------------------------
# deadlines + poison under the async scheduler
# ---------------------------------------------------------------------------

def test_deadline_resolves_typed_without_wedging_the_scheduler():
    g, fr = _case(20, 50, 2, seed=3)
    # batch_wait is huge: only deadline pressure can ship a partial bucket
    with QueryServer(fr, batch_size=64, batch_wait_ms=60_000.0,
                     ship_margin_ms=25.0) as srv:
        dead = srv.submit(0, 1, deadline_ms=0.0)       # already expired
        with pytest.raises(DeadlineExceeded):
            dead.result(timeout=RESULT_TIMEOUT_S)
        assert dead.status is Status.DEADLINE
        # a generous deadline ships the bucket well before expiring
        live = srv.submit(0, 1, deadline_ms=30_000.0)
        assert live.result(timeout=RESULT_TIMEOUT_S) == oracle_reach(g, 0, 1)
        assert live.status is Status.DONE


def test_poison_dead_letters_async_and_batchmates_survive():
    g, fr = _case(20, 50, 2, seed=7)
    chaos = FaultInjector(seed=0, poison=[(0, 1)])
    srv = QueryServer(fr, batch_size=8, backend="vmap", chaos=chaos,
                      batch_wait_ms=200.0,
                      retry=RetryPolicy(max_attempts=2, base_delay_ms=0.0))
    try:
        # same bucket: the poison request and innocent batchmates
        mates = [srv.submit(i, (i + 3) % g.n) for i in range(2, 6)]
        poison = srv.submit(0, 1)
        for f in mates:
            assert (f.result(timeout=RESULT_TIMEOUT_S)
                    == oracle_reach(g, f.s, f.t))
        with pytest.raises(DeadLetterError) as ei:
            poison.result(timeout=RESULT_TIMEOUT_S)
        assert poison.status is Status.DEAD_LETTER
        assert isinstance(ei.value.cause, InjectedFault)
        assert ei.value.cause.permanent
        assert srv.dead_letters == [poison]
        # scheduler is still alive and serving after the quarantine
        again = srv.submit(2, 5)
        assert again.result(timeout=RESULT_TIMEOUT_S) == oracle_reach(g, 2, 5)
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# drain() compatibility + telemetry
# ---------------------------------------------------------------------------

def test_drain_compat_warns_and_matches_futures_path():
    g, fr = _case(22, 60, 2, seed=1)
    rng = np.random.default_rng(4)
    pairs = [(int(rng.integers(g.n)), int(rng.integers(g.n)))
             for _ in range(9)]
    # legacy path: deferred server + deprecated drain()
    old = QueryServer(fr, batch_size=4, start=False)
    legacy = [old.submit(s, t) for s, t in pairs]
    with pytest.warns(DeprecationWarning, match="drain.*deprecated"):
        served = old.drain()
    assert sorted(map(id, served)) == sorted(map(id, legacy))
    # new path: continuous server + futures
    with QueryServer(fr, batch_size=4) as srv:
        fresh = [srv.submit(s, t) for s, t in pairs]
        for (s, t), a, b in zip(pairs, legacy, fresh):
            want = oracle_reach(g, s, t)
            assert a.value == b.result(timeout=RESULT_TIMEOUT_S) == want


def test_telemetry_reflects_served_load():
    g, fr = _case(24, 70, 2, seed=6)
    with QueryServer(fr, batch_size=4, batch_wait_ms=1.0) as srv:
        futs = [srv.submit(i % g.n, (i * 7) % g.n,
                           kind="dist" if i % 3 == 0 else "reach")
                for i in range(12)]
        for f in futs:
            f.result(timeout=RESULT_TIMEOUT_S)
        snap = srv.telemetry()
    assert snap["resolved"] == 12
    assert snap["statuses"] == {"done": 12}
    assert snap["batches"] == srv.batches_run >= 3     # 12 queries, bucket 4
    assert 0.0 < snap["batch_occupancy"] <= 1.0
    assert snap["qps"] > 0.0
    assert set(snap["lane_depths"]) == {"green", "yellow", "updates"}
    assert all(v == 0 for v in snap["lane_depths"].values())
    routes = snap["routes"]
    assert sum(r["count"] for r in routes.values()) == 12
    for r in routes.values():
        assert 0.0 <= r["p50_ms"] <= r["p95_ms"] <= r["p99_ms"]


def test_submit_after_close_is_refused():
    g, fr = _case(10, 20, 2, seed=0)
    srv = QueryServer(fr, batch_size=4, warm=False, start=False)
    srv.close()
    with pytest.raises(RuntimeError, match="stopped"):
        srv.submit(0, 1)
