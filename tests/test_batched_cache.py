"""Amortized rvset cache + batched session execution vs the seed path
and oracles.

The cached/batched evaluation (a ``repro.connect`` session over
core.cache) must answer exactly like the seed single-query engine
(core.api) and the networkx oracles on arbitrary graph x fragmentation x
query — the cache is an optimization, never a semantic change.  (The
PR-4-deprecated ``dis_*_cached`` / ``dis_*_batch`` shims these tests
used to drive were removed in PR 8; sessions are the one cached entry
point.)
"""
import zlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import connect
from repro.core import (Dist, Reach, build_query_automaton, dis_dist,
                        dis_reach, dis_rpq, fragment_graph, get_rvset_cache,
                        prepare_rvset_cache)
from repro.graph import erdos_renyi, random_partition
from repro.serve import QueryServer

from oracles import oracle_dist, oracle_reach, oracle_rpq


def _case(n, m, k, seed):
    g = erdos_renyi(n, m, n_labels=4, seed=seed)
    return g, fragment_graph(g, random_partition(g, k, seed), k)


# ---------------------------------------------------------------------------
# cached/batched == seed == oracle
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_batched_reach_matches_seed_and_oracle(data):
    n = data.draw(st.integers(4, 24), label="n")
    m = data.draw(st.integers(0, 60), label="m")
    k = data.draw(st.integers(1, 5), label="k")
    seed = data.draw(st.integers(0, 10_000), label="seed")
    g = erdos_renyi(n, m, n_labels=3, seed=seed)
    part = np.asarray(
        data.draw(st.lists(st.integers(0, k - 1), min_size=n, max_size=n),
                  label="part"), dtype=np.int32)
    fr = fragment_graph(g, part, k)
    pairs = [(data.draw(st.integers(0, n - 1)), data.draw(st.integers(0, n - 1)))
             for _ in range(4)]
    got = connect(fr).run([Reach(s, t) for s, t in pairs])
    for (s, t), r in zip(pairs, got):
        want = oracle_reach(g, s, t)
        assert r.answer == want
        assert dis_reach(fr, s, t).answer == want


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_property_batched_dist_matches_oracle(data):
    n = data.draw(st.integers(4, 20))
    m = data.draw(st.integers(0, 50))
    k = data.draw(st.integers(1, 4))
    seed = data.draw(st.integers(0, 10_000))
    g = erdos_renyi(n, m, n_labels=3, seed=seed)
    fr = fragment_graph(g, random_partition(g, k, seed), k)
    pairs = [(data.draw(st.integers(0, n - 1)), data.draw(st.integers(0, n - 1)))
             for _ in range(4)]
    got = connect(fr).run([Dist(s, t) for s, t in pairs])
    for (s, t), r in zip(pairs, got):
        assert r.distance == oracle_dist(g, s, t)


@pytest.mark.parametrize("seed", range(4))
def test_cached_single_query_session(seed):
    rng = np.random.default_rng(seed)
    g, fr = _case(int(rng.integers(8, 36)), int(rng.integers(5, 110)),
                  int(rng.integers(1, 5)), seed)
    sess = connect(fr)
    for _ in range(8):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        assert sess.reach(s, t) == oracle_reach(g, s, t)
        assert sess.dist(s, t).distance == oracle_dist(g, s, t)
    # bounded semantics agree with the seed path (answer AND distance:
    # a failed bounded query reports no distance on both paths)
    for bound in (0, 1, 3):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        got = sess.dist(s, t, bound=bound)
        want = dis_dist(fr, s, t, bound=bound)
        assert got.answer == want.answer
        assert got.distance == want.distance


@pytest.mark.parametrize("regex", ["0* 1*", "(0|1)* 2", ". . .", "0+ (1|2)*"])
def test_cached_rpq_matches_seed_and_oracle(regex):
    # crc32, not hash(): string hashing is salted per process and would
    # make the drawn pairs irreproducible across runs
    rng = np.random.default_rng(zlib.crc32(regex.encode()))
    g, fr = _case(18, 50, 3, int(rng.integers(100)))
    qa = build_query_automaton(regex, lambda x: int(x))
    sess = connect(fr)
    for _ in range(6):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        want = oracle_rpq(g, s, t, qa)
        assert dis_rpq(fr, s, t, qa).answer == want
        assert sess.rpq(s, t, automaton=qa) == want


def test_rpq_closure_cached_per_automaton():
    g, fr = _case(16, 40, 2, 0)
    sess = connect(fr)
    qa = build_query_automaton("0* 1", lambda x: int(x))
    sess.rpq(0, 5, automaton=qa)
    cache = get_rvset_cache(fr)
    assert len(cache.rpq_closures) == 1
    sess.rpq(1, 6, automaton=qa)           # same automaton: no new closure
    assert len(cache.rpq_closures) == 1
    qb = build_query_automaton("1* 0", lambda x: int(x))
    sess.rpq(0, 5, automaton=qb)
    assert len(cache.rpq_closures) == 2


def test_product_closure_eviction_is_lru_not_fifo(monkeypatch):
    """A cache hit must refresh recency: a server alternating
    MAX_RPQ_CLOSURES + 1 regexes with one hot one must keep the hot
    closure instead of rebuilding it on every query (FIFO would evict the
    oldest-*inserted*, i.e. the hot one)."""
    from repro.core import cache as cache_mod
    monkeypatch.setattr(cache_mod, "MAX_RPQ_CLOSURES", 2)
    g, fr = _case(16, 40, 2, 4)
    qa_hot = build_query_automaton("0*", lambda x: int(x))
    qa_b = build_query_automaton("1*", lambda x: int(x))
    qa_c = build_query_automaton("2*", lambda x: int(x))
    c_hot = cache_mod.product_closure(fr, qa_hot)
    cache_mod.product_closure(fr, qa_b)
    # hit the hot automaton: same object back, recency refreshed
    assert cache_mod.product_closure(fr, qa_hot) is c_hot
    cache_mod.product_closure(fr, qa_c)      # evicts qa_b (LRU), not hot
    keys = set(fr.rvset_cache.rpq_closures)
    assert qa_hot.cache_key() in keys and qa_c.cache_key() in keys
    assert qa_b.cache_key() not in keys
    assert cache_mod.product_closure(fr, qa_hot) is c_hot  # never rebuilt


# ---------------------------------------------------------------------------
# cache mechanics + stats
# ---------------------------------------------------------------------------

def test_cache_is_built_once_and_reused():
    g, fr = _case(20, 60, 3, 7)
    assert fr.rvset_cache is None
    c1 = prepare_rvset_cache(fr)
    c2 = get_rvset_cache(fr)
    assert c1 is c2 and fr.rvset_cache is c1
    # dist parts attach lazily to the same cache object
    assert c1.bl_dist is None
    connect(fr).run([Dist(0, 1)])
    assert c1.bl_dist is not None


def test_payload_bits_report_bitpacked_size():
    g, fr = _case(30, 90, 3, 3)
    B = fr.B
    words = (B + 31) // 32
    res = dis_reach(fr, 0, 1)
    assert res.stats.payload_bits == B * words * 32
    qa = build_query_automaton("0*", lambda x: int(x))
    side = B * qa.n_states
    assert (dis_rpq(fr, 0, 1, qa).stats.payload_bits ==
            side * ((side + 31) // 32) * 32)


def test_empty_and_degenerate_batches():
    g, fr = _case(10, 20, 2, 1)
    sess = connect(fr)
    assert sess.run([]) == []
    assert sess.reach(3, 3)                               # s == t
    # single fragment: no boundary at all (nb == 0)
    g1 = erdos_renyi(12, 30, seed=2)
    fr1 = fragment_graph(g1, np.zeros(12, np.int32), 1)
    sess1 = connect(fr1)
    pairs = [(0, 5), (5, 0), (2, 2)]
    got = sess1.run([Reach(s, t) for s, t in pairs])
    for (s, t), r in zip(pairs, got):
        assert r.answer == oracle_reach(g1, s, t)
    d = sess1.run([Dist(s, t) for s, t in pairs])
    for (s, t), r in zip(pairs, d):
        assert r.distance == oracle_dist(g1, s, t)


# ---------------------------------------------------------------------------
# serving loop
# ---------------------------------------------------------------------------

def test_query_server_matches_oracle_across_batches():
    g, fr = _case(36, 110, 4, 11)
    srv = QueryServer(fr, batch_size=8, start=False)
    rng = np.random.default_rng(0)
    pairs = [(int(rng.integers(g.n)), int(rng.integers(g.n)))
             for _ in range(19)]                       # odd: forces padding
    res = srv.serve_pairs(pairs)
    assert res == [oracle_reach(g, s, t) for s, t in pairs]
    assert srv.batches_run == 3

    for s, t in pairs[:5]:
        srv.submit(s, t, kind="dist")
    srv.submit(pairs[0][0], pairs[0][1], kind="bounded", bound=2)
    out = srv.flush()
    for r in out:
        want = oracle_dist(g, r.s, r.t)
        if r.kind == "dist":
            assert r.value == want
        else:
            assert r.value == (want is not None and want <= 2)
