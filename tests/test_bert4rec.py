"""BERT4Rec: masked-item training + the three serving paths."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import bert4rec as B
from repro.recsys import embedding_bag, embedding_lookup, onehot_lookup


def small_cfg():
    return B.Bert4RecConfig(n_items=500, embed_dim=32, n_blocks=2,
                            n_heads=2, seq_len=20)


def test_encode_and_loss():
    cfg = small_cfg()
    params = B.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    items = jnp.asarray(rng.integers(2, 500, (4, 20)), jnp.int32)
    targets = items
    mask_pos = jnp.asarray(rng.random((4, 20)) < 0.15)
    masked = jnp.where(mask_pos, cfg.MASK, items)
    loss = B.masked_item_loss(cfg, params, masked, targets, mask_pos)
    assert np.isfinite(float(loss)) and float(loss) > 0
    g = jax.grad(lambda p: B.masked_item_loss(cfg, p, masked, targets,
                                              mask_pos))(params)
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(g))


def test_pad_masking_blocks_attention():
    cfg = small_cfg()
    params = B.init_params(cfg, jax.random.key(0))
    items = jnp.asarray([[7, 9, 11, 0, 0] + [13] * 15], jnp.int32)
    h1 = B.encode(cfg, params, items)
    items2 = items  # change nothing
    # changing a PAD-adjacent live item changes states, changing nothing doesn't
    np.testing.assert_allclose(np.asarray(h1),
                               np.asarray(B.encode(cfg, params, items2)))


def test_serving_paths():
    cfg = small_cfg()
    params = B.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    items = jnp.asarray(rng.integers(2, 500, (3, 20)), jnp.int32)
    scores = B.score_next(cfg, params, items)
    assert scores.shape == (3, 500)
    cands = jnp.asarray(rng.integers(2, 500, 64), jnp.int32)
    cscores = B.score_candidates(cfg, params, items[:1], cands)
    assert cscores.shape == (64,)
    # retrieval scores agree with the full scoring restricted to candidates
    np.testing.assert_allclose(np.asarray(cscores),
                               np.asarray(scores[0][cands]), rtol=2e-4,
                               atol=1e-4)


def test_embedding_bag_modes():
    rng = np.random.default_rng(2)
    table = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
    ids = jnp.asarray([1, 2, 3, 10, 10, 49], jnp.int32)
    offsets = jnp.asarray([0, 0, 0, 1, 1, 2], jnp.int32)  # bags {0,1,2}
    s = embedding_bag(table, ids, offsets, 3, "sum")
    m = embedding_bag(table, ids, offsets, 3, "mean")
    np.testing.assert_allclose(np.asarray(s[0]),
                               np.asarray(table[1] + table[2] + table[3]),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(m[1]), np.asarray(table[10]),
                               rtol=1e-5)
    w = jnp.asarray([1.0, 0.0, 0.0, 2.0, 0.0, 1.0])
    sw = embedding_bag(table, ids, offsets, 3, "sum", weights=w)
    np.testing.assert_allclose(np.asarray(sw[1]), np.asarray(2 * table[10]),
                               rtol=1e-5)


def test_onehot_lookup_matches_take():
    rng = np.random.default_rng(3)
    table = jnp.asarray(rng.normal(size=(40, 6)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 40, 17), jnp.int32)
    np.testing.assert_allclose(np.asarray(onehot_lookup(table, ids)),
                               np.asarray(embedding_lookup(table, ids)),
                               rtol=1e-5, atol=1e-6)
