"""Fault-injection / robustness suite (DESIGN.md Sec. 7).

Exercises the serving stack under deterministic seeded chaos: typed
errors, retry/backoff, batch bisection + dead-lettering, admission lanes,
deadlines, degraded-mode fallback, and failed-delta rollback — plus the
8-fake-device subprocess acceptance run (mixed workload + interleaved
deltas + poison at a 1% injected fault rate, exactly-once resolution,
oracle-checked answers).

Run with ``pytest -m chaos`` (also part of the default suite).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import repro
from repro.core import (Dist, GraphDelta, Reach, Rpq, build_query_automaton,
                        fragment_graph)
from repro.graph import erdos_renyi, random_partition
from repro.graph.graph import Graph
from repro.serve import (AdmissionPolicy, DeadLetterError, DeadlineExceeded,
                         DeltaApplyFailed, FaultInjector, FaultSpec,
                         InjectedFault, QueryServer, QueryTooExpensive,
                         RetryPolicy, UpdateRequest, estimate_cost)
from repro.serve.admission import GREEN, YELLOW

from oracles import oracle_dist, oracle_reach, oracle_rpq

pytestmark = pytest.mark.chaos


def _case(n=30, m=70, k=2, seed=1):
    g = erdos_renyi(n, m, n_labels=3, seed=seed)
    fr = fragment_graph(g, random_partition(g, k, 1), k,
                        reserve_boundary=10, reserve_edges=24,
                        reserve_stubs=10)
    return g, fr


def _server(fr, backend="vmap", chaos=None, **kw):
    kw.setdefault("batch_size", 8)
    kw.setdefault("retry", RetryPolicy(max_attempts=3, base_delay_ms=0.0))
    # deferred mode: serving happens inside flush(), deterministically
    # (the continuous scheduler thread is covered by test_async_serve)
    kw.setdefault("start", False)
    return QueryServer(fr, backend=backend, chaos=chaos, **kw)


def _unreachable_pair(g, limit=12):
    for u in range(min(g.n, limit)):
        for v in range(min(g.n, limit)):
            if u != v and not oracle_reach(g, u, v):
                return u, v
    raise AssertionError("graph too dense for the test: no unreachable pair")


# ---------------------------------------------------------------------------
# fault injector
# ---------------------------------------------------------------------------

def test_fault_injector_deterministic_and_budgeted():
    """Same seed -> identical failure schedule per site, independent of how
    other sites interleave; max_failures heals the site."""
    def schedule(inj, n=50):
        out = []
        for _ in range(n):
            try:
                inj.maybe_fail("engine.vmap")
                out.append(False)
            except InjectedFault:
                out.append(True)
        return out

    a = FaultInjector(seed=7, rates={"engine.vmap": 0.3})
    b = FaultInjector(seed=7, rates={"engine.vmap": 0.3})
    for _ in range(17):          # interleaved draws at another site must
        b.maybe_fail("upload")   # not perturb engine.vmap's stream
    assert schedule(a) == schedule(b)
    assert any(schedule(FaultInjector(seed=7, rates={"engine.vmap": 0.3})))

    healed = FaultInjector(
        seed=7, rates={"engine.vmap": FaultSpec(rate=1.0, max_failures=3)})
    fired = schedule(healed, n=10)
    assert fired == [True] * 3 + [False] * 7
    assert healed.failures["engine.vmap"] == 3
    assert healed.draws["engine.vmap"] == 10


def test_fault_injector_rejects_unknown_site():
    inj = FaultInjector()
    with pytest.raises(ValueError, match="unknown fault site"):
        inj.maybe_fail("engine.tpu")
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultInjector(rates={"nope": 0.5})


def test_poison_pair_is_permanent():
    inj = FaultInjector(seed=0, poison=[(3, 4)])
    batch = np.array([[0, 1], [3, 4]])
    with pytest.raises(InjectedFault) as ei:
        inj.maybe_fail("engine.vmap", pairs=batch)
    assert ei.value.permanent
    inj.maybe_fail("engine.vmap", pairs=np.array([[0, 1]]))  # no poison: ok


# ---------------------------------------------------------------------------
# submit validation (satellite: endpoint range check)
# ---------------------------------------------------------------------------

def test_submit_validates_endpoints():
    g, fr = _case()
    srv = _server(fr, warm=False)
    for s, t in [(0, g.n), (g.n, 0), (-1, 0), (0, -1), (g.n + 5, 2)]:
        with pytest.raises(ValueError, match="out of range"):
            srv.submit(s, t)
    assert srv.pending() == 0     # nothing half-enqueued
    srv.submit(0, g.n - 1)        # boundary ids are valid
    assert srv.pending() == 1


# ---------------------------------------------------------------------------
# retry / bisect / dead-letter
# ---------------------------------------------------------------------------

def test_poison_request_quarantined_not_blocking():
    """Regression (satellite): a permanently-failing request used to
    re-queue its whole chunk at the head forever, starving every later
    submitter.  Now it is bisected out and dead-lettered while every
    unrelated request — batchmates and later submitters — is served."""
    g, fr = _case()
    chaos = FaultInjector(seed=0, poison=[(0, 1)])
    srv = _server(fr, chaos=chaos)
    poison = srv.submit(0, 1)
    mates = [srv.submit(2 + i, 10 + i) for i in range(5)]
    srv.flush()
    assert poison.status == "dead_letter"
    assert isinstance(poison.error, DeadLetterError)
    assert isinstance(poison.error.cause, InjectedFault)
    assert poison.error.cause.permanent
    assert srv.dead_letters == [poison]
    for r in mates:
        assert r.status == "done"
        assert r.value == oracle_reach(g, r.s, r.t)
    # later submitters are not blocked either
    later = srv.submit(5, 6)
    srv.flush()
    assert later.status == "done"
    assert later.value == oracle_reach(g, 5, 6)
    assert srv.pending() == 0


def test_transient_faults_retry_with_backoff_to_success():
    g, fr = _case()
    chaos = FaultInjector(
        seed=0, rates={"engine.vmap": FaultSpec(rate=1.0, max_failures=2)})
    sleeps = []
    srv = _server(fr, chaos=chaos, sleep=sleeps.append,
                  retry=RetryPolicy(max_attempts=4, base_delay_ms=5.0,
                                    max_delay_ms=8.0))
    reqs = [srv.submit(i, i + 3) for i in range(4)]
    srv.flush()
    for r in reqs:
        assert r.status == "done"
        assert r.value == oracle_reach(g, r.s, r.t)
        assert r.attempts == 3           # 2 injected failures + 1 success
    assert srv.retries == 2
    assert sleeps == [0.005, 0.008]      # exponential, capped at max_delay
    assert not srv.dead_letters


def test_permanent_fault_skips_backoff():
    """A permanent fault must not burn the batchmates' latency budgets on
    pointless sleeps: bisection starts immediately."""
    g, fr = _case()
    chaos = FaultInjector(seed=0, poison=[(0, 1)])
    sleeps = []
    srv = _server(fr, chaos=chaos, sleep=sleeps.append,
                  retry=RetryPolicy(max_attempts=5, base_delay_ms=50.0))
    srv.submit(0, 1)
    mate = srv.submit(2, 3)
    srv.flush()
    assert sleeps == []
    assert mate.status == "done"


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_admission_cost_ordering():
    _, fr = _case()
    reach = estimate_cost(fr, "reach")
    dist = estimate_cost(fr, "dist")
    rpq_warm = estimate_cost(fr, "rpq", states=3)
    rpq_cold = estimate_cost(fr, "rpq", states=3, closure_cached=False)
    assert reach < dist          # tropical costs more than Boolean
    assert reach < rpq_warm      # product system is states^2 bigger
    assert rpq_warm < rpq_cold   # closure build charged when uncached


def test_admission_lanes_and_red_rejection():
    g, fr = _case()
    reach_cost = estimate_cost(fr, "reach")
    policy = AdmissionPolicy(green_max=reach_cost, red_max=reach_cost * 3)
    srv = _server(fr, admission=policy, with_dist=True)
    qa = build_query_automaton("(0|1)*", lambda x: int(x))

    green = srv.submit(0, 5)
    yellow = srv.submit(0, 5, kind="dist")
    assert green.lane == GREEN and green.cost == reach_cost
    assert yellow.lane == YELLOW and yellow.cost > reach_cost

    with pytest.raises(QueryTooExpensive) as ei:        # cold RPQ is RED
        srv.submit(0, 5, kind="rpq", automaton=qa)
    assert ei.value.estimate > ei.value.limit == reach_cost * 3
    assert ei.value.kind == "rpq" and ei.value.permanent
    assert srv.rejected == 1
    assert srv.pending() == 2            # the rejected query never queued

    srv.flush()
    assert green.value == oracle_reach(g, 0, 5)
    assert yellow.value == oracle_dist(g, 0, 5)


def test_admission_default_policy_never_rejects():
    _, fr = _case()
    policy = AdmissionPolicy.for_fragmentation(fr)
    assert policy.red_max is None
    huge = estimate_cost(fr, "rpq", states=50, closure_cached=False)
    assert policy.lane(huge) == YELLOW   # expensive -> yellow, not rejected
    assert policy.lane(estimate_cost(fr, "reach")) == GREEN
    with pytest.raises(ValueError, match="red_max"):
        AdmissionPolicy(green_max=10.0, red_max=5.0)


def test_rpq_admission_cost_drops_once_closure_cached():
    """The same regex is charged the closure build only while cold: after
    one drain built the product closure, resubmitting is cheaper."""
    _, fr = _case()
    srv = _server(fr)
    cold = srv.submit(0, 5, kind="rpq", regex="(0|1)*")
    srv.flush()
    warm = srv.submit(0, 5, kind="rpq", regex="(0|1)*")
    srv.flush()
    assert warm.cost < cold.cost


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------

def test_expired_deadline_fails_fast():
    g, fr = _case()
    now = {"t": 0.0}
    srv = _server(fr, clock=lambda: now["t"])
    stale = srv.submit(0, 5, deadline_ms=50.0)
    fresh = srv.submit(1, 6)
    now["t"] = 1.0                       # budget long gone before the drain
    srv.flush()
    assert stale.status == "deadline"
    assert isinstance(stale.error, DeadlineExceeded)
    assert stale.value is None          # never served
    assert fresh.status == "done"
    assert fresh.value == oracle_reach(g, 1, 6)


def test_near_deadline_ships_partial_bucket():
    """A request whose budget is inside the ship margin must not wait for
    the bucket to fill or for batch_wait: the scheduler ships a
    partially-full bucket immediately (continuous mode)."""
    g, fr = _case()
    # batch_wait is effectively infinite, so only deadline pressure can
    # ship the 2-of-8 bucket before the timeout
    srv = _server(fr, batch_size=8, start=True, batch_wait_ms=60_000.0,
                  ship_margin_ms=1000.0)
    try:
        relaxed = srv.submit(1, 3)
        urgent = srv.submit(0, 5, deadline_ms=500.0)  # inside ship margin
        assert urgent.result(timeout=30.0) == oracle_reach(g, 0, 5)
        assert urgent.status == "done"
        # the partial bucket carried its lane-mate along (FIFO)
        assert relaxed.result(timeout=30.0) == oracle_reach(g, 1, 3)
        assert srv.batches_run == 1      # one 2-of-8 bucket, not two
    finally:
        srv.close()


def test_far_deadline_does_not_split_bucket():
    _, fr = _case()
    now = {"t": 0.0}
    srv = _server(fr, batch_size=8, clock=lambda: now["t"])
    srv.submit(0, 5, deadline_ms=60_000.0)
    for i in range(5):
        srv.submit(i, i + 2)
    srv.flush()
    assert srv.batches_run == 1          # plenty of budget: one fused batch


# ---------------------------------------------------------------------------
# failed-delta rollback (satellite: both backends)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["vmap", "shard_map"])
def test_delta_failure_rolls_back_to_pre_delta_snapshot(backend):
    """An injected failure mid-apply (after the host arrays mutated) must
    leave no trace: arrays_version and cache_version unchanged, answers
    still matching the pre-delta oracle; once the fault budget is spent
    the same delta applies cleanly and the new edge becomes visible."""
    g, fr = _case(seed=2)
    chaos = FaultInjector(
        seed=0, rates={"delta.repair": FaultSpec(rate=1.0, max_failures=1)})
    srv = _server(fr, backend=backend, chaos=chaos)
    srv.serve_pairs([(0, 1)])            # build the cache pre-delta
    u, v = _unreachable_pair(g)

    v0, av0 = srv.session.cache_version, fr.arrays_version
    upd = srv.submit_delta(GraphDelta.insert([(u, v)]))
    post = srv.submit(u, v)
    srv.flush()

    assert upd.status == "failed"
    assert isinstance(upd.error, DeltaApplyFailed) and upd.error.rolled_back
    assert isinstance(upd.error.cause, InjectedFault)
    assert srv.updates_failed == 1 and srv.session.stats.rollbacks == 1
    assert fr.arrays_version == av0      # rollback: version NOT bumped
    assert srv.session.cache_version == v0
    assert fr.g.m == g.m                 # the edge never landed
    # the query behind the failed update answers against the pre-delta
    # graph, exactly once
    assert post.status == "done"
    assert post.value == oracle_reach(g, u, v) is False

    # fault budget spent: the retried delta applies and flips the answer
    upd2 = srv.submit_delta(GraphDelta.insert([(u, v)]))
    post2 = srv.submit(u, v)
    srv.flush()
    assert upd2.status == "applied" and upd2.value is not None
    assert srv.session.cache_version == v0 + 1
    assert post2.value is True


@pytest.mark.parametrize("backend", ["vmap", "shard_map"])
def test_delta_rollback_with_dist_cache(backend):
    """Same rollback contract when the tropical cache is live (the sharded
    path falls through to the host repair for dist caches)."""
    g, fr = _case(seed=4)
    chaos = FaultInjector(
        seed=0, rates={"delta.repair": FaultSpec(rate=1.0, max_failures=1)})
    srv = _server(fr, backend=backend, chaos=chaos, with_dist=True)
    srv.serve_pairs([(0, 1)], kind="dist")
    v0 = srv.session.cache_version
    upd = srv.submit_delta(GraphDelta.insert([(2, 3)]))
    q = srv.submit(2, 3, kind="dist")
    srv.flush()
    assert upd.status == "failed"
    assert srv.session.cache_version == v0
    assert q.status == "done" and q.value == oracle_dist(g, 2, 3)


# ---------------------------------------------------------------------------
# degraded-mode fallback (shard_map engine failure -> vmap, exact answers)
# ---------------------------------------------------------------------------

def test_shard_map_failure_degrades_to_vmap_exact():
    g, fr = _case(seed=3)
    chaos = FaultInjector(seed=0, rates={"engine.shard_map": 1.0})
    sess = repro.connect(fr, backend="shard_map", chaos=chaos)
    sess.warm(with_dist=True)
    qa = build_query_automaton("(0|1)*", lambda x: int(x))
    queries = [Reach(0, 5), Dist(0, 5), Rpq(0, 5, automaton=qa)]
    res = sess.run(queries)
    assert all(r.degraded for r in res)
    assert sess.stats.degraded_groups == 3        # one per (kind) group
    # degraded answers are EXACT — served from the host rvset cache
    assert res[0].answer == oracle_reach(g, 0, 5)
    assert res[1].distance == oracle_dist(g, 0, 5)
    assert res[2].answer == oracle_rpq(g, 0, 5, qa)
    # healthy session on the same fragmentation: no degradation flag
    healthy = repro.connect(fr, backend="shard_map").run(queries)
    assert not any(r.degraded for r in healthy)
    assert [r.answer for r in healthy] == [r.answer for r in res]


def test_upload_failure_degrades_too():
    g, fr = _case(seed=3)
    chaos = FaultInjector(seed=0, rates={"upload": 1.0})
    srv = _server(fr, backend="shard_map", chaos=chaos)
    r = srv.submit(0, 5)
    srv.flush()
    assert r.status == "done" and r.degraded
    assert r.value == oracle_reach(g, 0, 5)
    assert srv.session.stats.degraded_groups == 1


# ---------------------------------------------------------------------------
# exactly-once property under seeded chaos (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_exactly_once_resolution_under_seeded_chaos(seed):
    """Under a random seeded fault schedule every submitted request reaches
    exactly one terminal status — answered, dead-lettered, or
    deadline-failed — never lost, never double-served; answered results
    match the oracle of the graph snapshot their position saw."""
    g, fr = _case(n=24, m=50, seed=5)
    chaos = FaultInjector(seed=seed, rates={"engine.vmap": 0.3,
                                            "delta.repair": 0.3})
    srv = _server(fr, chaos=chaos, batch_size=4,
                  retry=RetryPolicy(max_attempts=4, base_delay_ms=0.0))
    qa = build_query_automaton("(0|1)*", lambda x: int(x))
    rng = np.random.default_rng(100 + seed)

    submitted = []
    for _ in range(3):                       # 3 segments split by updates
        for _ in range(9):
            s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
            kind = int(rng.integers(3))
            if kind == 0:
                submitted.append(srv.submit(s, t))
            elif kind == 1:
                submitted.append(srv.submit(s, t, kind="dist"))
            else:
                submitted.append(srv.submit(s, t, kind="rpq", automaton=qa))
        edge = [(int(rng.integers(g.n)), int(rng.integers(g.n)))]
        submitted.append(srv.submit_delta(GraphDelta.insert(edge)))
    served = srv.flush()

    # exactly-once: the served list is a permutation of the submissions
    assert sorted(map(id, served)) == sorted(map(id, submitted))
    assert len(set(map(id, served))) == len(served)
    assert srv.pending() == 0
    assert all(r.status != "pending" for r in submitted)

    # replay in submission order to know each request's graph snapshot
    cur = g
    for r in submitted:
        if isinstance(r, UpdateRequest):
            assert r.status in ("applied", "failed")
            if r.status == "applied":
                cur = Graph(cur.n,
                            np.concatenate([cur.src, r.delta.add_src]),
                            np.concatenate([cur.dst, r.delta.add_dst]),
                            cur.labels, cur.label_names)
            continue
        assert r.status in ("done", "dead_letter"), r.status
        if r.status != "done":
            assert isinstance(r.error, DeadLetterError)
            continue
        if r.kind == "reach":
            assert r.value == oracle_reach(cur, r.s, r.t)
        elif r.kind == "dist":
            assert r.value == oracle_dist(cur, r.s, r.t)
        else:
            assert r.value == oracle_rpq(cur, r.s, r.t, qa)


# ---------------------------------------------------------------------------
# 8-fake-device subprocess acceptance run (ISSUE 7 acceptance criteria)
# ---------------------------------------------------------------------------

_CHAOS_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
sys.path.insert(0, "__SRC__")
sys.path.insert(0, "__TESTS__")
import numpy as np
import repro
from repro.core import GraphDelta, build_query_automaton, fragment_graph
from repro.graph import erdos_renyi, random_partition
from repro.graph.graph import Graph
from repro.serve import (FaultInjector, QueryServer, RetryPolicy,
                         UpdateRequest)
from oracles import oracle_dist, oracle_reach, oracle_rpq

g = erdos_renyi(40, 90, n_labels=3, seed=11)
fr = fragment_graph(g, random_partition(g, 8, 1), 8,
                    reserve_boundary=16, reserve_edges=32, reserve_stubs=16)
poison = (1, 2)
# the seeded 1% schedule of the acceptance criteria, every site at once
chaos = FaultInjector(seed=5, rates={"engine.shard_map": 0.01,
                                     "engine.vmap": 0.01,
                                     "upload": 0.01,
                                     "delta.repair": 0.01},
                      poison=[poison])
srv = QueryServer(fr, batch_size=8, chaos=chaos, start=False,
                  retry=RetryPolicy(max_attempts=3, base_delay_ms=0.0))
qa = build_query_automaton("(0|1)*", lambda x: int(x))
rng = np.random.default_rng(3)

submitted = []
for round_ in range(4):
    for _ in range(12):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        kind = int(rng.integers(3))
        if kind == 0:
            submitted.append(srv.submit(s, t))
        elif kind == 1:
            submitted.append(srv.submit(s, t, kind="dist"))
        else:
            submitted.append(srv.submit(s, t, kind="rpq", automaton=qa))
    submitted.append(srv.submit(*poison))          # the poison request
    edge = [(int(rng.integers(g.n)), int(rng.integers(g.n)))]
    submitted.append(srv.submit_delta(GraphDelta.insert(edge)))
served = srv.flush()

exactly_once = (sorted(map(id, served)) == sorted(map(id, submitted))
                and len(set(map(id, served))) == len(served)
                and srv.pending() == 0
                and all(r.status != "pending" for r in submitted))

cur = g
answers_ok = True
poison_ok = True
unexpected_dead = 0
n_done = n_poison = 0
for r in submitted:
    if isinstance(r, UpdateRequest):
        if r.status == "applied":
            cur = Graph(cur.n, np.concatenate([cur.src, r.delta.add_src]),
                        np.concatenate([cur.dst, r.delta.add_dst]),
                        cur.labels, cur.label_names)
        continue
    if (r.s, r.t) == poison:
        n_poison += 1
        poison_ok = poison_ok and r.status == "dead_letter"
        continue
    if r.status == "done":
        n_done += 1
        if r.kind == "reach":
            want = oracle_reach(cur, r.s, r.t)
        elif r.kind == "dist":
            want = oracle_dist(cur, r.s, r.t)
        else:
            want = oracle_rpq(cur, r.s, r.t, qa)
        answers_ok = answers_ok and (r.value == want)
    else:
        unexpected_dead += 1

# phase 2: force a total shard_map outage on the same fragmentation and
# assert the vmap fallback serves exact answers flagged degraded=True
chaos2 = FaultInjector(seed=6, rates={"engine.shard_map": 1.0})
srv2 = QueryServer(fr, batch_size=8, chaos=chaos2, warm=False, start=False,
                   retry=RetryPolicy(max_attempts=2, base_delay_ms=0.0))
reqs2 = [srv2.submit(int(rng.integers(g.n)), int(rng.integers(g.n)))
         for _ in range(8)]
srv2.flush()
degraded_ok = all(r.status == "done" and r.degraded
                  and r.value == oracle_reach(cur, r.s, r.t)
                  for r in reqs2)

print(json.dumps({
    "backend": srv.session.backend,
    "exactly_once": bool(exactly_once),
    "answers_ok": bool(answers_ok),
    "poison_ok": bool(poison_ok),
    "n_poison": n_poison,
    "unexpected_dead": unexpected_dead,
    "n_done": n_done,
    "dead_letters": len(srv.dead_letters),
    "injected": {k: v for k, v in chaos.failures.items() if v},
    "retries": srv.retries,
    "updates": [srv.updates_applied, srv.updates_failed],
    "rollbacks": srv.session.stats.rollbacks,
    "degraded_groups_p1": srv.session.stats.degraded_groups,
    "degraded_ok": bool(degraded_ok),
    "degraded_groups_p2": srv2.session.stats.degraded_groups,
}))
"""


@pytest.fixture(scope="module")
def chaos_report():
    here = os.path.dirname(__file__)
    code = (_CHAOS_SUBPROC
            .replace("__SRC__", os.path.abspath(os.path.join(here, "..",
                                                             "src")))
            .replace("__TESTS__", os.path.abspath(here)))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_chaos_subprocess_exactly_once_and_oracle(chaos_report):
    """Acceptance: 8 fake devices, mixed workload + interleaved deltas at a
    seeded 1% fault rate — zero lost / double-served requests, every
    answered result matching the oracle of its snapshot."""
    rep = chaos_report
    assert rep["backend"] == "shard_map"
    assert rep["exactly_once"], rep
    assert rep["answers_ok"], rep
    assert rep["unexpected_dead"] == 0, rep     # only poison dead-letters
    assert rep["n_done"] > 40, rep


def test_chaos_subprocess_poison_dead_lettered(chaos_report):
    rep = chaos_report
    assert rep["poison_ok"], rep
    assert rep["n_poison"] >= 4, rep            # one per round (rng may add
    assert rep["dead_letters"] == rep["n_poison"], rep     # more draws)


def test_chaos_subprocess_schedule_fired(chaos_report):
    """The seeded schedule must actually inject faults (else the run
    proves nothing) and the server must have retried or degraded through
    them."""
    rep = chaos_report
    assert rep["injected"], rep
    assert rep["retries"] > 0 or rep["degraded_groups_p1"] > 0, rep


def test_chaos_subprocess_degraded_fallback(chaos_report):
    """Total shard_map outage: every group transparently served by the
    vmap fallback, exact answers, flagged degraded=True."""
    rep = chaos_report
    assert rep["degraded_ok"], rep
    assert rep["degraded_groups_p2"] > 0, rep
