"""disReach / disDist correctness vs oracles, incl. hypothesis properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dis_dist, dis_reach, fragment_graph
from repro.graph import (bfs_partition, block_partition, erdos_renyi,
                         preferential_attachment, random_partition)

from oracles import oracle_dist, oracle_reach


def _case(n, m, k, seed, partitioner=random_partition):
    g = erdos_renyi(n, m, n_labels=4, seed=seed)
    part = partitioner(g, k, seed) if partitioner is random_partition \
        else partitioner(g, k)
    return g, fragment_graph(g, part, k)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("k", [1, 2, 4])
def test_reach_matches_oracle(seed, k):
    rng = np.random.default_rng(seed)
    g, fr = _case(int(rng.integers(8, 40)), int(rng.integers(10, 120)), k, seed)
    for _ in range(8):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        assert dis_reach(fr, s, t).answer == oracle_reach(g, s, t)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("k", [1, 3])
def test_dist_matches_oracle(seed, k):
    rng = np.random.default_rng(seed + 40)
    g, fr = _case(int(rng.integers(8, 40)), int(rng.integers(10, 120)), k, seed)
    for _ in range(6):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        assert dis_dist(fr, s, t).distance == oracle_dist(g, s, t)


def test_bounded_reach_semantics():
    # path 0->1->2->3 plus shortcut 0->3
    from repro.graph.graph import Graph
    g = Graph(5, np.array([0, 1, 2, 0]), np.array([1, 2, 3, 3]),
              np.zeros(5, np.int32))
    part = np.array([0, 1, 0, 1, 0], dtype=np.int32)
    fr = fragment_graph(g, part, 2)
    assert dis_dist(fr, 0, 3).distance == 1
    assert dis_dist(fr, 0, 3, bound=1).answer
    assert dis_dist(fr, 1, 3, bound=1).answer is False   # dist 2 > 1
    assert dis_dist(fr, 1, 3, bound=2).answer
    assert dis_dist(fr, 3, 0, bound=10).answer is False  # unreachable
    assert dis_dist(fr, 4, 4, bound=0).answer            # trivial


@pytest.mark.parametrize("partitioner", [block_partition, bfs_partition])
def test_partitioner_independence(partitioner):
    """Guarantee: answers hold no matter how G is fragmented."""
    g = preferential_attachment(60, 3, seed=7)
    fr = fragment_graph(g, partitioner(g, 4) if partitioner is block_partition
                        else partitioner(g, 4, 0), 4)
    rng = np.random.default_rng(0)
    for _ in range(10):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        assert dis_reach(fr, s, t).answer == oracle_reach(g, s, t)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_reach_any_fragmentation(data):
    """Hypothesis: random graph x random fragmentation x random query —
    disReach == oracle, and the traffic stays within the paper's bound."""
    n = data.draw(st.integers(4, 24), label="n")
    m = data.draw(st.integers(0, 60), label="m")
    k = data.draw(st.integers(1, 5), label="k")
    seed = data.draw(st.integers(0, 10_000), label="seed")
    g = erdos_renyi(n, m, n_labels=3, seed=seed)
    part = np.asarray(
        data.draw(st.lists(st.integers(0, k - 1), min_size=n, max_size=n),
                  label="part"), dtype=np.int32)
    fr = fragment_graph(g, part, k)
    s = data.draw(st.integers(0, n - 1), label="s")
    t = data.draw(st.integers(0, n - 1), label="t")
    res = dis_reach(fr, s, t)
    assert res.answer == oracle_reach(g, s, t)
    # Theorem 1(c): payload bits O(|V_f|^2); B = |V_f|+2.  The engine ships
    # the matrix bitpacked into uint32 words, so the exact count is
    # B * ceil(B/32) words — O(B^2) plus word-alignment slack.
    assert res.stats.payload_bits <= fr.B * ((fr.B + 31) // 32) * 32
    assert res.stats.collective_rounds <= 1


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_property_dist_matches_bfs(data):
    n = data.draw(st.integers(4, 20))
    m = data.draw(st.integers(0, 50))
    k = data.draw(st.integers(1, 4))
    seed = data.draw(st.integers(0, 10_000))
    g = erdos_renyi(n, m, n_labels=3, seed=seed)
    fr = fragment_graph(g, random_partition(g, k, seed), k)
    s = data.draw(st.integers(0, n - 1))
    t = data.draw(st.integers(0, n - 1))
    assert dis_dist(fr, s, t).distance == oracle_dist(g, s, t)


def test_single_fragment_degenerate():
    g = erdos_renyi(30, 80, seed=3)
    fr = fragment_graph(g, np.zeros(30, np.int32), 1)
    assert fr.B == 2  # only the s/t slots
    rng = np.random.default_rng(1)
    for _ in range(6):
        s, t = int(rng.integers(30)), int(rng.integers(30))
        assert dis_reach(fr, s, t).answer == oracle_reach(g, s, t)


def test_empty_graph_and_isolated_nodes():
    from repro.graph.graph import Graph
    g = Graph(4, np.array([], np.int64), np.array([], np.int64),
              np.zeros(4, np.int32))
    fr = fragment_graph(g, np.array([0, 1, 0, 1], np.int32), 2)
    assert dis_reach(fr, 0, 1).answer is False
    assert dis_reach(fr, 2, 2).answer is True
    assert dis_dist(fr, 0, 3).distance is None
