"""disRPQ + query-automaton correctness."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (accepts, build_query_automaton, dis_rpq,
                        dis_rpq_regex, fragment_graph)
from repro.core.mapreduce import mr_drpq
from repro.graph import erdos_renyi, labeled_chain_graph, random_partition

from oracles import oracle_rpq

LBL = lambda name: int(name)


# --- automaton unit tests ---------------------------------------------------

def test_automaton_basic():
    qa = build_query_automaton("0* 1*", LBL)
    assert accepts(qa, [])            # eps
    assert accepts(qa, [0, 0, 1])
    assert accepts(qa, [1, 1])
    assert not accepts(qa, [1, 0])
    assert qa.nullable


def test_automaton_alternation_and_plus():
    qa = build_query_automaton("(0|1)+ 2", LBL)
    assert accepts(qa, [0, 2])
    assert accepts(qa, [1, 0, 2])
    assert not accepts(qa, [2])
    assert not accepts(qa, [0])
    assert not qa.nullable


def test_automaton_wildcard_and_opt():
    qa = build_query_automaton(". . 3?", LBL)
    assert accepts(qa, [5, 7])
    assert accepts(qa, [5, 7, 3])
    assert not accepts(qa, [5])


def test_automaton_paper_example():
    """R = (DB* | HR*) from the paper's Example 1/6."""
    names = {"DB": 0, "HR": 1}
    qa = build_query_automaton("(DB* | HR*)", lambda n: names[n])
    assert accepts(qa, [1, 1, 1, 1, 1])   # the Ann->...->Mark HR chain
    assert accepts(qa, [0, 0])
    assert accepts(qa, [])
    assert not accepts(qa, [0, 1])


@settings(max_examples=30, deadline=None)
@given(word=st.lists(st.integers(0, 2), max_size=6),
       rx=st.sampled_from(["0* 1*", "(0|1)* 2", "1+", "(0 1)*", "0? 1? 2?",
                           ". *", "((0|1) 2)*"]))
def test_automaton_vs_python_re(word, rx):
    """Cross-check Glushkov acceptance against python's re on unary strings."""
    import re as pyre
    qa = build_query_automaton(rx, LBL)
    py = rx.replace(" ", "").replace("0", "a").replace("1", "b").replace("2", "c")
    s = "".join("abc"[w] for w in word)
    want = pyre.fullmatch(py, s) is not None
    assert accepts(qa, word) == want


# --- disRPQ end-to-end -------------------------------------------------------

REGEXES = ["0* 1*", "(0|1)*", "2 . *", "0 (1|2)* 3", ". . .", "1+", "0?"]


@pytest.mark.parametrize("seed", range(4))
def test_rpq_matches_oracle(seed):
    rng = np.random.default_rng(seed + 11)
    n = int(rng.integers(8, 28))
    g = erdos_renyi(n, int(rng.integers(10, 90)), n_labels=4, seed=seed)
    k = int(rng.integers(1, 5))
    fr = fragment_graph(g, random_partition(g, k, seed), k)
    for rx in REGEXES:
        qa = build_query_automaton(rx, LBL)
        for _ in range(3):
            s, t = int(rng.integers(n)), int(rng.integers(n))
            assert dis_rpq(fr, s, t, qa).answer == oracle_rpq(g, s, t, qa), \
                (rx, s, t)


def test_rpq_planted_chain_positive():
    g = labeled_chain_graph(12, 30, 80, chain_label=2, n_labels=4, seed=0)
    fr = fragment_graph(g, random_partition(g, 3, 5), 3)
    qa = build_query_automaton("2*", LBL)
    assert oracle_rpq(g, 0, 11, qa)
    assert dis_rpq(fr, 0, 11, qa).answer
    # and the matching MapReduce evaluation agrees
    assert mr_drpq(fr, 0, 11, qa).answer


def test_rpq_traffic_bound():
    """Theorem 3(c): payload O(|R|^2 |V_f|^2)."""
    g = erdos_renyi(40, 150, n_labels=4, seed=2)
    fr = fragment_graph(g, random_partition(g, 4, 2), 4)
    qa = build_query_automaton("(0|1)* 2", LBL)
    res = dis_rpq(fr, 0, 17, qa)
    # payload ships bitpacked: side * ceil(side/32) uint32 words — the
    # paper's O(|R|^2 |V_f|^2) bound plus word-alignment slack
    side = qa.n_states * fr.B
    assert res.stats.payload_bits <= side * ((side + 31) // 32) * 32
    assert res.stats.collective_rounds == 1


def test_rpq_regex_helper_with_names():
    g = labeled_chain_graph(8, 10, 20, chain_label=1, n_labels=3, seed=1)
    g.label_names = ["DB", "HR", "FA"]
    fr = fragment_graph(g, random_partition(g, 2, 0), 2)
    assert dis_rpq_regex(fr, 0, 7, "HR*").answer


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_property_rpq(data):
    n = data.draw(st.integers(5, 18))
    m = data.draw(st.integers(0, 40))
    k = data.draw(st.integers(1, 4))
    seed = data.draw(st.integers(0, 5000))
    rx = data.draw(st.sampled_from(REGEXES))
    g = erdos_renyi(n, m, n_labels=4, seed=seed)
    fr = fragment_graph(g, random_partition(g, k, seed), k)
    qa = build_query_automaton(rx, LBL)
    s = data.draw(st.integers(0, n - 1))
    t = data.draw(st.integers(0, n - 1))
    assert dis_rpq(fr, s, t, qa).answer == oracle_rpq(g, s, t, qa)
