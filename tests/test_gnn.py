"""GNN family: shapes, finiteness, and E(3)/E(n) equivariance properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.gnn import common, e3, egnn, equivariant, gat, sampler


def _random_graph(rng, n=20, e=60, n_max=24, e_max=80, n_graphs=1):
    senders = rng.integers(0, n, e)
    receivers = rng.integers(0, n, e)
    gi = rng.integers(0, n_graphs, n)
    return common.pad_graph(senders, receivers, n, e_max, n_max,
                            graph_ids=gi, n_graphs=n_graphs)


def _rotation(seed=0):
    from scipy.spatial.transform import Rotation
    return jnp.asarray(Rotation.random(random_state=seed).as_matrix(),
                       jnp.float32)


# --- e3 library --------------------------------------------------------------

def test_cg_invariance_under_rotation():
    rng = np.random.default_rng(0)

    def wigner_from_sh(l, R):
        X = rng.normal(size=(80, 3)).astype(np.float32)
        Y = np.asarray(e3.spherical_harmonics(jnp.asarray(X), 3)[l],
                       np.float64)
        YR = np.asarray(e3.spherical_harmonics(jnp.asarray(X) @ R.T, 3)[l],
                        np.float64)
        D, *_ = np.linalg.lstsq(Y, YR, rcond=None)
        return D.T

    R = np.asarray(_rotation(3), np.float64)
    D = {l: wigner_from_sh(l, R) for l in range(4)}
    for l in range(4):
        assert np.allclose(D[l] @ D[l].T, np.eye(2 * l + 1), atol=2e-4)
    for l1 in range(3):
        for l2 in range(3):
            for l3 in range(abs(l1 - l2), min(l1 + l2, 3) + 1):
                cg = e3.real_clebsch_gordan(l1, l2, l3)
                rot = np.einsum("ai,bj,ck,ijk->abc", D[l1], D[l2], D[l3], cg)
                assert np.allclose(rot, cg, atol=2e-3), (l1, l2, l3)


def test_cg_nonzero_and_selection_rules():
    for l1 in range(3):
        for l2 in range(3):
            for l3 in range(4):
                cg = e3.su2_clebsch_gordan(l1, l2, l3)
                if abs(l1 - l2) <= l3 <= l1 + l2:
                    assert np.abs(cg).max() > 0
                else:
                    assert np.abs(cg).max() == 0


def test_bessel_rbf_cutoff():
    r = jnp.asarray([0.1, 2.5, 4.99, 5.0, 7.0])
    rbf = e3.bessel_rbf(r, 8, 5.0)
    assert rbf.shape == (5, 8)
    np.testing.assert_allclose(np.asarray(rbf[3:]), 0.0, atol=1e-5)
    assert np.isfinite(np.asarray(rbf)).all()


# --- message-passing substrate ----------------------------------------------

def test_edge_softmax_normalizes():
    rng = np.random.default_rng(0)
    g = _random_graph(rng)
    scores = jnp.asarray(rng.normal(size=(80, 4)), jnp.float32)
    alpha = common.edge_softmax(scores, g.receivers, g.edge_mask, 24)
    sums = jax.ops.segment_sum(alpha, g.receivers, num_segments=24)
    live = np.asarray(jax.ops.segment_sum(
        g.edge_mask.astype(jnp.float32), g.receivers, num_segments=24)) > 0
    np.testing.assert_allclose(np.asarray(sums)[live], 1.0, atol=1e-5)


# --- GAT ----------------------------------------------------------------------

def test_gat_forward_and_loss():
    rng = np.random.default_rng(1)
    cfg = gat.GATConfig(d_in=33, n_classes=5)
    params = gat.init_params(cfg, jax.random.key(0))
    g = _random_graph(rng)
    x = jnp.asarray(rng.normal(size=(24, 33)), jnp.float32)
    logits = gat.forward(cfg, params, x, g)
    assert logits.shape == (24, 5)
    assert np.isfinite(np.asarray(logits)).all()
    labels = jnp.asarray(rng.integers(0, 5, 24), jnp.int32)
    mask = jnp.asarray(rng.random(24) < 0.5, jnp.float32)
    lval = gat.loss(cfg, params, x, g, labels, mask)
    grads = jax.grad(lambda p: gat.loss(cfg, p, x, g, labels, mask))(params)
    assert np.isfinite(float(lval))
    assert all(np.isfinite(np.asarray(v)).all()
               for v in jax.tree.leaves(grads))


# --- EGNN: E(n) equivariance ---------------------------------------------------

def test_egnn_energy_invariant_coords_equivariant():
    rng = np.random.default_rng(2)
    cfg = egnn.EGNNConfig(d_in=7)
    params = egnn.init_params(cfg, jax.random.key(0))
    g = _random_graph(rng, n_graphs=3)
    feats = jnp.asarray(rng.normal(size=(24, 7)), jnp.float32)
    coords = jnp.asarray(rng.normal(size=(24, 3)), jnp.float32)
    R = _rotation(1)
    shift = jnp.asarray([0.3, -1.2, 2.0])
    e1, h1, x1 = egnn.forward(cfg, params, feats, coords, g)
    e2, h2, x2 = egnn.forward(cfg, params, feats, coords @ R.T + shift, g)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(x2), np.asarray(x1 @ R.T + shift),
                               atol=1e-3, rtol=1e-3)


def test_egnn_forces():
    rng = np.random.default_rng(3)
    cfg = egnn.EGNNConfig(d_in=7)
    params = egnn.init_params(cfg, jax.random.key(0))
    g = _random_graph(rng)
    feats = jnp.asarray(rng.normal(size=(24, 7)), jnp.float32)
    coords = jnp.asarray(rng.normal(size=(24, 3)), jnp.float32)
    e, f = egnn.energy_and_forces(cfg, params, feats, coords, g)
    assert f.shape == (24, 3)
    assert np.isfinite(np.asarray(f)).all()


# --- NequIP / MACE: E(3) equivariance -----------------------------------------

@pytest.mark.parametrize("arch,layers,channels,corr",
                         [("nequip", 2, 8, 1), ("mace", 2, 8, 3)])
def test_equivariant_energy_invariance(arch, layers, channels, corr):
    rng = np.random.default_rng(4)
    cfg = equivariant.EquivariantConfig(arch=arch, n_layers=layers,
                                        channels=channels, l_max=2,
                                        correlation=corr, n_species=4,
                                        cutoff=3.0)
    params = equivariant.init_params(cfg, jax.random.key(0))
    g = _random_graph(rng, n=12, e=36, n_max=16, e_max=48, n_graphs=2)
    species = jnp.asarray(rng.integers(0, 4, 16), jnp.int32)
    coords = jnp.asarray(rng.normal(size=(16, 3)), jnp.float32)
    R = _rotation(2)
    shift = jnp.asarray([1.0, 0.5, -0.7])
    e1 = equivariant.forward(cfg, params, species, coords, g)
    e2 = equivariant.forward(cfg, params, species, coords @ R.T + shift, g)
    assert np.isfinite(np.asarray(e1)).all()
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("arch", ["nequip", "mace"])
def test_equivariant_forces_rotate(arch):
    rng = np.random.default_rng(5)
    cfg = equivariant.EquivariantConfig(arch=arch, n_layers=1, channels=8,
                                        l_max=2, correlation=2, n_species=4,
                                        cutoff=3.0)
    params = equivariant.init_params(cfg, jax.random.key(0))
    g = _random_graph(rng, n=10, e=30, n_max=12, e_max=40)
    species = jnp.asarray(rng.integers(0, 4, 12), jnp.int32)
    coords = jnp.asarray(rng.normal(size=(12, 3)), jnp.float32)
    R = _rotation(7)
    _, f1 = equivariant.energy_and_forces(cfg, params, species, coords, g)
    _, f2 = equivariant.energy_and_forces(cfg, params, species,
                                          coords @ R.T, g)
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f1 @ R.T),
                               atol=2e-3, rtol=1e-3)


# --- sampler -------------------------------------------------------------------

def test_host_sampler_shapes_and_membership():
    from repro.graph import erdos_renyi, csr_from_coo
    g = erdos_renyi(200, 1200, seed=0)
    indptr, indices = csr_from_coo(g.n, g.src, g.dst)
    seeds = np.array([3, 77, 150])
    node_ids, s, r = sampler.sample_subgraph_host(indptr, indices, seeds,
                                                  [5, 3], seed=1)
    assert (node_ids[:3] == seeds).all()
    assert s.max() < len(node_ids) and r.max() < len(node_ids)
    assert len(s) == 3 * 5 + len(np.unique(np.concatenate(
        [seeds, node_ids]))) * 0 + (len(s) - 15)  # trivially consistent


def test_device_sampler_jit():
    from repro.graph import erdos_renyi, csr_from_coo
    g = erdos_renyi(100, 600, seed=1)
    indptr, indices = csr_from_coo(g.n, g.src, g.dst)
    seeds = jnp.asarray([0, 5, 9], jnp.int32)
    fn = jax.jit(lambda k: sampler.sample_fanout_device(
        k, jnp.asarray(indptr), jnp.asarray(indices), seeds, 4))
    s, r = fn(jax.random.key(0))
    assert s.shape == (12,) and r.shape == (12,)
    # senders are actual neighbors (or self-loops for degree-0)
    indptr_np, indices_np = np.asarray(indptr), np.asarray(indices)
    for si, ri in zip(np.asarray(s), np.asarray(r)):
        nbrs = indices_np[indptr_np[ri]: indptr_np[ri + 1]]
        assert si in nbrs or si == ri
