"""The paper's performance guarantees, checked structurally.

Theorem 1-3 invariants: one visit per site (== one collective round),
traffic independent of |G|, response bounded by the largest fragment.
The shard_map checks run in a subprocess so the 8 fake host devices never
leak into other tests (smoke tests must see 1 device).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import dis_reach, fragment_graph
from repro.core.baselines import dis_reach_m, dis_reach_n
from repro.graph import erdos_renyi, random_partition

from oracles import oracle_reach

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, re, sys
sys.path.insert(0, "__SRC__")
import numpy as np
from repro.graph import erdos_renyi, random_partition
from repro.core import fragment_graph, build_query_automaton
from repro.core.distributed import (dis_reach_sharded, dis_rpq_sharded,
                                    lower_reach_hlo)
import networkx as nx

g = erdos_renyi(48, 140, n_labels=4, seed=5)
part = random_partition(g, 8, seed=2)
fr = fragment_graph(g, part, 8)
G = nx.DiGraph(); G.add_nodes_from(range(g.n))
G.add_edges_from(zip(g.src.tolist(), g.dst.tolist()))

rng = np.random.default_rng(0)
ok = True
for _ in range(6):
    s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
    if s == t: continue
    ans, _ = dis_reach_sharded(fr, s, t)
    ok &= (ans == nx.has_path(G, s, t))

qa = build_query_automaton("(0|1|2|3)*", lambda x: int(x))
ans_rpq = dis_rpq_sharded(fr, 0, 17, qa)

hlo = lower_reach_hlo(fr, 0, 17)
colls = re.findall(
    r"stablehlo\.[a-z_]*(?:all_reduce|all_gather|reduce_scatter|all_to_all|"
    r"collective_permute)[a-z_]*", hlo)
print(json.dumps({"ok": bool(ok), "collectives": colls,
                  "rpq": bool(ans_rpq)}))
"""


@pytest.fixture(scope="module")
def sharded_report():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = _SUBPROC.replace("__SRC__", os.path.abspath(src))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_engine_correct(sharded_report):
    assert sharded_report["ok"]


def test_one_collective_round(sharded_report):
    """Guarantee (1): each site visited once == exactly one collective."""
    assert len(sharded_report["collectives"]) == 1, sharded_report


def test_traffic_independent_of_graph_size():
    """Guarantee (2): payload depends on |V_f|, not |G|: grow the graph
    while keeping the cut constant -> payload constant."""
    payloads, cuts = [], []
    for scale in (1, 4):
        n = 40 * scale
        g = erdos_renyi(n, 0, seed=1)
        # build a fixed 6-edge cut between halves + dense internal edges
        rng = np.random.default_rng(0)
        half = n // 2
        src = list(rng.integers(0, half, 5 * n)) + \
              list(rng.integers(half, n, 5 * n)) + [0, 1, 2, 3, 4, 5]
        dst = list(rng.integers(0, half, 5 * n)) + \
              list(rng.integers(half, n, 5 * n)) + \
              [half, half + 1, half + 2, half + 3, half + 4, half + 5]
        from repro.graph.graph import Graph
        g = Graph(n, np.array(src), np.array(dst), np.zeros(n, np.int32))
        part = (np.arange(n) >= half).astype(np.int32)
        fr = fragment_graph(g, part, 2)
        res = dis_reach(fr, 0, n - 1)
        payloads.append(res.stats.payload_bits)
        cuts.append(fr.B)
    assert cuts[0] == cuts[1]          # same boundary
    assert payloads[0] == payloads[1]  # same traffic although |G| grew 4x


def test_message_passing_baseline_visits_sites_many_times():
    """The contrast the paper measures: disReach_m has no visit bound."""
    # long chain crossing fragments repeatedly -> many rounds
    n, k = 64, 4
    src = np.arange(n - 1)
    dst = np.arange(1, n)
    from repro.graph.graph import Graph
    g = Graph(n, src, dst, np.zeros(n, np.int32))
    part = (np.arange(n) % k).astype(np.int32)   # round-robin: max crossings
    fr = fragment_graph(g, part, k)
    res = dis_reach_m(fr, 0, n - 1)
    assert res.answer
    assert res.rounds > 1                        # multiple visits per site
    one = dis_reach(fr, 0, n - 1)
    assert one.answer and one.stats.collective_rounds == 1


def test_baselines_agree_with_engine():
    rng = np.random.default_rng(4)
    g = erdos_renyi(36, 100, seed=8)
    fr = fragment_graph(g, random_partition(g, 4, 1), 4)
    for _ in range(8):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        want = oracle_reach(g, s, t)
        assert dis_reach(fr, s, t).answer == want
        assert dis_reach_n(fr, s, t).answer == want
        assert dis_reach_m(fr, s, t).answer == want


def test_response_time_scales_with_largest_fragment():
    """Guarantee (3) proxy: localEval work is per-fragment; the padded
    engine shapes are set by |F_m|, not |G|."""
    g = erdos_renyi(100, 300, seed=0)
    fr_even = fragment_graph(g, random_partition(g, 4, 0), 4)
    part_skew = np.zeros(100, np.int32)
    part_skew[:10] = np.arange(10) % 3 + 1
    fr_skew = fragment_graph(g, part_skew, 4)
    assert fr_skew.largest_fragment() > fr_even.largest_fragment()
    # shapes (compute cost proxy) follow the largest fragment
    assert fr_skew.e_max >= fr_even.e_max
