"""The paper's performance guarantees, checked structurally.

Theorem 1-3 invariants: one visit per site (== one collective round),
traffic independent of |G|, response bounded by the largest fragment.
The shard_map checks run in a subprocess so the 8 fake host devices never
leak into other tests (smoke tests must see 1 device).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import dis_reach, fragment_graph
from repro.core.baselines import dis_reach_m, dis_reach_n
from repro.graph import erdos_renyi, random_partition

from oracles import oracle_reach

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
sys.path.insert(0, "__SRC__")
sys.path.insert(0, "__TESTS__")
import numpy as np
from repro.graph import erdos_renyi, random_partition
from repro.core import fragment_graph, build_query_automaton
from repro.core.distributed import (dis_reach_sharded, dis_reach_batch_sharded,
                                    dis_dist_batch_sharded,
                                    dis_rpq_batch_sharded,
                                    dis_rpq_sharded, lower_batch_hlo,
                                    lower_reach_hlo)
from oracles import oracle_rpq
import networkx as nx

g = erdos_renyi(48, 140, n_labels=4, seed=5)
part = random_partition(g, 8, seed=2)
fr = fragment_graph(g, part, 8)
G = nx.DiGraph(); G.add_nodes_from(range(g.n))
G.add_edges_from(zip(g.src.tolist(), g.dst.tolist()))

def nx_dist(s, t):
    try:
        return nx.shortest_path_length(G, s, t)
    except nx.NetworkXNoPath:
        return -1

rng = np.random.default_rng(0)
ok = True
for _ in range(6):
    s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
    if s == t: continue
    ans, _ = dis_reach_sharded(fr, s, t)
    ok &= (ans == nx.has_path(G, s, t))

pairs = [(int(rng.integers(g.n)), int(rng.integers(g.n))) for _ in range(16)]
batch = dis_reach_batch_sharded(fr, pairs)
ok_batch = all(bool(a) == nx.has_path(G, s, t)
               for (s, t), a in zip(pairs, batch))

# batched sharded dist + RPQ: ONE collective each, answers vs oracles
qa_b = build_query_automaton("(0|1)* 2", lambda x: int(x))
dpairs = pairs[:8]
dbatch = dis_dist_batch_sharded(fr, dpairs)
ok_dist = all(int(d) == (0 if s == t else nx_dist(s, t))
              for (s, t), d in zip(dpairs, dbatch))
rbatch = dis_rpq_batch_sharded(fr, dpairs, qa_b)
ok_rpq_batch = all(bool(a) == oracle_rpq(g, s, t, qa_b)
                   for (s, t), a in zip(dpairs, rbatch))

# adversarial for the packed collective: chain graph, round-robin partition
# -> every node is boundary, paths are unique, and packed words mix bits
# owned by different fragments (any dropped bit flips an answer)
from repro.graph.graph import Graph
nc, kc = 64, 8
gc = Graph(nc, np.arange(nc - 1), np.arange(1, nc), np.zeros(nc, np.int32))
frc = fragment_graph(gc, (np.arange(nc) % kc).astype(np.int32), kc)
cpairs = [(0, nc - 1), (5, 60), (10, 11), (63, 0), (30, 30), (2, 50)]
cbatch = dis_reach_batch_sharded(frc, cpairs)
ok_batch &= all(bool(a) == (s <= t) for (s, t), a in zip(cpairs, cbatch))
# tropical twin: unique path lengths make any merged-wire error visible
cdist = dis_dist_batch_sharded(frc, cpairs)
ok_dist &= all(int(d) == (t - s if s <= t else -1)
               for (s, t), d in zip(cpairs, cdist))

# degenerate: single fragment, no boundary nodes at all
g1 = erdos_renyi(12, 30, seed=2)
fr1 = fragment_graph(g1, np.zeros(12, np.int32), 1)
G1 = nx.DiGraph(); G1.add_nodes_from(range(12))
G1.add_edges_from(zip(g1.src.tolist(), g1.dst.tolist()))
p1 = [(0, 5), (5, 0), (2, 2), (1, 7)]
b1 = dis_reach_batch_sharded(fr1, p1)
ok_batch &= all(bool(a) == nx.has_path(G1, s, t) for (s, t), a in zip(p1, b1))

qa = build_query_automaton("(0|1|2|3)*", lambda x: int(x))
ans_rpq = dis_rpq_sharded(fr, 0, 17, qa)

# ONE collective-matching parser in the repo: the structured model from
# repro.analysis (DESIGN.md Sec. 10.1), not a window-scanning regex.
from repro.analysis import check_program, parse_program

def coll_report(hlo, rows, cols, dtype, expected_bits=None):
    m = parse_program(hlo)
    vs = check_program(m, expect_count=1, expected_bits=expected_bits)
    return {
        "collectives": [c.kind for c in m.collectives],
        "payload_shape_ok": any(
            c.results and c.results[0].dtype == dtype
            and c.results[0].dims == (rows, cols) for c in m.collectives),
        "violations": [str(v) for v in vs],
    }

hlo = lower_reach_hlo(fr, 0, 17)
model1 = parse_program(hlo)
colls = [c.kind for c in model1.collectives]
packed = all(t.dtype == "ui32"
             for c in model1.collectives for t in c.results)
W = (fr.B + 31) // 32
payload_shape_ok = any(c.results and c.results[0].dims == (fr.B, W)
                       for c in model1.collectives)

# batched HLO, all three kinds: one collective per fused group, payload
# typed [side + 2N, side + 1] (bitpacked ui32 for reach/rpq, raw i32 for
# the tropical wire); check_program also pins payload bits to the
# fr.traffic_bits wire model (Theorem 5.5)
N, nb = 8, fr.n_boundary
side_q = nb * qa_b.n_states
batch_hlo = {
    "reach": (lower_batch_hlo(fr, dpairs, "reach"),
              (nb + 2 * N, (nb + 1 + 31) // 32, "ui32"), 1),
    "dist": (lower_batch_hlo(fr, dpairs, "dist"),
             (nb + 2 * N, nb + 1, "i32"), 1),
    "rpq": (lower_batch_hlo(fr, dpairs, "rpq", qa=qa_b),
            (side_q + 2 * N, (side_q + 1 + 31) // 32, "ui32"),
            qa_b.n_states),
}
batch_report = {}
for kind, (bh, (rows, cols, dtype), states) in batch_hlo.items():
    batch_report[kind] = coll_report(
        bh, rows, cols, dtype,
        expected_bits=fr.traffic_bits(kind, states=states, batch=N))

# ---- scale-out (k >> d): 32 fragments packed onto the 8-device mesh ----
# The one-collective-per-fused-group guarantee must hold verbatim when
# several fragments share a device: the owned boundary rows are merged
# on-device BEFORE the collective, so the wire keeps the exact
# [side + 2N, side + 1] shape of the one-fragment-per-device layout.
from repro.core import Placement
g32 = erdos_renyi(96, 300, n_labels=4, seed=9)
fr32 = fragment_graph(g32, random_partition(g32, 32, seed=3), 32)
G2 = nx.DiGraph(); G2.add_nodes_from(range(g32.n))
G2.add_edges_from(zip(g32.src.tolist(), g32.dst.tolist()))
def nx_dist2(s, t):
    try:
        return nx.shortest_path_length(G2, s, t)
    except nx.NetworkXNoPath:
        return -1
pl32 = Placement.balanced(fr32, 8)
pack_layout_ok = (pl32.d == 8 and pl32.fpd == 4
                  and sorted(pl32.device_of) == sorted(i % 8 for i in range(32)))
p32 = [(int(rng.integers(g32.n)), int(rng.integers(g32.n))) for _ in range(8)]
r32 = dis_reach_batch_sharded(fr32, p32, placement=pl32)
d32 = dis_dist_batch_sharded(fr32, p32, placement=pl32)
q32 = dis_rpq_batch_sharded(fr32, p32, qa_b, placement=pl32)
ok_pack = (all(bool(a) == nx.has_path(G2, s, t) for (s, t), a in zip(p32, r32))
           and all(int(x) == (0 if s == t else nx_dist2(s, t))
                   for (s, t), x in zip(p32, d32))
           and all(bool(a) == oracle_rpq(g32, s, t, qa_b)
                   for (s, t), a in zip(p32, q32)))

nb2, N2 = fr32.n_boundary, len(p32)
side2 = nb2 * qa_b.n_states
pack_hlo = {
    "reach": (lower_batch_hlo(fr32, p32, "reach", placement=pl32),
              (nb2 + 2 * N2, (nb2 + 1 + 31) // 32, "ui32"), 1),
    "dist": (lower_batch_hlo(fr32, p32, "dist", placement=pl32),
             (nb2 + 2 * N2, nb2 + 1, "i32"), 1),
    "rpq": (lower_batch_hlo(fr32, p32, "rpq", qa=qa_b, placement=pl32),
            (side2 + 2 * N2, (side2 + 1 + 31) // 32, "ui32"),
            qa_b.n_states),
}
pack_report = {}
for kind, (bh, (rows, cols, dtype), states) in pack_hlo.items():
    pack_report[kind] = coll_report(
        bh, rows, cols, dtype,
        expected_bits=fr32.traffic_bits(kind, states=states, batch=N2))

print(json.dumps({"ok": bool(ok), "ok_batch": bool(ok_batch),
                  "ok_dist": bool(ok_dist),
                  "ok_rpq_batch": bool(ok_rpq_batch),
                  "collectives": colls, "rpq": bool(ans_rpq),
                  "packed": bool(packed),
                  "payload_shape_ok": bool(payload_shape_ok),
                  "batch": batch_report,
                  "ok_pack": bool(ok_pack),
                  "pack_layout_ok": bool(pack_layout_ok),
                  "pack": pack_report}))
"""


@pytest.fixture(scope="module")
def sharded_report():
    here = os.path.dirname(__file__)
    src = os.path.join(here, "..", "src")
    code = (_SUBPROC.replace("__SRC__", os.path.abspath(src))
            .replace("__TESTS__", os.path.abspath(here)))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_engine_correct(sharded_report):
    assert sharded_report["ok"]


def test_one_collective_round(sharded_report):
    """Guarantee (1): each site visited once == exactly one collective —
    still true after the payload is bitpacked."""
    assert len(sharded_report["collectives"]) == 1, sharded_report


def test_collective_payload_is_bitpacked(sharded_report):
    """The one collective ships B x ceil(B/32) uint32 words (8x fewer bits
    than the seed's B x B uint8 payload), not the unpacked matrix."""
    assert sharded_report["packed"], sharded_report
    assert sharded_report["payload_shape_ok"], sharded_report


def test_batched_sharded_engine_correct(sharded_report):
    """dis_reach_batch_sharded: N pairs, one packed collective, answers
    match the oracle."""
    assert sharded_report["ok_batch"], sharded_report


def test_batched_sharded_dist_and_rpq_correct(sharded_report):
    """dis_dist_batch_sharded / dis_rpq_batch_sharded answers match the
    oracles — incl. the all-boundary chain whose unique path lengths expose
    any error in the merged tropical wire."""
    assert sharded_report["ok_dist"], sharded_report
    assert sharded_report["ok_rpq_batch"], sharded_report


@pytest.mark.parametrize("kind", ["reach", "dist", "rpq"])
def test_one_collective_per_fused_batch_all_kinds(sharded_report, kind):
    """The one-collective guarantee survives batching for ALL THREE query
    classes: the fused N-pair program lowers to exactly one collective
    whose payload is [side + 2N, side + 1] — bitpacked ui32 words for the
    Boolean kinds, raw i32 rows for the tropical wire."""
    rep = sharded_report["batch"][kind]
    assert len(rep["collectives"]) == 1, rep
    assert rep["payload_shape_ok"], rep
    assert rep["violations"] == [], rep


def test_packed_batches_correct_on_small_mesh(sharded_report):
    """k >> d: 32 fragments balanced onto 8 devices (4 per device) answer
    all three query kinds identically to the oracles."""
    assert sharded_report["pack_layout_ok"], sharded_report
    assert sharded_report["ok_pack"], sharded_report


@pytest.mark.parametrize("kind", ["reach", "dist", "rpq"])
def test_one_collective_per_fused_batch_packed_mesh(sharded_report, kind):
    """Guarantee (1) survives packing: with 32 fragments on 8 devices the
    fused batch still lowers to EXACTLY one collective per kind, and the
    wire keeps the one-fragment-per-device payload shape
    [side + 2N, side + 1] — owned rows are merged on-device before the
    collective, so co-packing adds zero bytes to the wire."""
    rep = sharded_report["pack"][kind]
    assert len(rep["collectives"]) == 1, rep
    assert rep["payload_shape_ok"], rep
    assert rep["violations"] == [], rep


def test_traffic_independent_of_graph_size():
    """Guarantee (2): payload depends on |V_f|, not |G|: grow the graph
    while keeping the cut constant -> payload constant."""
    payloads, cuts = [], []
    for scale in (1, 4):
        n = 40 * scale
        g = erdos_renyi(n, 0, seed=1)
        # build a fixed 6-edge cut between halves + dense internal edges
        rng = np.random.default_rng(0)
        half = n // 2
        src = list(rng.integers(0, half, 5 * n)) + \
              list(rng.integers(half, n, 5 * n)) + [0, 1, 2, 3, 4, 5]
        dst = list(rng.integers(0, half, 5 * n)) + \
              list(rng.integers(half, n, 5 * n)) + \
              [half, half + 1, half + 2, half + 3, half + 4, half + 5]
        from repro.graph.graph import Graph
        g = Graph(n, np.array(src), np.array(dst), np.zeros(n, np.int32))
        part = (np.arange(n) >= half).astype(np.int32)
        fr = fragment_graph(g, part, 2)
        res = dis_reach(fr, 0, n - 1)
        payloads.append(res.stats.payload_bits)
        cuts.append(fr.B)
    assert cuts[0] == cuts[1]          # same boundary
    assert payloads[0] == payloads[1]  # same traffic although |G| grew 4x


def test_message_passing_baseline_visits_sites_many_times():
    """The contrast the paper measures: disReach_m has no visit bound."""
    # long chain crossing fragments repeatedly -> many rounds
    n, k = 64, 4
    src = np.arange(n - 1)
    dst = np.arange(1, n)
    from repro.graph.graph import Graph
    g = Graph(n, src, dst, np.zeros(n, np.int32))
    part = (np.arange(n) % k).astype(np.int32)   # round-robin: max crossings
    fr = fragment_graph(g, part, k)
    res = dis_reach_m(fr, 0, n - 1)
    assert res.answer
    assert res.rounds > 1                        # multiple visits per site
    one = dis_reach(fr, 0, n - 1)
    assert one.answer and one.stats.collective_rounds == 1


def test_baselines_agree_with_engine():
    rng = np.random.default_rng(4)
    g = erdos_renyi(36, 100, seed=8)
    fr = fragment_graph(g, random_partition(g, 4, 1), 4)
    for _ in range(8):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        want = oracle_reach(g, s, t)
        assert dis_reach(fr, s, t).answer == want
        assert dis_reach_n(fr, s, t).answer == want
        assert dis_reach_m(fr, s, t).answer == want


def test_response_time_scales_with_largest_fragment():
    """Guarantee (3) proxy: localEval work is per-fragment; the padded
    engine shapes are set by |F_m|, not |G|."""
    g = erdos_renyi(100, 300, seed=0)
    fr_even = fragment_graph(g, random_partition(g, 4, 0), 4)
    part_skew = np.zeros(100, np.int32)
    part_skew[:10] = np.arange(10) % 3 + 1
    fr_skew = fragment_graph(g, part_skew, 4)
    assert fr_skew.largest_fragment() > fr_even.largest_fragment()
    # shapes (compute cost proxy) follow the largest fragment
    assert fr_skew.e_max >= fr_even.e_max
