"""Incremental rvset-cache maintenance vs from-scratch rebuild and oracles.

The contract (DESIGN.md Sec. 3.5): after any stream of edge deltas, the
``apply_delta``-maintained cache answers exactly like a cache rebuilt from
scratch on the updated graph, and both match the numpy/networkx oracles —
for plain reachability, distances, and regular (RPQ) queries.  Repair is an
optimization, never a semantic change.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (GraphDelta, apply_delta, build_query_automaton,
                        fragment_graph, get_rvset_cache, prepare_rvset_cache)
# The rebuild-vs-maintained comparisons below want the raw batched kernels
# (with the -1 "unreachable" sentinel), not session-level QueryResults; the
# public dis_*_batch shims were removed in PR 8, so reach into the internal
# cache engines directly.
from repro.core.cache import dis_dist_batch, dis_reach_batch, rpq_cached
from repro.core.incremental import (REBUILD_DEBT, changed_row_ids,
                                    pad_row_ids)
from repro.graph import erdos_renyi, random_partition
from repro.graph.graph import Graph
from repro.serve import DeltaApplyFailed, QueryServer

from oracles import oracle_dist, oracle_reach, oracle_rpq


def _dynamic_case(n, m, k, seed, **reserve):
    g = erdos_renyi(n, m, n_labels=3, seed=seed)
    part = random_partition(g, k, seed)
    kw = dict(reserve_boundary=8, reserve_edges=24, reserve_stubs=12)
    kw.update(reserve)
    return g, part, fragment_graph(g, part, k, **kw)


def _draw_delta(data, fr, n_add, n_del):
    n = fr.g.n
    adds = [(data.draw(st.integers(0, n - 1)), data.draw(st.integers(0, n - 1)))
            for _ in range(n_add)]
    dels, taken = [], set()
    for _ in range(n_del):
        if fr.g.m == 0:
            break
        e = data.draw(st.integers(0, fr.g.m - 1))
        if e in taken:                    # one delete per edge occurrence
            continue
        taken.add(e)
        dels.append((int(fr.g.src[e]), int(fr.g.dst[e])))
    return GraphDelta(add_src=[u for u, _ in adds], add_dst=[v for _, v in adds],
                      del_src=[u for u, _ in dels], del_dst=[v for _, v in dels])


def _check_against_rebuild_and_oracle(fr, pairs):
    """maintained == rebuilt-from-scratch == oracle, reach + dist."""
    fresh = fragment_graph(fr.g, fr.part, fr.k)
    got = dis_reach_batch(fr, pairs)
    ref = dis_reach_batch(fresh, pairs)
    got_d = dis_dist_batch(fr, pairs)
    ref_d = dis_dist_batch(fresh, pairs)
    for (s, t), a, ra, d, rd in zip(pairs, got, ref, got_d, ref_d):
        want = oracle_reach(fr.g, s, t)
        want_d = oracle_dist(fr.g, s, t)
        assert bool(a) == bool(ra) == want, (s, t)
        assert int(d) == int(rd), (s, t)
        assert (None if d < 0 else int(d)) == want_d, (s, t)


# ---------------------------------------------------------------------------
# property: maintained cache == rebuilt cache == oracle on delta streams
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(data=st.data())
def test_property_delta_stream_reach_dist(data):
    n = data.draw(st.integers(6, 20), label="n")
    m = data.draw(st.integers(0, 40), label="m")
    k = data.draw(st.integers(1, 4), label="k")
    seed = data.draw(st.integers(0, 10_000), label="seed")
    g, part, fr = _dynamic_case(n, m, k, seed)
    prepare_rvset_cache(fr, with_dist=True)
    for _ in range(3):
        n_add = data.draw(st.integers(0, 4), label="n_add")
        n_del = data.draw(st.integers(0, 2), label="n_del")
        delta = _draw_delta(data, fr, n_add, n_del)
        apply_delta(fr, delta)
        pairs = [(data.draw(st.integers(0, n - 1)),
                  data.draw(st.integers(0, n - 1))) for _ in range(4)]
        _check_against_rebuild_and_oracle(fr, pairs)


@settings(max_examples=4, deadline=None)
@given(data=st.data())
def test_property_delta_stream_rpq(data):
    n = data.draw(st.integers(8, 16), label="n")
    m = data.draw(st.integers(5, 30), label="m")
    k = data.draw(st.integers(1, 3), label="k")
    seed = data.draw(st.integers(0, 10_000), label="seed")
    g, part, fr = _dynamic_case(n, m, k, seed)
    qa = build_query_automaton(
        data.draw(st.sampled_from(["0* 1*", "(0|1)* 2", ". . ."]),
                  label="regex"), lambda x: int(x))
    prepare_rvset_cache(fr)
    for _ in range(2):
        delta = _draw_delta(data, fr, data.draw(st.integers(1, 3)),
                            data.draw(st.integers(0, 1)))
        apply_delta(fr, delta)
        fresh = fragment_graph(fr.g, fr.part, fr.k)
        for _ in range(3):
            s = data.draw(st.integers(0, n - 1))
            t = data.draw(st.integers(0, n - 1))
            want = oracle_rpq(fr.g, s, t, qa)
            assert rpq_cached(fr, s, t, qa) == want, (s, t)
            assert rpq_cached(fresh, s, t, qa) == want, (s, t)


# ---------------------------------------------------------------------------
# cache invalidation edge cases
# ---------------------------------------------------------------------------

def test_cross_edge_landing_on_query_target():
    """A delta whose cross edge lands exactly on a query target t: the
    t-column must pick up the new boundary row (alias-column case)."""
    # two fragments: 0|1|2 -> frag 0, 3|4|5 -> frag 1; t = 5 only reachable
    # through the inserted cross edge 2 -> 5
    g = Graph(6, np.array([0, 1, 3]), np.array([1, 2, 4]),
              np.zeros(6, np.int32))
    part = np.array([0, 0, 0, 1, 1, 1], np.int32)
    fr = fragment_graph(g, part, 2, reserve_boundary=4, reserve_edges=8,
                        reserve_stubs=4)
    prepare_rvset_cache(fr, with_dist=True)
    assert not dis_reach_batch(fr, [(0, 5)])[0]
    st1 = apply_delta(fr, GraphDelta.insert([(2, 5)]))
    assert st1.new_boundary == 1          # 5 became a boundary in-node
    assert bool(dis_reach_batch(fr, [(0, 5)])[0])
    assert int(dis_dist_batch(fr, [(0, 5)])[0]) == 3
    # and a second cross edge onto the (now-boundary) target: alias path
    st2 = apply_delta(fr, GraphDelta.insert([(1, 5)]))
    assert st2.new_boundary == 0
    assert int(dis_dist_batch(fr, [(0, 5)])[0]) == 2
    _check_against_rebuild_and_oracle(fr, [(0, 5), (5, 0), (3, 5), (0, 4)])


def test_nonboundary_node_becomes_boundary_in_node():
    """Activating a spare boundary slot must not change any array shape
    (jit stability) while making the new in-node's row live."""
    g, part, fr = _dynamic_case(18, 25, 3, seed=4)
    prepare_rvset_cache(fr)
    cache = get_rvset_cache(fr)
    B0, closure_shape = fr.B, cache.closure.shape
    nb_active0 = fr.nb_active
    # find a node with no incoming cross edge and a source in another frag
    cross_dst = set(g.dst[part[g.src] != part[g.dst]].tolist())
    w = next(v for v in range(g.n) if v not in cross_dst)
    u = next(u for u in range(g.n) if part[u] != part[w])
    st1 = apply_delta(fr, GraphDelta.insert([(u, w)]))
    assert st1.new_boundary == 1
    assert fr.nb_active == nb_active0 + 1
    assert fr.b_index[w] == nb_active0    # landed in the first spare slot
    assert fr.B == B0                     # static shapes preserved
    assert cache.closure.shape == closure_shape
    pairs = [(u, w), (w, u)] + [(s, w) for s in range(0, g.n, 5)]
    _check_against_rebuild_and_oracle(fr, pairs)


def test_empty_delta_is_noop_with_array_identity():
    g, part, fr = _dynamic_case(14, 20, 2, seed=6)
    prepare_rvset_cache(fr, with_dist=True)
    cache = get_rvset_cache(fr)
    arrays, bl, C = cache.arrays, cache.bl_frontier, cache.closure
    bl_d, Cd, v = cache.bl_dist, cache.dist_closure, cache.version
    stats = apply_delta(fr, GraphDelta())
    assert stats.mode == "noop"
    assert cache.arrays is arrays         # same objects, not equal copies
    assert cache.bl_frontier is bl and cache.closure is C
    assert cache.bl_dist is bl_d and cache.dist_closure is Cd
    assert cache.version == v and fr.rvset_cache is cache


def test_deletions_recompute_then_debt_forces_rebuild():
    g, part, fr = _dynamic_case(20, 60, 3, seed=8)
    prepare_rvset_cache(fr)
    rng = np.random.default_rng(0)
    modes = []
    for _ in range(12):
        e = int(rng.integers(fr.g.m))
        stats = apply_delta(
            fr, GraphDelta.delete([(int(fr.g.src[e]), int(fr.g.dst[e]))]))
        modes.append(stats.mode)
        if stats.mode == "rebuild":
            assert stats.reason == "repair debt"
            break
    assert modes[0] == "recompute"
    assert "rebuild" in modes             # debt counter eventually trips
    assert len(modes) <= int(REBUILD_DEBT / 0.5) + 1
    pairs = [(int(rng.integers(g.n)), int(rng.integers(g.n)))
             for _ in range(6)]
    _check_against_rebuild_and_oracle(fr, pairs)


def test_capacity_overflow_falls_back_to_rebuild():
    g, part, fr = _dynamic_case(16, 30, 2, seed=3, reserve_boundary=0,
                                reserve_edges=0, reserve_stubs=0)
    prepare_rvset_cache(fr)
    other = np.nonzero(part != part[0])[0]
    adds = [(0, int(v)) for v in other[:3]] * 8   # blow the edge headroom
    stats = apply_delta(fr, GraphDelta.insert(adds))
    assert stats.mode == "rebuild"
    _check_against_rebuild_and_oracle(fr, [(0, int(other[0])), (3, 9)])


def test_changed_row_padding_buckets():
    g, part, fr = _dynamic_case(20, 50, 3, seed=1)
    dirty = np.zeros(fr.k, dtype=bool)
    dirty[0] = True
    rows = changed_row_ids(fr, dirty)
    assert set(fr.boundary_owner()[rows]) <= {0}
    padded = pad_row_ids(rows, pad=8)
    assert len(padded) % 8 == 0
    assert set(padded) == set(rows)       # padding repeats, never invents


# ---------------------------------------------------------------------------
# serving loop: interleaved updates with snapshot consistency
# ---------------------------------------------------------------------------

def test_server_interleaved_updates_snapshot_consistency():
    g, part, fr = _dynamic_case(24, 30, 3, seed=11)
    srv = QueryServer(fr, batch_size=4, start=False)
    rng = np.random.default_rng(1)
    s = t = None
    for _ in range(400):
        a, b = int(rng.integers(g.n)), int(rng.integers(g.n))
        if a != b and not oracle_reach(g, a, b):
            s, t = a, b
            break
    assert s is not None
    q_before = srv.submit(s, t)
    upd = srv.submit_delta(GraphDelta.insert([(s, t)]))
    q_after = srv.submit(s, t)
    srv.flush()
    # the pre-update query saw the pre-delta snapshot
    assert q_before.result() is False and q_after.result() is True
    assert upd.value.mode in ("repair", "recompute")
    assert q_before.cache_version < q_after.cache_version
    assert srv.updates_applied == 1
    # mixed stream stays correct against the evolving oracle
    for _ in range(2):
        reqs = [srv.submit(int(rng.integers(g.n)), int(rng.integers(g.n)))
                for _ in range(7)]
        pre_g = fr.g
        srv.submit_delta(GraphDelta.insert(
            [(int(rng.integers(g.n)), int(rng.integers(g.n)))]))
        srv.flush()
        for r in reqs:
            assert r.result() == oracle_reach(pre_g, r.s, r.t)


def test_server_failed_update_preserves_later_requests():
    """A bad update resolves ``failed`` (typed, rolled back) and must not
    eat the queue: pre- and post-update queries are served in the same
    drain (PR 7 replaced the old raise-out-of-drain behavior)."""
    g, part, fr = _dynamic_case(16, 24, 2, seed=13)
    srv = QueryServer(fr, batch_size=4, start=False)
    present = set(zip(g.src.tolist(), g.dst.tolist()))
    missing = next((u, v) for u in range(g.n) for v in range(g.n)
                   if (u, v) not in present)
    q_before = srv.submit(0, 1)
    upd = srv.submit_delta(GraphDelta.delete([missing]))  # nonexistent edge
    q_after = srv.submit(2, 3)
    served = srv.flush()
    assert q_before.result() == oracle_reach(g, 0, 1)     # flushed first
    assert upd.status == "failed" and srv.updates_failed == 1
    assert isinstance(upd.error, DeltaApplyFailed) and upd.error.rolled_back
    assert isinstance(upd.error.cause, ValueError)
    assert q_after.result() == oracle_reach(g, 2, 3)      # not blocked
    assert srv.pending() == 0
    assert sorted(map(id, served)) == sorted(map(id, [q_before, upd, q_after]))


# ---------------------------------------------------------------------------
# sharded repair: update collective ships only the changed bitpacked rows
# ---------------------------------------------------------------------------

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
sys.path.insert(0, "__SRC__")
import numpy as np
from repro.graph import erdos_renyi, random_partition
from repro.graph.graph import bfs_reachable
from repro.core import fragment_graph, prepare_rvset_cache, GraphDelta
from repro.core.cache import dis_reach_batch
from repro.core import incremental
from repro.core.distributed import (apply_delta_sharded, fragment_mesh,
                                    lower_update_hlo)

g = erdos_renyi(48, 120, n_labels=4, seed=5)
k = 8
part = random_partition(g, k, seed=2)
fr = fragment_graph(g, part, k, reserve_boundary=8, reserve_edges=32,
                    reserve_stubs=16)
prepare_rvset_cache(fr)
mesh = fragment_mesh(k)
rng = np.random.default_rng(0)

ok, modes = True, []
for step in range(3):
    f = int(rng.integers(k))
    mine = np.nonzero(part == f)[0]
    other = np.nonzero(part != f)[0]
    adds = [(int(rng.choice(mine)), int(rng.choice(mine))) for _ in range(2)]
    adds += [(int(rng.choice(mine)), int(rng.choice(other)))]
    st = apply_delta_sharded(fr, GraphDelta.insert(adds), mesh=mesh)
    modes.append(st.mode)
    pairs = [(int(rng.integers(g.n)), int(rng.integers(g.n)))
             for _ in range(24)]
    got = dis_reach_batch(fr, pairs)
    for (s, t), a in zip(pairs, got):
        ok &= bool(a) == bool(bfs_reachable(fr.g, s)[t])

row_ids = incremental.pad_row_ids(np.arange(3), pad=8, cap=fr.n_boundary)
warm = np.zeros((fr.k, fr.s_max, fr.n_max + 1), dtype=bool)
hlo = lower_update_hlo(fr, warm, row_ids, mesh=mesh)
from repro.analysis import parse_program
model = parse_program(hlo)
words = (fr.n_boundary + 31) // 32
shape_ok = any(c.results and c.results[0].dtype == "ui32"
               and c.results[0].dims == (len(row_ids), words)
               for c in model.collectives)
print(json.dumps({"ok": bool(ok), "modes": modes,
                  "n_collectives": len(model.collectives),
                  "payload_shape_ok": bool(shape_ok),
                  "rows": int(len(row_ids)), "nb": int(fr.n_boundary)}))
"""


@pytest.fixture(scope="module")
def sharded_update_report():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = _SUBPROC.replace("__SRC__", os.path.abspath(src))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_repair_correct(sharded_update_report):
    assert sharded_update_report["ok"]
    assert set(sharded_update_report["modes"]) == {"repair_sharded"}


def test_sharded_update_ships_changed_rows_only(sharded_update_report):
    """One collective; its payload is [changed_rows, ceil(nb/32)] uint32 —
    rows that did not change never hit the wire."""
    assert sharded_update_report["n_collectives"] == 1
    assert sharded_update_report["payload_shape_ok"]
    assert sharded_update_report["rows"] < sharded_update_report["nb"]
