"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), shape sweeps."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.bool_matmul import bool_matmul, bool_matmul_ref
from repro.kernels.tropical_matmul import tropical_matmul, tropical_matmul_ref
from repro.kernels.tropical_matmul.ref import INF
from repro.kernels.bitpack_ops import (bitpack_bool_matmul,
                                       bitpack_matmul_ref, pack_rows,
                                       pack_rows_ref, unpack_rows)

SHAPES = [(128, 128, 128), (7, 200, 33), (256, 64, 128), (1, 1, 1),
          (130, 257, 5), (64, 512, 64)]
DENSITIES = [0.0, 0.02, 0.3, 1.0]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("density", DENSITIES)
def test_bool_matmul(shape, density):
    m, k, n = shape
    rng = np.random.default_rng(hash(shape) % 2**32)
    a = jnp.asarray(rng.random((m, k)) < density)
    b = jnp.asarray(rng.random((k, n)) < density)
    np.testing.assert_array_equal(np.asarray(bool_matmul(a, b)),
                                  np.asarray(bool_matmul_ref(a, b)))


@pytest.mark.parametrize("shape", SHAPES)
def test_tropical_matmul(shape):
    m, k, n = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    a = rng.integers(0, 50, (m, k)).astype(np.int32)
    b = rng.integers(0, 50, (k, n)).astype(np.int32)
    # sprinkle INF entries (absent edges)
    a[rng.random((m, k)) < 0.3] = int(INF)
    b[rng.random((k, n)) < 0.3] = int(INF)
    got = np.asarray(tropical_matmul(jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(tropical_matmul_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("density", [0.02, 0.3])
def test_bitpack_matmul(shape, density):
    m, k, n = shape
    rng = np.random.default_rng(hash(shape) % 2**30)
    a = jnp.asarray(rng.random((m, k)) < density)
    b = jnp.asarray(rng.random((k, n)) < density)
    got = np.asarray(bitpack_bool_matmul(a, b))
    want = np.asarray(bitpack_matmul_ref(a, b))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("k", [1, 31, 32, 33, 100, 256])
def test_pack_roundtrip(k):
    rng = np.random.default_rng(k)
    a = jnp.asarray(rng.random((17, k)) < 0.4)
    packed = pack_rows(a)
    np.testing.assert_array_equal(np.asarray(packed), pack_rows_ref(a))
    np.testing.assert_array_equal(np.asarray(unpack_rows(packed, k)),
                                  np.asarray(a))


def test_closure_with_pallas_matches_ref():
    """End-to-end: bes closures using the kernels == pure-jnp closures."""
    from repro.core.bes import bool_closure, tropical_closure
    rng = np.random.default_rng(0)
    D = jnp.asarray(rng.random((50, 50)) < 0.05)
    np.testing.assert_array_equal(np.asarray(bool_closure(D, use_pallas=True)),
                                  np.asarray(bool_closure(D)))
    W = rng.integers(0, 9, (40, 40)).astype(np.int32)
    W[rng.random((40, 40)) < 0.6] = int(INF)
    got = tropical_closure(jnp.asarray(W), use_pallas=True)
    want = tropical_closure(jnp.asarray(W))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
