"""Launch layer: HLO collective parsing + mini dry-run on a 4x4 fake mesh.

The full 512-device dry-run is exercised by ``repro.launch.dryrun`` (see
results/dryrun.json); here we keep a fast structural test that the cell
programs lower+compile with their shardings on a small mesh, in a
subprocess so the fake device count never leaks into other tests.
"""
import json
import os
import subprocess
import sys

from repro.launch.hlo_stats import collective_bytes, collective_schedule


SAMPLE_HLO = """
  %ar = f32[128,1024]{1,0} all-reduce(f32[128,1024]{1,0} %x), replica_groups={}
  %ag.1 = bf16[32,4096]{1,0} all-gather(bf16[32,256]{1,0} %y), dimensions={1}
  %tup = (s32[64]{0}, s32[64]{0}) all-to-all(s32[64]{0} %a, s32[64]{0} %b)
  %cp = u8[16,16]{1,0} collective-permute(u8[16,16]{1,0} %z)
  %rs = f32[8,8]{1,0} reduce-scatter(f32[64,8]{1,0} %w), dimensions={0}
"""


def test_collective_bytes_parses_all_kinds():
    out = collective_bytes(SAMPLE_HLO)
    assert out["count"] == 5
    assert out["all-reduce"] == 128 * 1024 * 4
    assert out["all-gather"] == 32 * 4096 * 2
    assert out["all-to-all"] == 64 * 4 * 2          # tuple of two s32[64]
    assert out["collective-permute"] == 16 * 16 * 1
    assert out["reduce-scatter"] == 8 * 8 * 4
    assert out["total"] == sum(v for k, v in out.items()
                               if k not in ("total", "count"))


def test_collective_schedule_order():
    sched = collective_schedule(SAMPLE_HLO)
    assert sched[0].startswith("all-reduce")
    assert sched[1].startswith("all-gather")


_MINI = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json, sys
sys.path.insert(0, "__SRC__")
import jax
from jax.sharding import NamedSharding
from repro.configs import get_arch
from repro.launch.hlo_stats import collective_bytes

mesh = jax.make_mesh((4, 4), ("data", "model"))
results = {}
for aid, sid in [("qwen2-1.5b", "train_4k"), ("gat-cora", "molecule"),
                 ("bert4rec", "train_batch")]:
    prog = get_arch(aid).build(sid, multipod=False, reduced=True)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), prog.arg_specs,
                      is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    with mesh:
        compiled = jax.jit(prog.step_fn, in_shardings=sh).lower(
            *prog.abstract_args).compile()
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    results[f"{aid}/{sid}"] = dict(
        temp=int(getattr(mem, "temp_size_in_bytes", 0)),
        coll=int(coll["total"]), n_coll=int(coll["count"]))
print(json.dumps(results))
"""


def test_mini_dryrun_cells_compile_sharded():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    code = _MINI.replace("__SRC__", src)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert len(res) == 3
    # data+model sharded programs must actually communicate
    assert res["qwen2-1.5b/train_4k"]["n_coll"] > 0
