"""LM transformer family: forward/grad/decode/prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T


def tiny_cfg(**kw):
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
                d_ff=128, vocab=97)
    base.update(kw)
    return T.LMConfig(**base)


CFGS = {
    "dense": tiny_cfg(),
    "dense_bias_partial_rope": tiny_cfg(qkv_bias=True, rope_pct=0.5),
    "mha": tiny_cfg(n_kv_heads=4),
    "swa": tiny_cfg(sliding_window=6),
    "moe": tiny_cfg(n_experts=4, top_k=2, d_ff_expert=32,
                    capacity_factor=8.0),
}


@pytest.mark.parametrize("name", list(CFGS))
def test_forward_shapes_finite(name):
    cfg = CFGS[name]
    params = T.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab)
    logits, aux = T.forward(cfg, params, toks)
    assert logits.shape == (2, 12, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    loss = T.lm_loss(cfg, params, toks[:, :-1], toks[:, 1:])
    assert np.isfinite(float(loss))


def test_grads_finite_and_nonzero():
    cfg = CFGS["moe"]
    params = T.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab)
    g = jax.grad(lambda p: T.lm_loss(cfg, p, toks[:, :-1], toks[:, 1:]))(params)
    leaves = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(x)).all() for x in leaves)
    assert any(float(jnp.max(jnp.abs(x))) > 0 for x in leaves)


@pytest.mark.parametrize("name", ["dense", "dense_bias_partial_rope", "moe"])
def test_decode_matches_forward(name):
    cfg = CFGS[name]
    params = T.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 10), 0, cfg.vocab)
    logits, _ = T.forward(cfg, params, toks)
    cache = T.init_cache(cfg, 2, 16)
    outs = []
    for i in range(10):
        lg, cache = T.decode_step(cfg, params, cache, toks[:, i],
                                  jnp.full((2,), i, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits),
                               atol=2e-4, rtol=2e-3)


def test_prefill_then_decode_continuation():
    cfg = CFGS["dense"]
    params = T.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab)
    full, _ = T.forward(cfg, params, toks)
    lg_pre, cache = T.prefill(cfg, params, toks[:, :8], 16)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(full[:, :8]),
                               atol=2e-4, rtol=2e-3)
    lg, cache = T.decode_step(cfg, params, cache, toks[:, 8],
                              jnp.full((2,), 8, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 8]),
                               atol=2e-4, rtol=2e-3)


def test_sliding_window_ring_buffer_decode():
    """SWA ring cache: decoding far beyond the window stays finite and
    matches a full forward restricted to the window."""
    cfg = tiny_cfg(sliding_window=4)
    params = T.init_params(cfg, jax.random.key(0))
    S = 12
    toks = jax.random.randint(jax.random.key(2), (1, S), 0, cfg.vocab)
    full, _ = T.forward(cfg, params, toks)   # SWA mask applied inside
    cache = T.init_cache(cfg, 1, S)          # ring length == window
    assert cache["k"].shape[2] == 4
    outs = []
    for i in range(S):
        lg, cache = T.decode_step(cfg, params, cache, toks[:, i],
                                  jnp.full((1,), i, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-4, rtol=2e-3)


def test_swa_mask_limits_attention():
    """A token > window away must not influence the current logits."""
    cfg = tiny_cfg(sliding_window=3, n_layers=1)
    params = T.init_params(cfg, jax.random.key(0))
    toks = np.array([[5, 6, 7, 8, 9, 10]])
    l1, _ = T.forward(cfg, params, jnp.asarray(toks))
    toks2 = toks.copy()
    toks2[0, 0] = 50          # outside the window of the last position
    l2, _ = T.forward(cfg, params, jnp.asarray(toks2))
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               atol=1e-5)


def test_param_count_formula():
    cfg = CFGS["dense"]
    params = T.init_params(cfg, jax.random.key(0))
    actual = sum(np.prod(x.shape) for x in jax.tree.leaves(params)
                 if x.dtype != jnp.int32)
    # formula excludes nothing for the tied dense config except biases
    assert abs(actual - cfg.n_params()) / actual < 0.02


def test_moe_aux_loss_nonnegative():
    cfg = CFGS["moe"]
    params = T.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab)
    _, aux = T.forward(cfg, params, toks)
    assert float(aux) >= 0.0
