"""MVCC snapshot store: non-blocking deltas, versioned rvset caches,
concurrent repair (DESIGN.md Sec. 9).

The contracts under test:

* ``commit_delta`` publishes a new head without the base version ever
  observing a change — a reader pinned to the old snapshot keeps getting
  pre-delta oracle answers after the commit;
* rollback is **drop**: a failed repair (or an explicit ``drop``) retires
  the version while pinned readers keep their snapshot, and the head
  keeps serving — no restore, no pause;
* capacity eviction reclaims only unpinned non-head versions; pinned
  versions persist past capacity until their readers drain;
* the engine in MVCC mode never blocks a query on an in-progress repair
  (measured against an injected slow repair), keeps the deterministic
  inline ordering in deferred mode (queued queries answer the pre-delta
  head), and surfaces the version/pin/repair gauges through telemetry;
* the sharded path serves a chunk pinned to a pre-delta version with
  pre-delta oracle answers while the delta commits, and the
  one-collective-per-fused-group HLO guarantee holds on **every** live
  version (subprocess over 8 fake devices).
"""
import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

import repro
from repro.core import GraphDelta, Reach, fragment_graph
from repro.core.versions import VersionedCacheStore, cow_clone
from repro.errors import DeltaApplyFailed, Status
from repro.graph import erdos_renyi, random_partition
from repro.serve import FaultInjector, FaultSpec, QueryServer, RetryPolicy

from oracles import oracle_reach

pytestmark = pytest.mark.mvcc

RESULT_TIMEOUT_S = 120.0


def _case(n=24, m=40, k=3, seed=11, **kw):
    kw.setdefault("reserve_boundary", 12)
    kw.setdefault("reserve_edges", 24)
    kw.setdefault("reserve_stubs", 12)
    g = erdos_renyi(n, m, n_labels=3, seed=seed)
    fr = fragment_graph(g, random_partition(g, k, seed), k, **kw)
    return g, fr


def _unreachable_pair(g, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(500):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        if s != t and not oracle_reach(g, s, t):
            return s, t
    pytest.skip("graph is (almost) strongly connected")


def _store(fr, capacity=4):
    sess = repro.connect(fr).warm()
    return sess, VersionedCacheStore(sess, capacity=capacity)


def _pin(store, ver):
    """Pin an arbitrary (possibly non-head) version, like an in-flight
    reader that acquired it before newer versions published."""
    with store._lock:
        ver.pins += 1
    return ver


# ---------------------------------------------------------------------------
# store semantics: commit, pinned readers, drop, eviction
# ---------------------------------------------------------------------------

def test_commit_publishes_new_head_base_untouched():
    g, fr = _case()
    s, t = _unreachable_pair(g)
    sess, store = _store(fr)
    old = store.acquire_head()
    g0, av0, cv0 = fr.g, fr.arrays_version, fr.rvset_cache.version

    ver, stats = store.commit_delta(GraphDelta.insert([(s, t)]))
    assert stats.mode in ("repair", "recompute")
    assert store.head() is ver and ver.vid == 1
    assert store.committed == 1
    # the base version never observed the delta: same graph object, same
    # array/cache versions, and the pinned reader still answers pre-delta
    assert fr.g is g0 and fr.arrays_version == av0
    assert fr.rvset_cache.version == cv0
    assert ver.fr.g is not g0 and ver.cache_version == cv0 + 1
    r_old = sess.run([Reach(s, t)], version=old)[0]
    r_new = sess.run([Reach(s, t)], version=ver)[0]
    assert r_old.answer is False and r_old.cache_version == cv0
    assert r_new.answer is True and r_new.cache_version == cv0 + 1
    store.release(old)


def test_empty_delta_is_noop_version():
    _, fr = _case(16, 30, 2, seed=3)
    _, store = _store(fr)
    ver, stats = store.commit_delta(GraphDelta())
    assert stats.mode == "noop"
    assert ver is store.head() and ver.vid == 0
    assert store.committed == 0


def test_drop_non_head_keeps_pinned_reader_snapshot():
    g, fr = _case(seed=5)
    sess, store = _store(fr)
    rng = np.random.default_rng(1)
    for _ in range(2):
        store.commit_delta(GraphDelta.insert(
            [(int(rng.integers(g.n)), int(rng.integers(g.n)))]))
    v0, v1, v2 = store.live()
    _pin(store, v1)                     # reader holding the middle version

    store.drop(v1.vid)                  # non-head rollback
    assert store.head() is v2           # head unmoved
    assert v1.retired and store.dropped == 1
    assert v1.vid in store._versions    # pinned: not reclaimed yet
    # the pinned reader still runs against its retired snapshot
    r = sess.run([Reach(0, 1)], version=v1)[0]
    assert r.cache_version == v1.cache_version
    store.release(v1)
    assert v1.vid not in store._versions    # reclaimed once unpinned

    # dropping the head falls back to the newest remaining live version
    store.drop(v2.vid)
    assert store.head() is v0
    with pytest.raises(ValueError, match="last live"):
        store.drop(v0.vid)
    with pytest.raises(KeyError):
        store.drop(v1.vid)              # already gone


def test_capacity_evicts_only_unpinned_nonhead():
    g, fr = _case(seed=7)
    _, store = _store(fr, capacity=2)
    pinned = store.acquire_head()       # v0 pinned by an in-flight reader
    rng = np.random.default_rng(2)
    for _ in range(3):
        store.commit_delta(GraphDelta.insert(
            [(int(rng.integers(g.n)), int(rng.integers(g.n)))]))
    # v1 and v2 (unpinned, non-head) were evicted; pinned v0 persists
    # beyond capacity alongside the head
    assert [v.vid for v in store.live()] == [0, 3]
    assert store.evicted == 2
    assert len(store._versions) == 2    # transiently ok: pinned + head
    store.release(pinned)
    assert [v.vid for v in store.live()] == [0, 3]
    gauges = store.gauges()
    assert gauges["head_vid"] == 3
    assert gauges["versions_evicted"] == 2
    assert gauges["pinned_readers"] == {}


def test_failed_repair_drops_clone_head_keeps_serving():
    g, fr = _case(seed=9)
    s, t = _unreachable_pair(g)
    chaos = FaultInjector(
        seed=0, rates={"delta.repair": FaultSpec(rate=1.0, max_failures=1)})
    sess = repro.connect(fr, chaos=chaos).warm()
    store = VersionedCacheStore(sess)
    cv0 = fr.rvset_cache.version
    with pytest.raises(DeltaApplyFailed):
        store.commit_delta(GraphDelta.insert([(s, t)]))
    assert store.head().vid == 0 and store.dropped == 1
    assert store.committed == 0 and sess.stats.rollbacks == 1
    # head never touched: no restore happened, same cache version, and
    # reads still answer the pre-delta graph
    assert fr.g is g and fr.rvset_cache.version == cv0
    assert sess.run([Reach(s, t)], version=store.head())[0].answer is False
    # after the fault schedule heals, the same delta commits
    ver, stats = store.commit_delta(GraphDelta.insert([(s, t)]))
    assert store.head() is ver and stats.mode in ("repair", "recompute")
    assert sess.run([Reach(s, t)], version=ver)[0].answer is True


def test_cow_clone_shares_untouched_copies_touched():
    g, fr = _case(seed=13)
    repro.connect(fr).warm()
    u = int(np.nonzero(fr.part == 0)[0][0])
    w = int(np.nonzero(fr.part == 1)[0][0])
    clone = cow_clone(fr, GraphDelta.insert([(u, w)]))      # cross edge
    assert clone.arrays["esrc"] is not fr.arrays["esrc"]
    assert clone.arrays["src_local"] is not fr.arrays["src_local"]
    assert clone.arrays["src_row"] is not fr.arrays["src_row"]
    # never-touched state shares buffers; mutated bookkeeping is copied
    assert clone.g is fr.g and clone.part is fr.part
    assert clone.b_index is not fr.b_index
    assert clone.rvset_cache is not fr.rvset_cache
    assert clone.rvset_cache.arrays is not fr.rvset_cache.arrays
    assert clone.rvset_cache.closure is fr.rvset_cache.closure
    # memoized device uploads / default sessions stay with the base
    assert "_sharded_device_inputs" not in clone.__dict__
    # an intra-fragment delta copies only the edge arrays
    u2 = int(np.nonzero(fr.part == 0)[0][1])
    intra = cow_clone(fr, GraphDelta.insert([(u, u2)]))
    assert intra.arrays["src_local"] is fr.arrays["src_local"]


def test_store_capacity_validation():
    _, fr = _case(12, 20, 2, seed=1)
    sess = repro.connect(fr)
    with pytest.raises(ValueError, match="capacity"):
        VersionedCacheStore(sess, capacity=0)


# ---------------------------------------------------------------------------
# engine integration (vmap): ordering, non-blocking reads, telemetry
# ---------------------------------------------------------------------------

def test_deferred_mvcc_queued_queries_answer_pre_delta_head():
    g, fr = _case(24, 30, 3, seed=11)
    s, t = _unreachable_pair(g)
    srv = QueryServer(fr, batch_size=4, start=False, mvcc=True)
    try:
        pre = srv.submit(s, t)
        upd = srv.submit_delta(GraphDelta.insert([(s, t)]))
        mid = srv.submit(s, t)          # queued queries drain before the
        srv.flush()                     # repair: both answer pre-delta
        assert pre.value is False and mid.value is False
        assert pre.cache_version == mid.cache_version
        assert upd.status is Status.APPLIED
        assert upd.value.mode in ("repair", "recompute")
        # the committed version is visible to the next batch
        post = srv.submit(s, t)
        srv.flush()
        assert post.value is True
        assert post.cache_version == pre.cache_version + 1
        assert srv.updates_applied == 1
    finally:
        srv.close()


def test_live_mvcc_commit_point_and_monotonic_reads():
    g, fr = _case(24, 30, 3, seed=17)
    s, t = _unreachable_pair(g)
    with QueryServer(fr, batch_size=4, batch_wait_ms=1.0, mvcc=True) as srv:
        pre = srv.submit(s, t)
        assert pre.result(timeout=RESULT_TIMEOUT_S) is False
        upd = srv.submit_delta(GraphDelta.insert([(s, t)]))
        upd.result(timeout=RESULT_TIMEOUT_S)    # the commit point
        post = srv.submit(s, t)
        assert post.result(timeout=RESULT_TIMEOUT_S) is True
        assert post.cache_version > pre.cache_version
        snap = srv.telemetry()
        assert snap["mvcc"]["versions_committed"] == 1
        assert snap["mvcc"]["head_vid"] == 1
        assert snap["mvcc"]["repair_queue_depth"] == 0


def test_queries_never_block_on_inflight_repair():
    g, fr = _case(30, 60, 3, seed=19)
    srv = QueryServer(fr, batch_size=4, batch_wait_ms=1.0, mvcc=True)
    real_repair = srv.session.repair_on
    try:
        # pre-compile every reach bucket (1, 2, 4) so the timed reads
        # below measure serving, not XLA compiles
        for size in (1, 2, 4):
            srv.session.run([Reach(0, 1)] * size)

        entered = threading.Event()

        def slow_repair(work_fr, delta):
            entered.set()
            time.sleep(3.0)             # a deliberately glacial repair
            return real_repair(work_fr, delta)

        srv.session.repair_on = slow_repair
        upd = srv.submit_delta(GraphDelta.insert([(0, 1)]))
        assert entered.wait(timeout=RESULT_TIMEOUT_S)
        # reads submitted mid-repair complete long before the repair does
        t0 = time.monotonic()
        reads = [srv.submit(i, (i + 5) % g.n) for i in range(4)]
        for r in reads:
            r.result(timeout=RESULT_TIMEOUT_S)
        read_s = time.monotonic() - t0
        assert not upd.done()           # the repair is still in flight
        assert read_s < 1.5, f"reads stalled {read_s:.2f}s behind a repair"
        for r in reads:
            assert r.value == oracle_reach(g, r.s, r.t)
        upd.result(timeout=RESULT_TIMEOUT_S)
        assert srv.updates_applied == 1
    finally:
        srv.session.repair_on = real_repair
        srv.close()


def test_failed_delta_resolves_failed_and_serving_continues():
    g, fr = _case(seed=23)
    s, t = _unreachable_pair(g)
    chaos = FaultInjector(seed=0, rates={"delta.repair": 1.0})
    srv = QueryServer(fr, batch_size=4, start=False, mvcc=True, chaos=chaos,
                      retry=RetryPolicy(max_attempts=2, base_delay_ms=0.0))
    try:
        upd = srv.submit_delta(GraphDelta.insert([(s, t)]))
        q = srv.submit(s, t)
        srv.flush()
        assert upd.status is Status.FAILED
        with pytest.raises(DeltaApplyFailed):
            upd.result(timeout=RESULT_TIMEOUT_S)
        assert q.value is False         # head kept serving pre-delta
        assert srv.updates_failed == 1
        assert srv.telemetry()["mvcc"]["versions_dropped"] == 1
    finally:
        srv.close()


def test_dead_letter_cap_evicts_oldest_and_counts():
    _, fr = _case(20, 50, 2, seed=7)
    poisons = [(0, 1), (2, 3), (4, 5)]
    chaos = FaultInjector(seed=0, poison=poisons)
    srv = QueryServer(fr, batch_size=4, start=False, chaos=chaos,
                      dead_letter_cap=2,
                      retry=RetryPolicy(max_attempts=2, base_delay_ms=0.0))
    try:
        futs = [srv.submit(s, t) for s, t in poisons]
        srv.flush()
        assert all(f.status is Status.DEAD_LETTER for f in futs)
        assert srv.dead_letters == futs[1:]     # oldest evicted
        assert srv.dead_letters_evicted == 1
    finally:
        srv.close()


def test_telemetry_has_no_mvcc_block_outside_mvcc_mode():
    _, fr = _case(12, 20, 2, seed=1)
    srv = QueryServer(fr, batch_size=4, warm=False, start=False)
    try:
        assert "mvcc" not in srv.telemetry()
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# shard_map backend over 8 fake devices (subprocess, like test_session)
# ---------------------------------------------------------------------------

_MVCC_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
sys.path.insert(0, "__SRC__")
sys.path.insert(0, "__TESTS__")
import numpy as np
import repro
from repro.core import GraphDelta, Reach, fragment_graph
from repro.core.distributed import lower_batch_hlo
from repro.graph import erdos_renyi, random_partition
from repro.serve import QueryServer
from oracles import oracle_reach

g = erdos_renyi(40, 120, n_labels=3, seed=7)
fr = fragment_graph(g, random_partition(g, 8, 1), 8,
                    reserve_boundary=12, reserve_edges=24, reserve_stubs=12)
rng = np.random.default_rng(4)
s = t = None
for _ in range(500):
    a, b = int(rng.integers(g.n)), int(rng.integers(g.n))
    if a != b and not oracle_reach(g, a, b):
        s, t = a, b
        break

srv = QueryServer(fr, batch_size=8, start=False, mvcc=True)
backend = srv.session.backend
pairs = [(int(rng.integers(g.n)), int(rng.integers(g.n))) for _ in range(7)]
pre = [srv.submit(a, b) for a, b in pairs] + [srv.submit(s, t)]
upd = srv.submit_delta(GraphDelta.insert([(s, t)]))
post = [srv.submit(a, b) for a, b in pairs] + [srv.submit(s, t)]
srv.flush()

# deterministic inline ordering: every queued chunk answered the
# pre-delta head even though a repair was pending behind it
pre_ok = (all(r.value == oracle_reach(g, r.s, r.t) for r in pre + post)
          and pre[-1].value is False and post[-1].value is False)
stamps = {r.cache_version for r in pre + post}
update_mode = upd.value.mode

# the committed version is visible to the next batch; a reader still
# pinned to the OLD version (an in-flight chunk when the delta landed)
# keeps answering the pre-delta oracle
store = srv.store
old = next(v for v in store.live() if v.vid == 0)
with store._lock:
    old.pins += 1
fresh = srv.submit(s, t)
srv.flush()
post_commit_ok = (fresh.value is True
                  and fresh.cache_version == pre[-1].cache_version + 1)
r_old = srv.session.run([Reach(s, t)], version=old)[0]
pinned_old_ok = (r_old.answer is False
                 and r_old.cache_version == pre[-1].cache_version)
store.release(old)

# one collective per fused group on EVERY live version's fragmentation
from repro.analysis import parse_program
colls_per_version = []
for ver in store.live():
    hlo = lower_batch_hlo(ver.fr, pairs, "reach")
    colls_per_version.append(len(parse_program(hlo).collectives))
gauges = srv.telemetry()["mvcc"]
srv.close()

print(json.dumps({
    "backend": backend,
    "pre_ok": bool(pre_ok),
    "one_stamp_pre": len(stamps) == 1,
    "update_mode": update_mode,
    "post_commit_ok": bool(post_commit_ok),
    "pinned_old_ok": bool(pinned_old_ok),
    "n_live": len(colls_per_version),
    "colls_per_version": colls_per_version,
    "committed": gauges["versions_committed"],
}))
"""


@pytest.fixture(scope="module")
def mvcc_shard_report():
    here = os.path.dirname(__file__)
    code = (_MVCC_SUBPROC
            .replace("__SRC__",
                     os.path.abspath(os.path.join(here, "..", "src")))
            .replace("__TESTS__", os.path.abspath(here)))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_shard_map_mvcc_pinned_reader_and_commit_visibility(
        mvcc_shard_report):
    rep = mvcc_shard_report
    assert rep["backend"] == "shard_map"
    assert rep["pre_ok"], rep
    assert rep["one_stamp_pre"], rep
    assert rep["update_mode"] in ("repair_sharded", "repair", "recompute",
                                  "rebuild"), rep
    assert rep["post_commit_ok"], rep
    assert rep["pinned_old_ok"], rep
    assert rep["committed"] == 1, rep


def test_shard_map_one_collective_on_every_live_version(mvcc_shard_report):
    """The one-collective-per-fused-group HLO guarantee survives the COW
    clone: both the pre-delta version and the repaired head lower to
    exactly one collective per fused reach batch."""
    rep = mvcc_shard_report
    assert rep["n_live"] >= 2, rep
    assert all(c == 1 for c in rep["colls_per_version"]), rep
