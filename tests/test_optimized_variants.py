"""Beyond-paper optimizations must be numerically faithful to baselines
(EXPERIMENTS.md §Perf): two-stage top-k is exact; fused GNN aggregation
matches per-path aggregation (bf16-tolerance); LM sharding hints are
no-ops numerically."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import bert4rec as B
from repro.models import transformer as T
from repro.models.gnn import common, equivariant


def test_two_stage_topk_exact():
    cfg = B.Bert4RecConfig(n_items=512, embed_dim=32, n_blocks=1,
                           n_heads=2, seq_len=8, topk_ways=8)
    rng = np.random.default_rng(0)
    scores = jnp.asarray(rng.normal(size=(6, 512)), jnp.float32)
    v2, i2 = B._topk_scores(cfg, scores, 10)
    v1, i1 = jax.lax.top_k(scores, 10)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_fused_agg_matches_per_path():
    rng = np.random.default_rng(1)
    base = equivariant.EquivariantConfig(arch="nequip", n_layers=2,
                                         channels=8, l_max=2, correlation=1,
                                         n_species=4, cutoff=3.0)
    fused = dataclasses.replace(base, fused_agg=True)
    params = equivariant.init_params(base, jax.random.key(0))
    senders = rng.integers(0, 12, 40)
    receivers = rng.integers(0, 12, 40)
    g = common.pad_graph(senders, receivers, 12, 48, 16)
    species = jnp.asarray(rng.integers(0, 4, 16), jnp.int32)
    coords = jnp.asarray(rng.normal(size=(16, 3)), jnp.float32)
    e_base = equivariant.forward(base, params, species, coords, g)
    e_fused = equivariant.forward(fused, params, species, coords, g)
    # fused path aggregates messages in bf16
    np.testing.assert_allclose(np.asarray(e_base), np.asarray(e_fused),
                               rtol=3e-2, atol=3e-2)


def test_lm_dp_hints_are_numeric_noops():
    base = T.LMConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=128, vocab=97, attn_chunk=8,
                      remat=False)
    hinted = dataclasses.replace(base, dp_axes=("data",))
    params = T.init_params(base, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, 97)
    l1, _ = T.forward(base, params, toks)
    l2, _ = T.forward(hinted, params, toks)   # no mesh -> hints no-op
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)


def test_optimized_builds_smoke():
    from repro.configs import get_arch
    from repro.configs.families.base import zeros_from_abstract
    for aid, sid in [("bert4rec", "serve_bulk"), ("mace", "molecule"),
                     ("qwen2-1.5b", "train_4k")]:
        prog = get_arch(aid).build(sid, reduced=True, optimized=True)
        args = zeros_from_abstract(prog.abstract_args, seed=1)
        out = jax.jit(prog.step_fn)(*args)
        for leaf in jax.tree.leaves(out):
            arr = np.asarray(leaf)
            if arr.dtype.kind == "f":
                assert np.isfinite(arr).all(), (aid, sid)
