"""Placement policy and k >> d fragment packing.

In-process tests cover the Placement dataclass (validation, layout,
the balance guarantee of the greedy policy) and the single-device packed
path.  The 8-fake-device scale-out runs (k = 16 and k = 32 on d = 8,
including a delta landing in a co-packed fragment) run in a subprocess so
the forced device count never leaks into other tests.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import repro
from repro.core import Placement, fragment_graph
from repro.core.plan import Dist, Reach, Rpq
from repro.core.automaton import build_query_automaton
from repro.graph import erdos_renyi, random_partition

from oracles import oracle_dist, oracle_reach, oracle_rpq


def _case(n, m, k, seed, **kw):
    g = erdos_renyi(n, m, n_labels=3, seed=seed)
    fr = fragment_graph(g, random_partition(g, k, seed), k, **kw)
    return g, fr


# ---------------------------------------------------------------------------
# Placement dataclass
# ---------------------------------------------------------------------------

def test_placement_refuses_more_devices_than_fragments():
    """d > k is invalid at every entry point, with an error that says why
    (a fragment is never split across devices)."""
    g, fr = _case(24, 60, 4, 0)
    with pytest.raises(ValueError, match="d > k"):
        Placement.round_robin(4, 8)
    with pytest.raises(ValueError, match="d > k"):
        Placement.balanced(fr, 5)
    with pytest.raises(ValueError, match="d > k"):
        Placement(k=2, d=3, device_of=(0, 1))


def test_placement_validates_assignment():
    with pytest.raises(ValueError, match="entries"):
        Placement(k=4, d=2, device_of=(0, 1, 0))       # wrong length
    with pytest.raises(ValueError):
        Placement(k=3, d=2, device_of=(0, 1, 2))       # device out of range
    with pytest.raises(ValueError):
        Placement(k=2, d=0, device_of=())


def test_placement_round_robin_layout():
    pl = Placement.round_robin(7, 3)
    assert pl.device_of == (0, 1, 2, 0, 1, 2, 0)
    assert pl.fpd == 3                                  # ceil(7/3)
    perm = pl.perm()
    assert perm.shape == (9,)
    # device-major layout: slot dev*fpd + j holds that device's j-th
    # fragment, -1 pads the ragged tail
    assert perm.tolist() == [0, 3, 6, 1, 4, -1, 2, 5, -1]
    # every fragment appears exactly once
    assert sorted(p for p in perm.tolist() if p >= 0) == list(range(7))


def test_placement_balanced_bound_and_shapes():
    """Greedy LPT with a cardinality cap: (a) same fpd as round-robin, so
    packing never inflates the compiled shapes; (b) the classic
    list-scheduling guarantee max_load <= total/d + max_weight, which is
    the 'largest per-device workload' response-time bound."""
    for seed, k, d in [(0, 8, 3), (1, 16, 8), (2, 32, 8), (3, 5, 5),
                       (4, 9, 2)]:
        g, fr = _case(12 * k, 30 * k, k, seed)
        pl = Placement.balanced(fr, d)
        assert pl.d == d and pl.k == fr.k
        assert pl.fpd == -(-k // d)                    # == round-robin fpd
        assert sorted(np.bincount(pl.device_of, minlength=d)) == \
            sorted(np.bincount(Placement.round_robin(k, d).device_of,
                               minlength=d))
        w = Placement.fragment_weights(fr)
        assert pl.max_load(fr) <= w.sum() / d + w.max()
        # each fragment placed exactly once
        assert len(pl.device_of) == k


def test_balanced_beats_round_robin_on_skew():
    """On a deliberately skewed fragmentation the greedy policy's largest
    per-device workload is no worse than round-robin's."""
    g = erdos_renyi(96, 260, n_labels=3, seed=7)
    part = np.minimum(np.arange(96) * 8 // 96, 7).astype(np.int32)
    part[:40] = 0                                       # one huge fragment
    fr = fragment_graph(g, part, 8)
    for d in (2, 4):
        assert (Placement.balanced(fr, d).max_load(fr)
                <= Placement.round_robin(8, d).max_load(fr))


# ---------------------------------------------------------------------------
# packed execution, single device (d=1, fpd=k)
# ---------------------------------------------------------------------------

def test_packed_single_device_matches_oracle_all_kinds():
    """backend='shard_map' with 4 fragments on the 1 host device packs all
    fragments onto one device: the degenerate-but-complete packing case."""
    g, fr = _case(28, 80, 4, 5)
    sess = repro.connect(fr, backend="shard_map")
    assert sess.backend == "shard_map"
    assert sess.placement.d == 1 and sess.placement.fpd == 4
    qa = build_query_automaton("(0|1)* 2", lambda x: int(x))
    queries = [Reach(0, 9), Reach(9, 9), Dist(1, 7), Dist(3, 3, bound=0),
               Rpq(2, 11, automaton=qa), Reach(6, 0)]
    res = sess.run(queries)
    for q, r in zip(queries, res):
        if isinstance(q, Reach):
            assert r.answer == oracle_reach(g, q.s, q.t)
        elif isinstance(q, Dist):
            want = oracle_dist(g, q.s, q.t)
            if q.bound is not None:
                assert r.answer == (want >= 0 and want <= q.bound)
            else:
                assert r.distance == want
        else:
            assert r.answer == oracle_rpq(g, q.s, q.t, qa)


def test_explicit_placement_threads_through_session():
    """A hand-built placement is honoured (not replaced by balanced) and a
    mismatched one is refused."""
    g, fr = _case(20, 50, 3, 6)
    pl = Placement(k=3, d=1, device_of=(0, 0, 0))
    sess = repro.connect(fr, backend="shard_map", placement=pl)
    assert sess.placement is pl
    assert sess.run(Reach(0, 5))[0].answer == oracle_reach(g, 0, 5)
    with pytest.raises(ValueError, match="placement"):
        repro.connect(fr, placement=Placement.round_robin(4, 2))


# ---------------------------------------------------------------------------
# 8-device scale-out: k = 16 and k = 32 on d = 8, plus a delta landing in
# a co-packed fragment
# ---------------------------------------------------------------------------

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
sys.path.insert(0, "__SRC__")
sys.path.insert(0, "__TESTS__")
import numpy as np
import repro
from repro.core import GraphDelta, Placement, fragment_graph, \
    build_query_automaton
from repro.core.plan import Reach, Dist, Rpq
from repro.graph import erdos_renyi, random_partition
from oracles import oracle_reach, oracle_dist, oracle_rpq

report = {}
rng = np.random.default_rng(11)
qa = build_query_automaton("(0|1)* 2", lambda x: int(x))

for k, n, m in [(16, 64, 180), (32, 96, 280)]:
    g = erdos_renyi(n, m, n_labels=3, seed=k)
    fr = fragment_graph(g, random_partition(g, k, 1), k,
                        reserve_boundary=8, reserve_edges=32,
                        reserve_stubs=16)
    sess = repro.connect(fr).warm()     # auto: 8 devices, d=8 <= k
    pl = sess.placement
    pairs = [(int(rng.integers(n)), int(rng.integers(n))) for _ in range(6)]
    queries = ([Reach(s, t) for s, t in pairs]
               + [Dist(s, t) for s, t in pairs]
               + [Rpq(s, t, automaton=qa) for s, t in pairs])
    def want_all(gg):
        return ([oracle_reach(gg, s, t) for s, t in pairs]
                + [oracle_dist(gg, s, t) for s, t in pairs]
                + [oracle_rpq(gg, s, t, qa) for s, t in pairs])
    res = sess.run(queries)
    got = [r.distance if isinstance(q, Dist) else r.answer
           for q, r in zip(queries, res)]

    # summed QueryStats over each fused group == the one concatenated-
    # owned-rows wire (identical to the d == k wire: packing is free)
    bits_ok = True
    for grp in sess.last_plan.groups:
        states = 1 if grp.automaton is None else grp.automaton.n_states
        total = fr.traffic_bits(grp.kind, states=states,
                                batch=grp.padded_size)
        bits_ok &= sum(res[i].stats.payload_bits
                       for i in grp.indices) == total
        bits_ok &= sum(res[i].stats.collective_rounds
                       for i in grp.indices) == 1

    # delta landing in a co-packed fragment: pick an insert edge whose
    # source fragment shares its device with >= 1 other fragment (with
    # k >= 2d every fragment is co-packed -- assert it anyway), repair
    # sharded, re-check against the post-delta oracle
    u = int(fr.bnodes[0]); v = int(rng.integers(n))
    dirty = int(fr.part[u])
    co_packed = sum(1 for x in pl.device_of
                    if x == pl.device_of[dirty]) >= 2
    upd = sess.apply(GraphDelta.insert([(u, v)]))
    post = sess.run(queries)
    post_got = [r.distance if isinstance(q, Dist) else r.answer
                for q, r in zip(queries, post)]
    report[str(k)] = {
        "backend": sess.backend, "d": pl.d, "fpd": pl.fpd,
        "ok": got == want_all(g), "bits_ok": bool(bits_ok),
        "co_packed": bool(co_packed), "update_mode": upd.mode,
        "post_ok": post_got == want_all(fr.g),
    }

print(json.dumps(report))
"""


@pytest.fixture(scope="module")
def scaleout_report():
    here = os.path.dirname(__file__)
    code = (_SUBPROC
            .replace("__SRC__",
                     os.path.abspath(os.path.join(here, "..", "src")))
            .replace("__TESTS__", os.path.abspath(here)))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("k", ["16", "32"])
def test_scaleout_oracle_answers(scaleout_report, k):
    """k fragments on 8 devices (auto backend): shard_map is chosen, the
    balanced placement packs k/8 fragments per device, and all three query
    kinds match the oracles."""
    rep = scaleout_report[k]
    assert rep["backend"] == "shard_map", rep
    assert rep["d"] == 8 and rep["fpd"] == int(k) // 8, rep
    assert rep["ok"], rep


@pytest.mark.parametrize("k", ["16", "32"])
def test_scaleout_wire_unchanged_by_packing(scaleout_report, k):
    """Summed per-group QueryStats.payload_bits equals the concatenated-
    owned-rows wire size — the same traffic_bits as one-fragment-per-
    device, i.e. packing adds zero wire — and one collective per group."""
    assert scaleout_report[k]["bits_ok"], scaleout_report[k]


@pytest.mark.parametrize("k", ["16", "32"])
def test_scaleout_delta_in_co_packed_fragment(scaleout_report, k):
    """An insert whose dirty fragment shares its device with others takes
    the sharded repair path and post-delta answers match the post-delta
    oracle (clean co-packed fragments converge without extra work)."""
    rep = scaleout_report[k]
    assert rep["co_packed"], rep
    assert rep["update_mode"] == "repair_sharded", rep
    assert rep["post_ok"], rep
