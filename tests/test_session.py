"""QuerySession / planner / shims vs the seed engine and oracles.

The session is a *routing* layer: whatever the planner fuses, every query
in a mixed reach+dist+RPQ batch must answer exactly like the single-query
seed paths (``dis_*``) and the networkx oracles — under the vmap backend,
the shard_map backend (single-device compat here, 8 fake devices in the
subprocess check), and across ``submit_delta`` snapshot boundaries.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
from repro.core import (Dist, GraphDelta, Reach, Rpq, build_query_automaton,
                        dis_dist, dis_reach, dis_rpq, fragment_graph)
from repro.core.plan import bucket_size, plan_queries
from repro.graph import erdos_renyi, random_partition
from repro.serve import QueryServer

from oracles import oracle_dist, oracle_reach, oracle_rpq

REGEXES = ["0* 1*", "(0|1)* 2"]


def _case(n, m, k, seed):
    g = erdos_renyi(n, m, n_labels=3, seed=seed)
    return g, fragment_graph(g, random_partition(g, k, seed), k)


def _automaton(regex):
    return build_query_automaton(regex, lambda x: int(x))


def _draw_mixed(data, n, n_queries):
    """Random mixed-kind batch; a small endpoint pool forces duplicate
    pairs and s == t cases."""
    pool = [(data.draw(st.integers(0, n - 1), label="s"),
             data.draw(st.integers(0, n - 1), label="t"))
            for _ in range(max(2, n_queries // 2))]
    qs = []
    for _ in range(n_queries):
        s, t = pool[data.draw(st.integers(0, len(pool) - 1), label="pair")]
        kind = data.draw(st.integers(0, 2), label="kind")
        if kind == 0:
            qs.append(Reach(s, t))
        elif kind == 1:
            bound = data.draw(st.integers(-1, 4), label="bound")
            qs.append(Dist(s, t, bound=None if bound < 0 else bound))
        else:
            rx = REGEXES[data.draw(st.integers(0, 1), label="rx")]
            qs.append(Rpq(s, t, regex=rx))
    return qs


def _check_against_seed_and_oracle(g, fr, queries, results):
    for q, r in zip(queries, results):
        if isinstance(q, Reach):
            assert r.answer == oracle_reach(g, q.s, q.t), q
            assert r.answer == dis_reach(fr, q.s, q.t).answer
        elif isinstance(q, Dist):
            ref = dis_dist(fr, q.s, q.t, bound=q.bound)
            assert (r.answer, r.distance) == (ref.answer, ref.distance), q
            if q.bound is None:
                assert r.distance == oracle_dist(g, q.s, q.t)
        else:
            qa = q.automaton or _automaton(q.regex)
            assert r.answer == oracle_rpq(g, q.s, q.t, qa), q
            assert r.answer == dis_rpq(fr, q.s, q.t, qa).answer


# ---------------------------------------------------------------------------
# property: mixed batches == seed single-query paths == oracles
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_property_session_mixed_batch_matches_oracles(data):
    n = data.draw(st.integers(4, 20), label="n")
    m = data.draw(st.integers(0, 50), label="m")
    k = data.draw(st.integers(1, 4), label="k")
    seed = data.draw(st.integers(0, 10_000), label="seed")
    g, fr = _case(n, m, k, seed)
    sess = repro.connect(fr, backend="vmap")
    queries = _draw_mixed(data, n, 6)
    results = sess.run(queries)
    assert len(results) == len(queries)
    _check_against_seed_and_oracle(g, fr, queries, results)
    # one fused execution per (kind, automaton) group
    assert sess.stats.executions == sess.last_plan.n_groups


def test_session_shard_map_compat_single_device():
    """backend='shard_map' on a 1-fragment mesh (the only shape a single
    CPU device admits) answers identically to vmap."""
    g = erdos_renyi(14, 35, n_labels=3, seed=4)
    fr = fragment_graph(g, np.zeros(14, np.int32), 1)
    sess = repro.connect(fr, backend="shard_map")
    assert sess.backend == "shard_map"
    qa = _automaton(REGEXES[0])
    queries = [Reach(0, 5), Reach(5, 5), Dist(1, 7), Dist(2, 2, bound=0),
               Rpq(3, 9, automaton=qa), Reach(6, 0)]
    results = sess.run(queries)
    _check_against_seed_and_oracle(g, fr, queries, results)


def test_session_auto_backend_single_device_is_vmap():
    g, fr = _case(12, 30, 3, 0)
    assert repro.connect(fr).backend == "vmap"
    # 3 fragments, 1 device: since the k >> d packing layer, explicit
    # shard_map is valid (all fragments packed onto the one device) and
    # must agree with vmap.
    sess = repro.connect(fr, backend="shard_map")
    assert sess.backend == "shard_map" and sess.placement.d == 1
    queries = [Reach(0, 5), Dist(1, 7), Reach(4, 4)]
    got = [r.answer for r in sess.run(queries)]
    want = [r.answer for r in repro.connect(fr, backend="vmap").run(queries)]
    assert got == want
    with pytest.raises(ValueError, match="backend"):
        repro.connect(fr, backend="nope")
    with pytest.raises(ValueError, match="cache"):
        repro.connect(fr, cache="nope")


# ---------------------------------------------------------------------------
# planner mechanics
# ---------------------------------------------------------------------------

def test_planner_groups_by_kind_and_automaton():
    qa1, qa2 = _automaton(REGEXES[0]), _automaton(REGEXES[1])
    queries = [Reach(0, 1), Dist(0, 1), Rpq(0, 1, automaton=qa1),
               Reach(2, 3), Dist(2, 3, bound=2), Rpq(2, 3, automaton=qa2),
               Rpq(4, 5, automaton=_automaton(REGEXES[0]))]  # equal key
    plan = plan_queries(queries, lambda q: q.automaton)
    assert plan.n_groups == 4          # reach, dist(+bounded), rpq x2
    kinds = [(grp.kind, grp.n) for grp in plan.groups]
    assert kinds == [("reach", 2), ("dist", 2), ("rpq", 2), ("rpq", 1)]
    # submission order is preserved through the group indices
    assert sorted(i for grp in plan.groups for i in grp.indices) == \
        list(range(len(queries)))
    assert "fused executions" in plan.explain()


def test_bucket_padding_avoids_retraces():
    assert [bucket_size(n) for n in (1, 8, 9, 16, 17, 100)] == \
        [8, 8, 16, 16, 32, 128]
    g, fr = _case(16, 40, 2, 1)
    sess = repro.connect(fr)
    for n_batch in (1, 3, 5, 7):       # same bucket -> same compiled shape
        res = sess.run([Reach(0, i + 1) for i in range(n_batch)])
        assert len(res) == n_batch
    assert sess.last_plan.groups[0].padded_size == 8


def test_query_ir_validation():
    with pytest.raises(ValueError, match="exactly one"):
        Rpq(0, 1)
    with pytest.raises(ValueError, match="exactly one"):
        Rpq(0, 1, regex="0*", automaton=_automaton("0*"))
    with pytest.raises(ValueError, match=">= 0"):
        Reach(-1, 2)
    with pytest.raises(TypeError):
        plan_queries(["not a query"], lambda q: None)
    # IR values are hashable/comparable, incl. automaton-based RPQs (the
    # automaton holds numpy arrays; value semantics go via cache_key)
    qa_a, qa_b = _automaton("0* 1"), _automaton("0* 1")
    assert Rpq(0, 1, automaton=qa_a) == Rpq(0, 1, automaton=qa_b)
    assert Rpq(0, 1, automaton=qa_a) != Rpq(0, 1, regex="0* 1")
    assert len({Rpq(0, 1, automaton=qa_a), Rpq(0, 1, automaton=qa_b),
                Reach(0, 1), Dist(0, 1)}) == 3


def test_session_version_stamping_and_apply():
    g, fr = _case(18, 40, 2, 5)
    sess = repro.connect(fr, backend="vmap").warm()
    r0 = sess.run([Reach(0, 1)])[0]
    assert r0.cache_version == 0
    stats = sess.apply(GraphDelta.insert([(0, 1)]))
    assert stats.mode in ("repair", "recompute", "rebuild")
    r1 = sess.run([Reach(0, 1)])[0]
    assert r1.answer and r1.cache_version == r0.cache_version + 1
    assert sess.stats.updates == 1
    # uncached execution never consulted the cache -> stamped None even
    # though a cache exists on the shared fragmentation
    assert dis_reach(fr, 0, 1).cache_version is None


# ---------------------------------------------------------------------------
# shims & stats consistency
# ---------------------------------------------------------------------------

def test_deprecated_shims_removed_seed_paths_warning_free():
    """PR 8 retired the PR-4-deprecated cache-bearing shims; the seed
    one-shot entry points survive and stay warning-free."""
    import warnings as _w
    import repro.core
    g, fr = _case(12, 30, 2, 2)
    qa = _automaton("0*")
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        dis_reach(fr, 0, 1)            # seed paths stay warning-free
        dis_dist(fr, 0, 1)
        dis_rpq(fr, 0, 1, qa)
    for name in ("dis_reach_cached", "dis_dist_cached", "dis_rpq_cached",
                 "dis_reach_batch", "dis_dist_batch", "dis_rpq_batch"):
        assert not hasattr(repro.core, name), name
        assert not hasattr(repro.core.api, name), name
        assert name not in repro.core.__all__


def test_traffic_bits_consistent_across_kinds():
    g, fr = _case(30, 90, 3, 3)
    B, words = fr.B, (fr.B + 31) // 32
    assert fr.traffic_bits("reach") == B * words * 32
    assert fr.traffic_bits("dist") == B * B * 32
    assert fr.traffic_bits("bounded") == fr.traffic_bits("dist")
    qa = _automaton("0* 1")
    side = B * qa.n_states
    assert fr.traffic_bits("rpq", states=qa.n_states) == \
        side * ((side + 31) // 32) * 32
    with pytest.raises(ValueError, match="unknown query kind"):
        fr.traffic_bits("nope")
    with pytest.raises(ValueError, match="unknown query kind"):
        fr.traffic_bits("nope", batch=8)
    # every query class reports through the one helper
    assert dis_reach(fr, 0, 1).stats.payload_bits == fr.traffic_bits("reach")
    assert dis_dist(fr, 0, 1).stats.payload_bits == fr.traffic_bits("dist")
    assert dis_rpq(fr, 0, 1, qa).stats.payload_bits == \
        fr.traffic_bits("rpq", states=qa.n_states)
    # fused-batch wire format: side + 2N rows of side + 1 (the direct
    # column); Boolean kinds bitpack, the tropical wire ships raw int32
    nb, N = fr.n_boundary, 8
    assert fr.traffic_bits("reach", batch=N) == \
        (nb + 2 * N) * ((nb + 1 + 31) // 32) * 32
    assert fr.traffic_bits("dist", batch=N) == (nb + 2 * N) * (nb + 1) * 32
    sq = nb * qa.n_states
    assert fr.traffic_bits("rpq", states=qa.n_states, batch=N) == \
        (sq + 2 * N) * ((sq + 1 + 31) // 32) * 32


def test_group_traffic_sums_to_one_collective_vmap():
    """Per-group stats amortize the group's ONE collective: summed
    payload_bits over every fused group equal the wire size of that
    group's single collective, and exactly one collective round is
    reported per group (not one per query)."""
    g, fr = _case(20, 55, 3, 9)
    sess = repro.connect(fr, backend="vmap")
    qa = _automaton(REGEXES[0])
    queries = [Reach(0, 5), Reach(3, 3), Reach(1, 2), Dist(0, 7),
               Dist(2, 2, bound=1), Rpq(4, 9, automaton=qa),
               Rpq(5, 5, automaton=qa), Dist(6, 1, bound=3)]
    results = sess.run(queries)
    for grp in sess.last_plan.groups:
        states = 1 if grp.automaton is None else grp.automaton.n_states
        want = fr.traffic_bits(grp.kind, states=states,
                               batch=grp.padded_size)
        assert sum(results[i].stats.payload_bits
                   for i in grp.indices) == want, grp.kind
        assert sum(results[i].stats.collective_rounds
                   for i in grp.indices) == 1, grp.kind


# ---------------------------------------------------------------------------
# server: rpq kind, submit validation, batches spanning a delta
# ---------------------------------------------------------------------------

def test_sharded_device_inputs_memoized_until_delta():
    """The batched sharded engines' device uploads (edge lists + boundary
    gathers) are built once per fragmentation state: repeat batches reuse
    the memo, and an apply_delta (which mutates the host arrays in place)
    invalidates it via arrays_version."""
    from repro.core import Placement, distributed
    g, fr = _case(16, 40, 2, 3)
    pl = Placement.round_robin(fr.k, fr.k)
    m1 = distributed._device_inputs(fr, pl)
    assert distributed._device_inputs(fr, pl) is m1   # steady state: reused
    # a different placement misses the (version, placement) memo key
    other = Placement.balanced(fr, 1)
    assert distributed._device_inputs(fr, other) is not m1
    v0 = fr.arrays_version
    fr.apply_delta(GraphDelta.insert([(0, 1)]))
    assert fr.arrays_version == v0 + 1
    m2 = distributed._device_inputs(fr, pl)
    assert m2 is not m1 and m2["version"] == fr.arrays_version
    assert distributed._device_inputs(fr, pl) is m2


def test_server_submit_validates_kind_and_args():
    g, fr = _case(10, 20, 2, 6)
    srv = QueryServer(fr, batch_size=4, warm=False)
    with pytest.raises(ValueError, match="unknown query kind 'reachh'"):
        srv.submit(0, 1, kind="reachh")
    with pytest.raises(ValueError, match="bound"):
        srv.submit(0, 1, kind="bounded")
    with pytest.raises(ValueError, match="only valid for kind='bounded'"):
        srv.submit(0, 1, kind="dist", bound=3)    # meant kind="bounded"
    with pytest.raises(ValueError, match="exactly one"):
        srv.submit(0, 1, kind="rpq")
    with pytest.raises(ValueError, match="only valid"):
        srv.submit(0, 1, kind="reach", regex="0*")
    assert srv.pending() == 0          # rejected submits never enqueue


def test_server_serves_rpq_kind():
    g, fr = _case(18, 50, 3, 7)
    srv = QueryServer(fr, batch_size=4, start=False)
    qa = _automaton(REGEXES[1])
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(9):
        s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
        # alternate regex / prebuilt automaton — same fused group either way
        if i % 2:
            reqs.append(srv.submit(s, t, kind="rpq", regex=REGEXES[1]))
        else:
            reqs.append(srv.submit(s, t, kind="rpq", automaton=qa))
    srv.flush()
    for r in reqs:
        assert r.value == oracle_rpq(g, r.s, r.t, qa), (r.s, r.t)
        assert r.cache_version is not None


def test_server_mixed_batch_spanning_delta_snapshots():
    """Queries on both sides of a submit_delta answer against their own
    snapshot, for all three kinds in one flush."""
    g, fr = _case(16, 26, 2, 8)
    srv = QueryServer(fr, batch_size=8, start=False)
    qa = _automaton("(0|1|2)*")
    rng = np.random.default_rng(3)
    pairs = [(int(rng.integers(g.n)), int(rng.integers(g.n)))
             for _ in range(4)]
    pre = ([srv.submit(s, t) for s, t in pairs]
           + [srv.submit(s, t, kind="dist") for s, t in pairs]
           + [srv.submit(s, t, kind="rpq", automaton=qa)
              for s, t in pairs])
    pre_want = ([oracle_reach(g, s, t) for s, t in pairs]
                + [oracle_dist(g, s, t) for s, t in pairs]
                + [oracle_rpq(g, s, t, qa) for s, t in pairs])
    delta = GraphDelta.insert(
        [(int(rng.integers(g.n)), int(rng.integers(g.n)))
         for _ in range(3)])
    upd = srv.submit_delta(delta)
    post = ([srv.submit(s, t) for s, t in pairs]
            + [srv.submit(s, t, kind="rpq", automaton=qa)
               for s, t in pairs])
    srv.flush()
    g2 = fr.g                                  # post-delta graph
    post_want = ([oracle_reach(g2, s, t) for s, t in pairs]
                 + [oracle_rpq(g2, s, t, qa) for s, t in pairs])
    assert [r.value for r in pre] == pre_want
    assert [r.value for r in post] == post_want
    assert upd.value is not None and srv.updates_applied == 1
    # snapshot stamps: everything before the delta at version v, after > v
    v_pre = {r.cache_version for r in pre}
    v_post = {r.cache_version for r in post}
    assert len(v_pre) == 1 and len(v_post) == 1
    assert v_post.pop() > v_pre.pop()


# ---------------------------------------------------------------------------
# shard_map backend over 8 fake devices (subprocess, like test_guarantees)
# ---------------------------------------------------------------------------

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
sys.path.insert(0, "__SRC__")
sys.path.insert(0, "__TESTS__")
import numpy as np
import repro
from repro.core import (Dist, GraphDelta, Reach, Rpq, build_query_automaton,
                        fragment_graph)
from repro.core.distributed import fragment_mesh
from repro.graph import erdos_renyi, random_partition
from repro.serve import QueryServer
from oracles import oracle_dist, oracle_reach, oracle_rpq

g = erdos_renyi(40, 120, n_labels=3, seed=7)
fr = fragment_graph(g, random_partition(g, 8, 1), 8)
sess = repro.connect(fr)                      # auto -> shard_map on 8 devs
qa = build_query_automaton("(0|1)*", lambda x: int(x))
rng = np.random.default_rng(2)
queries, want = [], []
for _ in range(12):
    s, t = int(rng.integers(g.n)), int(rng.integers(g.n))
    kind = int(rng.integers(3))
    if kind == 0:
        queries.append(Reach(s, t)); want.append(oracle_reach(g, s, t))
    elif kind == 1:
        queries.append(Dist(s, t)); want.append(oracle_dist(g, s, t))
    else:
        queries.append(Rpq(s, t, automaton=qa))
        want.append(oracle_rpq(g, s, t, qa))
res = sess.run(queries)
got = [r.distance if isinstance(q, Dist) else r.answer
       for q, r in zip(queries, res)]
kinds_seen = sorted({grp.kind for grp in sess.last_plan.groups})

# summed per-group QueryStats == the wire of the group's ONE collective
bits_ok = True
for grp in sess.last_plan.groups:
    states = 1 if grp.automaton is None else grp.automaton.n_states
    total = fr.traffic_bits(grp.kind, states=states, batch=grp.padded_size)
    bits_ok &= sum(res[i].stats.payload_bits for i in grp.indices) == total
    bits_ok &= sum(res[i].stats.collective_rounds for i in grp.indices) == 1

# backend='auto' judges shard_map-vs-vmap against an explicit mesh, not
# the process device count (8 devices here, mesh of 2): with the k >> d
# packing layer a 2-device mesh HOLDS 4 fragments (2 per device), so auto
# picks shard_map; a mesh larger than fr.k still cannot work (a fragment
# is never split across devices) and must fall back / refuse instead of
# crashing inside the engine
mesh2 = fragment_mesh(2)
mesh4 = fragment_mesh(4)
fr4 = fragment_graph(g, random_partition(g, 4, 0), 4)
fr2 = fragment_graph(g, random_partition(g, 2, 0), 2)
small = repro.connect(fr4, mesh=mesh2)        # 4 frags packed on 2 devices
auto_small_mesh = small.backend                     # must be shard_map now
small_res = small.run([Reach(0, 5), Dist(1, 7)])
small_ok = (small_res[0].answer == oracle_reach(g, 0, 5)
            and small_res[1].distance == oracle_dist(g, 1, 7)
            and small.placement.d == 2 and small.placement.fpd == 2)
auto_big_mesh = repro.connect(fr2, mesh=mesh4).backend       # must be vmap
auto_fit_mesh = repro.connect(fr2, mesh=mesh2).backend  # must be shard_map
try:
    repro.connect(fr2, backend="shard_map", mesh=mesh4)
    big_mesh_raises = False
except ValueError:
    big_mesh_raises = True
sess2 = repro.connect(fr2, mesh=mesh2)
res2 = sess2.run([Reach(0, 5), Dist(1, 7), Rpq(2, 9, automaton=qa)])
mesh_ok = (res2[0].answer == oracle_reach(g, 0, 5)
           and res2[1].distance == oracle_dist(g, 1, 7)
           and res2[2].answer == oracle_rpq(g, 2, 9, qa))

# server over the shard_map backend: a mixed batch of all three kinds
# spanning a submit_delta answers each side against its own snapshot
gs = erdos_renyi(24, 40, n_labels=3, seed=8)
frs = fragment_graph(gs, random_partition(gs, 4, 3), 4,
                     reserve_boundary=8, reserve_edges=16, reserve_stubs=8)
srv = QueryServer(frs, batch_size=16, start=False)
qa2 = build_query_automaton("(0|1|2)*", lambda x: int(x))
pairs = [(int(rng.integers(gs.n)), int(rng.integers(gs.n)))
         for _ in range(4)]
def submit_all():
    return ([srv.submit(s, t) for s, t in pairs]
            + [srv.submit(s, t, kind="dist") for s, t in pairs]
            + [srv.submit(s, t, kind="rpq", automaton=qa2)
               for s, t in pairs])
def want_all(gg):
    return ([oracle_reach(gg, s, t) for s, t in pairs]
            + [oracle_dist(gg, s, t) for s, t in pairs]
            + [oracle_rpq(gg, s, t, qa2) for s, t in pairs])
pre = submit_all()
pre_want = want_all(gs)
upd = srv.submit_delta(GraphDelta.insert(
    [(int(rng.integers(gs.n)), int(rng.integers(gs.n))) for _ in range(3)]))
post = submit_all()
srv.flush()
post_want = want_all(frs.g)                   # post-delta graph
v_pre = {r.cache_version for r in pre}
v_post = {r.cache_version for r in post}
server_ok = ([r.value for r in pre] == pre_want
             and [r.value for r in post] == post_want
             and len(v_pre) == 1 and len(v_post) == 1
             and v_post.pop() > v_pre.pop())

print(json.dumps({"backend": sess.backend, "ok": got == want,
                  "kinds": kinds_seen, "bits_ok": bool(bits_ok),
                  "groups": sess.last_plan.n_groups,
                  "executions": sess.stats.executions,
                  "auto_small_mesh": auto_small_mesh,
                  "small_ok": bool(small_ok),
                  "auto_big_mesh": auto_big_mesh,
                  "auto_fit_mesh": auto_fit_mesh,
                  "big_mesh_raises": bool(big_mesh_raises),
                  "mesh_ok": bool(mesh_ok),
                  "server_backend": srv.session.backend,
                  "update_mode": upd.value.mode,
                  "server_ok": bool(server_ok)}))
"""


@pytest.fixture(scope="module")
def shard_map_report():
    here = os.path.dirname(__file__)
    code = (_SUBPROC
            .replace("__SRC__", os.path.abspath(os.path.join(here, "..",
                                                             "src")))
            .replace("__TESTS__", os.path.abspath(here)))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_session_shard_map_mixed_batch_subprocess(shard_map_report):
    rep = shard_map_report
    assert rep["backend"] == "shard_map"
    assert rep["ok"], rep
    assert rep["executions"] == rep["groups"]
    # the random draw produced all three kinds -> all three sharded paths ran
    assert rep["kinds"] == ["dist", "reach", "rpq"], rep


def test_shard_map_group_traffic_sums_to_one_collective(shard_map_report):
    """Summed QueryStats over any fused shard_map group equals the wire
    size of that group's single collective (one round per group)."""
    assert shard_map_report["bits_ok"], shard_map_report


def test_auto_backend_respects_explicit_mesh(shard_map_report):
    """backend='auto' with an explicit mesh decides from the mesh's device
    count: a 2-device mesh holds 4 fragments (2 packed per device) so auto
    picks shard_map and answers match the oracle; a mesh larger than fr.k
    must fall back to vmap (auto) or raise up front (explicit) instead of
    crashing inside the sharded engine."""
    rep = shard_map_report
    assert rep["auto_small_mesh"] == "shard_map", rep
    assert rep["small_ok"], rep
    assert rep["auto_big_mesh"] == "vmap", rep
    assert rep["auto_fit_mesh"] == "shard_map", rep
    assert rep["big_mesh_raises"], rep
    assert rep["mesh_ok"], rep


def test_server_shard_map_mixed_batch_spanning_delta(shard_map_report):
    """QueryServer on the shard_map backend: all three kinds in one flush,
    split across a submit_delta, answer against their own snapshots."""
    rep = shard_map_report
    assert rep["server_backend"] == "shard_map", rep
    assert rep["server_ok"], rep
    assert rep["update_mode"] in ("repair_sharded", "repair", "recompute",
                                  "rebuild"), rep
