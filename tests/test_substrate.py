"""Substrate: optimizer, checkpointing, fault-tolerant trainer, compression,
serving loop, data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.data import TokenStream
from repro.models import transformer as T
from repro.optim import adamw
from repro.train import Trainer, TrainerConfig, compression
from repro.serve import Request, ServeEngine


def _cfg():
    return T.LMConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_head=16, d_ff=64, vocab=64, remat=False)


def _loss_fn(cfg):
    return lambda p, b: T.lm_loss(cfg, p, b["tokens"], b["targets"])


def test_adamw_reduces_loss():
    cfg = _cfg()
    params = T.init_params(cfg, jax.random.key(0))
    stream = TokenStream(vocab=64, batch=8, seq_len=16)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    state = adamw.init(params)
    loss_fn = _loss_fn(cfg)
    losses = []

    @jax.jit
    def step(params, state, batch):
        l, g = jax.value_and_grad(loss_fn)(params, batch)
        params, state, _ = adamw.update(opt_cfg, g, state, params)
        return params, state, l

    for i in range(40):
        params, state, l = step(params, state, stream.batch_at(i))
        losses.append(float(l))
    assert losses[-1] < losses[0] * 0.9, losses[::10]


def test_ckpt_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = dict(a=jnp.arange(5), b=[jnp.ones((2, 3)), jnp.float32(7)],
                c=dict(d=jnp.zeros(1, jnp.int32)))
    mgr.save(3, tree)
    mgr.save(9, tree)
    mgr.save(12, tree)
    assert mgr.latest_step() == 12
    back = mgr.restore()
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # GC kept only 2
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(dirs) == 2


def test_trainer_recovers_from_failure_bitwise(tmp_path):
    """Crash at step 7, restore from ckpt at 5, replay -> same trajectory."""
    cfg = _cfg()
    params = T.init_params(cfg, jax.random.key(0))
    stream = TokenStream(vocab=64, batch=4, seq_len=12)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)

    def make(path, fail):
        tr = Trainer(TrainerConfig(ckpt_dir=path, ckpt_every=5,
                                   ckpt_async=False, max_restarts=2),
                     opt_cfg, _loss_fn(cfg), params)
        fired = {"done": False}

        def hook(step):
            if fail and step == 7 and not fired["done"]:
                fired["done"] = True
                raise RuntimeError("simulated node failure")
        tr.run(lambda s: stream.batch_at(s), 10,
               fail_hook=hook if fail else None)
        return tr

    t_clean = make(str(tmp_path / "clean"), fail=False)
    t_fail = make(str(tmp_path / "fail"), fail=True)
    for a, b in zip(jax.tree.leaves(t_clean.state["params"]),
                    jax.tree.leaves(t_fail.state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(t_fail.state["step"]) == 10


def test_grad_accum_equivalence(tmp_path):
    """grad_accum=4 over microbatches == one big batch (linear loss avg)."""
    cfg = _cfg()
    params = T.init_params(cfg, jax.random.key(1))
    stream = TokenStream(vocab=64, batch=8, seq_len=12)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                                clip_norm=1e9)
    big = stream.batch_at(0)
    micro = jax.tree.map(lambda x: x.reshape(4, 2, *x.shape[1:]), big)

    t1 = Trainer(TrainerConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=100),
                 opt_cfg, _loss_fn(cfg), params)
    s1, _ = t1._step_fn(t1.state, big)
    t2 = Trainer(TrainerConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=100,
                               grad_accum=4), opt_cfg, _loss_fn(cfg), params)
    s2, _ = t2._step_fn(t2.state, micro)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_compression_error_feedback_unbiased():
    """Sum of dequantized grads over steps tracks the true sum (error
    feedback carries the residual)."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
              for _ in range(20)]
    err = compression.init_error(g_true[0])
    tot_deq = jnp.zeros((32, 32))
    for g in g_true:
        deq, err = compression.compress_decompress(g, err)
        tot_deq = tot_deq + deq
    tot_true = sum(g_true)
    resid = float(jnp.max(jnp.abs(tot_deq - tot_true)))
    scale = float(jnp.max(jnp.abs(tot_true)))
    # residual bounded by one quantization step, NOT accumulating over steps
    assert resid < 0.05 * scale + 0.1


def test_serve_engine_greedy_decode():
    cfg = _cfg()
    params = T.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, batch=2, max_len=32)
    reqs = [Request(prompt=np.array([3, 5, 7], np.int32), max_new_tokens=4),
            Request(prompt=np.array([11, 13], np.int32), max_new_tokens=4)]
    done = eng.generate(reqs)
    for r in done:
        assert len(r.generated) == 4
        assert all(0 <= t < cfg.vocab for t in r.generated)


def test_data_stream_deterministic():
    s = TokenStream(vocab=100, batch=4, seq_len=8, seed=3)
    a, b = s.batch_at(17), s.batch_at(17)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = s.batch_at(18)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))


def test_elastic_reshard_roundtrip():
    from repro.train import reshard
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = dict(w=jnp.ones((8, 4)), b=jnp.zeros(4))
    out = reshard(tree, mesh, lambda path, leaf: P())
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((8, 4)))
